//! End-to-end coordinator pipeline tests over real (scaled) datasets:
//! load → preprocess → run → metrics, for every registered app and
//! dataset family — all through the `GraphApp` registry.

use cagra::apps::{bc, bfs, cf, pagerank, registry};
use cagra::coordinator::{run_job, AppKind, JobSpec, SystemConfig};

const SCALE: f64 = 1.0 / 64.0;

fn spec(dataset: &str, app: AppKind, iters: usize) -> JobSpec {
    JobSpec {
        dataset: dataset.to_string(),
        app,
        iters,
        num_sources: 2,
        scale: SCALE,
        ..Default::default()
    }
}

#[test]
fn every_registered_app_variant_runs_through_the_pipeline() {
    // The §6.1 suite, complete: all 8 apps, every advertised variant,
    // through the one generic run_job loop.
    let cfg = SystemConfig {
        llc_bytes: 32 * 1024, // scaled so small graphs still segment
        ..Default::default()
    };
    assert_eq!(registry::APPS.len(), 8);
    for app in registry::APPS {
        for v in app.variants() {
            let r = run_job(&spec("livejournal-sim", v.kind, 2), &cfg)
                .unwrap_or_else(|e| panic!("{}/{}: {e:#}", app.name(), v.name));
            assert!(
                r.summary.is_finite() && r.summary != 0.0,
                "{}/{}: summary {}",
                app.name(),
                v.name,
                r.summary
            );
            assert!(r.metrics.edges > 0);
            assert_eq!(
                r.metrics.app.as_deref(),
                Some(format!("{}/{}", v.kind.app_name(), v.kind.variant_name()).as_str())
            );
        }
    }
}

#[test]
fn registry_variants_round_trip_through_parse() {
    for app in registry::APPS {
        for v in app.variants() {
            let parsed = AppKind::parse(app.name(), v.name)
                .unwrap_or_else(|e| panic!("{}/{}: {e:#}", app.name(), v.name));
            assert_eq!(parsed, v.kind, "{}/{}", app.name(), v.name);
            for alias in v.aliases {
                assert_eq!(
                    AppKind::parse(app.name(), alias).unwrap(),
                    v.kind,
                    "{} alias {alias}",
                    app.name()
                );
            }
        }
        // App aliases resolve to the same app.
        for alias in app.aliases() {
            let via_alias = AppKind::parse(alias, app.variants()[0].name).unwrap();
            assert_eq!(via_alias, app.variants()[0].kind, "app alias {alias}");
        }
        assert!(AppKind::parse(app.name(), "definitely-not-a-variant").is_err());
    }
    assert!(AppKind::parse("definitely-not-an-app", "baseline").is_err());
}

#[test]
fn pagerank_all_variants_on_all_graph_datasets() {
    let cfg = SystemConfig {
        llc_bytes: 32 * 1024, // scaled so small graphs still segment
        ..Default::default()
    };
    for ds in cagra::graph::datasets::GRAPH_DATASETS {
        for &v in pagerank::Variant::all() {
            let r = run_job(&spec(ds, AppKind::PageRank(v), 3), &cfg)
                .unwrap_or_else(|e| panic!("{ds}/{}: {e:#}", v.name()));
            assert_eq!(r.metrics.iter_seconds.len(), 3, "{ds}/{}", v.name());
            assert!(r.metrics.edges > 0);
        }
    }
}

#[test]
fn cf_on_netflix_family() {
    let cfg = SystemConfig::default();
    for ds in ["netflix-sim"] {
        for v in [cf::Variant::Baseline, cf::Variant::Segmented] {
            let r = run_job(&spec(ds, AppKind::Cf(v), 2), &cfg).unwrap();
            assert!(r.summary.is_finite() && r.summary > 0.0, "rmse {}", r.summary);
        }
    }
}

#[test]
fn frontier_apps_run() {
    let cfg = SystemConfig::default();
    for app in [
        AppKind::Bfs(bfs::Variant::ReorderedBitvector),
        AppKind::Bc(bc::Variant::Baseline),
    ] {
        let r = run_job(&spec("livejournal-sim", app, 1), &cfg).unwrap();
        assert!(r.summary > 0.0);
        // Per-source apps record one timing entry per source.
        assert_eq!(r.metrics.iter_seconds.len(), 2);
    }
}

#[test]
fn memory_analysis_attaches_stalls() {
    let cfg = SystemConfig {
        llc_bytes: 32 * 1024,
        ..Default::default()
    };
    let mut s = spec(
        "rmat27-sim",
        AppKind::PageRank(pagerank::Variant::Baseline),
        1,
    );
    s.analyze_memory = true;
    let r = run_job(&s, &cfg).unwrap();
    let stalls = r.metrics.stalls.expect("stall estimate attached");
    assert!(stalls.accesses > 0);
    assert!(stalls.llc_miss_rate > 0.0);
}

#[test]
fn segmented_beats_baseline_on_simulated_stalls() {
    // The paper's central claim, via the simulator, on a scaled dataset
    // with working set >> effective LLC.
    let cfg = SystemConfig {
        llc_bytes: 16 * 1024,
        ..Default::default()
    };
    let ds = cagra::graph::datasets::load_scaled("rmat27-sim", SCALE).unwrap();
    let base = cagra::coordinator::job::simulate_pagerank(
        &ds.graph,
        &cfg,
        pagerank::Variant::Baseline,
    );
    let seg = cagra::coordinator::job::simulate_pagerank(
        &ds.graph,
        &cfg,
        pagerank::Variant::ReorderedSegmented,
    );
    assert!(
        seg.stall_cycles < base.stall_cycles,
        "seg {} !< base {}",
        seg.stall_cycles,
        base.stall_cycles
    );
    // Note: total LLC miss *rate* need not drop — segmenting converts
    // expensive random misses into cheap sequential (prefetched) misses;
    // the cost per miss is what falls. Stall cycles capture that. The
    // random-read miss-rate drop itself is asserted in cache::stall's
    // unit tests with an L1/L2-scaled hierarchy.
    assert!(seg.stalls_per_access() < base.stalls_per_access());
}
