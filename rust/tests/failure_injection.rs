//! Failure-injection and edge-case tests: malformed inputs, degenerate
//! graphs, and hostile configurations must fail cleanly (or degrade
//! gracefully), never corrupt results.

use cagra::apps::pagerank;
use cagra::coordinator::{run_job, AppKind, JobSpec, SystemConfig};
use cagra::graph::{edgelist, Csr, CsrBuilder};
use cagra::segment::{SegmentBuffers, SegmentedCsr};

#[test]
fn empty_graph() {
    let g = Csr::from_edges(0, &[]);
    assert_eq!(g.num_vertices(), 0);
    let sg = SegmentedCsr::build(&g, 16);
    assert_eq!(sg.num_edges(), 0);
    let mut bufs = SegmentBuffers::for_graph(&sg);
    let mut out: Vec<f64> = vec![];
    sg.aggregate(|_| 1.0, &mut bufs, 0.0, &mut out);
}

#[test]
fn single_vertex_no_edges() {
    let g = Csr::from_edges(1, &[]);
    let cfg = SystemConfig::default();
    for &v in pagerank::Variant::all() {
        let r = pagerank::run(&g, &cfg, v, 3);
        assert_eq!(r.values.len(), 1);
        assert!(r.values[0].is_finite());
    }
}

#[test]
fn all_self_loops_graph_becomes_empty() {
    let mut b = CsrBuilder::new(4);
    for v in 0..4u32 {
        b.add_edge(v, v);
    }
    let g = b.build();
    assert_eq!(g.num_edges(), 0);
    let cfg = SystemConfig::default();
    let r = pagerank::run(&g, &cfg, pagerank::Variant::Segmented, 2);
    // No edges: every vertex holds the teleport mass.
    for v in r.values {
        assert!((v - (1.0 - cfg.damping) / 4.0).abs() < 1e-12);
    }
}

#[test]
fn star_graph_extreme_skew() {
    // One hub pointed at by everyone: worst-case degree skew for the
    // cost-based load balancer.
    let n = 5000;
    let edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (v, 0)).collect();
    let g = Csr::from_edges(n, &edges);
    let cfg = SystemConfig {
        llc_bytes: 8 * 1024,
        ..Default::default()
    };
    let want = pagerank::reference(&g, cfg.damping, 3);
    for &v in pagerank::Variant::all() {
        let got = pagerank::run(&g, &cfg, v, 3);
        for (i, (a, b)) in got.values.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-9, "{} v={i}", v.name());
        }
    }
}

#[test]
fn corrupt_binary_edge_list_rejected() {
    let dir = std::env::temp_dir().join(format!("cagra-fi-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Out-of-range vertex id in the payload.
    let p = dir.join("bad.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"CAGRAEL1");
    bytes.extend_from_slice(&2u64.to_le_bytes()); // n = 2
    bytes.extend_from_slice(&1u64.to_le_bytes()); // m = 1
    bytes.extend_from_slice(&9u32.to_le_bytes()); // src 9 >= n
    bytes.extend_from_slice(&0u32.to_le_bytes());
    std::fs::write(&p, bytes).unwrap();
    assert!(edgelist::read_binary(&p).is_err());
    // Truncated file.
    let p2 = dir.join("trunc.bin");
    std::fs::write(&p2, b"CAGRAEL1\x01").unwrap();
    assert!(edgelist::read_binary(&p2).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn unknown_dataset_job_fails_cleanly() {
    let spec = JobSpec {
        dataset: "not-a-dataset".into(),
        app: AppKind::PageRank(pagerank::Variant::Baseline),
        iters: 1,
        num_sources: 1,
        ..Default::default()
    };
    let err = run_job(&spec, &SystemConfig::default()).unwrap_err();
    assert!(format!("{err:#}").contains("unknown dataset"));
}

#[test]
fn hostile_segment_sizes() {
    let (n, e) = cagra::graph::generators::rmat(
        8,
        4,
        cagra::graph::generators::RmatParams::graph500(),
        77,
    );
    let g = Csr::from_edges(n, &e);
    let want = pagerank::reference(&g, 0.85, 2);
    // seg_size = 1 (one segment per vertex) and gigantic both work.
    for seg in [1usize, 3, n, n * 10] {
        let sg = SegmentedCsr::build(&g, seg);
        let mut bufs = SegmentBuffers::for_graph(&sg);
        let inv: Vec<f64> = (0..n)
            .map(|v| {
                let d = g.degree(v as u32);
                if d == 0 {
                    0.0
                } else {
                    1.0 / d as f64
                }
            })
            .collect();
        let mut rank = vec![1.0 / n as f64; n];
        let mut out = vec![0.0; n];
        for _ in 0..2 {
            let contrib: Vec<f64> = rank.iter().zip(&inv).map(|(r, i)| r * i).collect();
            sg.aggregate(|u| contrib[u as usize], &mut bufs, 0.0, &mut out);
            for v in 0..n {
                out[v] = 0.15 / n as f64 + 0.85 * out[v];
            }
            std::mem::swap(&mut rank, &mut out);
        }
        for (i, (a, b)) in rank.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-9, "seg={seg} v={i}: {a} vs {b}");
        }
    }
}

#[test]
fn zero_iterations_is_identity() {
    let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
    let cfg = SystemConfig::default();
    let r = pagerank::run(&g, &cfg, pagerank::Variant::Baseline, 0);
    for v in r.values {
        assert!((v - 1.0 / 3.0).abs() < 1e-12);
    }
}
