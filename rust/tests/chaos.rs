//! Chaos coverage for the serve path (DESIGN.md §8): the daemon keeps
//! serving — and every *successful* reply stays bitwise identical to a
//! fault-free run — while deterministic failpoints inject worker panics,
//! storage failures, and clients abort mid-stream. Every scenario ends
//! with a graceful drain joined under a hard timeout, so a hang is a
//! test failure, never a stuck CI job.
//!
//! The failpoint registry is process-global, so every test serializes on
//! [`LOCK`] (arming in one test must not leak into another).

use cagra::coordinator::{run_job, JobSpec, SystemConfig};
use cagra::serve::{serve, ServeOpts};
use cagra::util::json::{parse, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: each arms (or disarms) the
/// process-global failpoint registry when its daemon's pool starts.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

const SCALE: f64 = 1.0 / 64.0;

fn small_spec() -> JobSpec {
    JobSpec {
        dataset: "livejournal-sim".into(),
        scale: SCALE,
        iters: 2,
        ..Default::default()
    }
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cagra-chaos-{tag}-{}", std::process::id()))
}

/// A daemon under test: the bound address plus a completion channel so
/// tests can join it with a timeout (a hang fails fast instead of
/// wedging the whole test binary).
struct Daemon {
    addr: String,
    done: mpsc::Receiver<anyhow::Result<()>>,
    port_file: PathBuf,
}

fn start_daemon(tag: &str, cfg: SystemConfig, mut opts: ServeOpts) -> Daemon {
    let port_file = temp_path(&format!("{tag}-port"));
    std::fs::remove_file(&port_file).ok();
    opts.addr = "127.0.0.1:0".to_string();
    opts.stdio = false;
    opts.port_file = Some(port_file.display().to_string());
    let (tx, done) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(serve(cfg, &opts));
    });
    let deadline = Instant::now() + Duration::from_secs(30);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                break s;
            }
        }
        assert!(Instant::now() < deadline, "daemon never wrote the port file");
        std::thread::sleep(Duration::from_millis(10));
    };
    Daemon { addr, done, port_file }
}

impl Daemon {
    fn connect(&self) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(&self.addr).expect("connect");
        let writer = stream.try_clone().expect("clone");
        (writer, BufReader::new(stream))
    }

    /// Graceful drain with a hard no-hang bound. Tolerates a transient
    /// `overloaded` refusal: connection slots free asynchronously after
    /// a client drops, so a fresh connection can race the accounting.
    fn shutdown_and_join(self) {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let (mut w, mut r) = self.connect();
            match try_roundtrip(&mut w, &mut r, r#"{"op":"shutdown"}"#) {
                Some(ack) if ack.get("ok") == Some(&Value::Bool(true)) => break,
                Some(ack) => assert_eq!(
                    ack.get("error").and_then(Value::as_str),
                    Some("overloaded"),
                    "shutdown nacked: {ack:?}"
                ),
                None => {} // refusal raced the send; try again
            }
            assert!(Instant::now() < deadline, "connection slots never freed");
            std::thread::sleep(Duration::from_millis(20));
        }
        self.done
            .recv_timeout(Duration::from_secs(120))
            .expect("daemon hung past drain deadline")
            .expect("daemon errored");
        std::fs::remove_file(&self.port_file).ok();
    }
}

fn roundtrip(writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Value {
    try_roundtrip(writer, reader, line).expect("request round trip")
}

/// Best-effort round trip: `None` when the daemon closed on us (e.g. an
/// `overloaded` refusal raced our send) — callers in retry loops treat
/// that as "try a fresh connection".
fn try_roundtrip(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> Option<Value> {
    writer
        .write_all(format!("{line}\n").as_bytes())
        .and_then(|()| writer.flush())
        .ok()?;
    let mut reply = String::new();
    match reader.read_line(&mut reply) {
        Ok(n) if n > 0 => {}
        _ => return None,
    }
    Some(parse(reply.trim()).unwrap_or_else(|e| panic!("unparseable reply {reply:?}: {e:#}")))
}

fn run_line(id: u64) -> String {
    format!(
        r#"{{"op":"run","id":{id},"app":"pagerank","graph":"livejournal-sim","scale":{SCALE},"iters":2}}"#
    )
}

/// Like [`run_line`] but a variant with cacheable preprocessing, so the
/// job actually exercises the disk artifact store.
fn run_line_stored(id: u64) -> String {
    format!(
        r#"{{"op":"run","id":{id},"app":"pagerank","variant":"reordering","graph":"livejournal-sim","scale":{SCALE},"iters":2}}"#
    )
}

/// Injected job panics become `failed` replies; the pool keeps serving
/// with all workers alive and successful replies stay bitwise identical
/// to a fault-free in-process run.
#[test]
fn worker_panics_are_contained_and_serving_continues() {
    let _g = lock();
    // Reference before any failpoint arms (the registry is clean here).
    let expected = run_job(&small_spec(), &SystemConfig::default())
        .expect("reference job")
        .summary;
    let cfg = SystemConfig {
        failpoints: "worker.job=panic@every:3".to_string(),
        ..SystemConfig::default()
    };
    let daemon = start_daemon(
        "panic",
        cfg,
        ServeOpts {
            workers: 2,
            queue_cap: 8,
            ..ServeOpts::default()
        },
    );
    let (mut w, mut r) = daemon.connect();
    // One serial client → job executions are sequential → exactly the
    // 3rd and 6th fire. Panics must surface as replies, never hangups.
    let mut failed = 0;
    for id in 1..=6u64 {
        let v = roundtrip(&mut w, &mut r, &run_line(id));
        assert_eq!(v.get("id").and_then(Value::as_u64), Some(id));
        if v.get("ok") == Some(&Value::Bool(true)) {
            let got = v.get("summary").and_then(Value::as_f64).expect("summary");
            assert_eq!(
                got.to_bits(),
                expected.to_bits(),
                "request {id}: summary under faults differs from fault-free"
            );
        } else {
            assert_eq!(
                v.get("error").and_then(Value::as_str),
                Some("failed"),
                "request {id}: wrong error kind: {v:?}"
            );
            failed += 1;
        }
    }
    assert_eq!(failed, 2, "every:3 over 6 jobs must fail exactly twice");
    let stats = roundtrip(&mut w, &mut r, r#"{"op":"stats"}"#);
    assert_eq!(
        stats.get("panics_contained").and_then(Value::as_u64),
        Some(2),
        "stats: {stats:?}"
    );
    assert_eq!(
        stats.get("workers_alive").and_then(Value::as_u64),
        Some(2),
        "panicking jobs must not kill workers: {stats:?}"
    );
    assert_eq!(stats.get("jobs_done").and_then(Value::as_u64), Some(6));
    drop((w, r));
    daemon.shutdown_and_join();
}

/// Worker *thread* deaths are repaired by the supervisor: the abandoned
/// job errs, a replacement spawns, and the pool serves on at full
/// strength.
#[test]
fn dead_worker_threads_respawn_and_serving_continues() {
    let _g = lock();
    let cfg = SystemConfig {
        failpoints: "worker.thread=panic@every:4".to_string(),
        ..SystemConfig::default()
    };
    let daemon = start_daemon(
        "respawn",
        cfg,
        ServeOpts {
            workers: 2,
            queue_cap: 8,
            ..ServeOpts::default()
        },
    );
    let (mut w, mut r) = daemon.connect();
    let mut ok = 0;
    let mut failed = 0;
    for id in 1..=8u64 {
        let v = roundtrip(&mut w, &mut r, &run_line(id));
        if v.get("ok") == Some(&Value::Bool(true)) {
            ok += 1;
        } else {
            assert_eq!(v.get("error").and_then(Value::as_str), Some("failed"));
            failed += 1;
        }
    }
    assert_eq!(ok, 6, "every:4 over 8 jobs must abandon exactly 2");
    assert_eq!(failed, 2);
    // The supervisor replaces dead threads; give it a bounded moment.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = roundtrip(&mut w, &mut r, r#"{"op":"stats"}"#);
        if stats.get("workers_alive").and_then(Value::as_u64) == Some(2) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "workers never respawned: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    drop((w, r));
    daemon.shutdown_and_join();
}

/// Storage faults self-heal: injected write failures only cost the cache
/// entry, injected load failures quarantine the artifact and force a
/// rebuild — and every reply stays correct and bitwise stable.
#[test]
fn store_faults_quarantine_rebuild_and_stay_bitwise_correct() {
    let _g = lock();
    let spec = JobSpec {
        app: cagra::coordinator::AppKind::parse("pagerank", "reordering").unwrap(),
        ..small_spec()
    };
    let expected = run_job(&spec, &SystemConfig::default())
        .expect("reference job")
        .summary;
    let store_dir = temp_path("store");
    std::fs::remove_dir_all(&store_dir).ok();
    // Round 1 writes artifacts (every 3rd write fails, harmlessly);
    // later rounds would normally be served by the resident memory
    // layer, so `mem.insert` degrades it to pass-through and every warm
    // load goes to disk — where map and read both err, turning each hit
    // into quarantine → rebuild → correct fresh answer.
    let cfg = SystemConfig {
        store_enabled: true,
        store_dir: store_dir.display().to_string(),
        failpoints: "store.write=err@every:3;store.map=err@every:1;\
                     store.read=err@every:1;mem.insert=err@every:1"
            .to_string(),
        ..SystemConfig::default()
    };
    let daemon = start_daemon(
        "store",
        cfg,
        ServeOpts {
            workers: 1,
            queue_cap: 8,
            ..ServeOpts::default()
        },
    );
    let (mut w, mut r) = daemon.connect();
    for id in 1..=3u64 {
        let v = roundtrip(&mut w, &mut r, &run_line_stored(id));
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "request {id}: {v:?}");
        let got = v.get("summary").and_then(Value::as_f64).expect("summary");
        assert_eq!(
            got.to_bits(),
            expected.to_bits(),
            "request {id}: storage faults changed the answer"
        );
    }
    let stats = roundtrip(&mut w, &mut r, r#"{"op":"stats"}"#);
    let store = stats.get("store").expect("store stats when enabled");
    let quarantined = store.get("quarantined").and_then(Value::as_u64).unwrap_or(0);
    let rebuilds = store.get("rebuilds").and_then(Value::as_u64).unwrap_or(0);
    assert!(quarantined >= 1, "no artifact was quarantined: {stats:?}");
    assert!(rebuilds >= 1, "no rebuild was recorded: {stats:?}");
    // Self-healing evidence on disk, out of the store's way.
    assert!(
        store_dir.join(".quarantine").is_dir(),
        "quarantine dir missing"
    );
    drop((w, r));
    daemon.shutdown_and_join();
    std::fs::remove_dir_all(&store_dir).ok();
}

/// Abrupt client departures (mid-line abort, idle stall, connection
/// flood) never take the daemon down and never wedge the drain.
#[test]
fn client_aborts_idle_and_overload_are_contained() {
    let _g = lock();
    let daemon = start_daemon(
        "abort",
        SystemConfig::default(),
        ServeOpts {
            workers: 1,
            queue_cap: 4,
            max_conns: 2,
            idle_timeout_ms: 200,
            ..ServeOpts::default()
        },
    );
    // Abort mid-line: write half a request and slam the connection.
    {
        let (mut w, _r) = daemon.connect();
        w.write_all(br#"{"op":"run","app":"pa"#).expect("partial write");
        w.flush().ok();
    } // dropped here — RST/EOF while the daemon is mid-read
    // Idle stall: send nothing; the idle timeout must close us cleanly.
    {
        let (_w, mut r) = daemon.connect();
        let mut line = String::new();
        let n = r.read_line(&mut line).expect("idle close should be EOF");
        assert_eq!(n, 0, "expected clean close, got {line:?}");
    }
    // Flood past max_conns: the third concurrent connection gets one
    // `overloaded` line instead of a handler thread. Earlier aborted
    // connections may still hold slots for a moment (they free when the
    // handler notices the close), so retry until the state settles.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_w1, _r1) = daemon.connect();
        let (_w2, _r2) = daemon.connect();
        let (_w3, mut r3) = daemon.connect();
        let mut line = String::new();
        r3.read_line(&mut line).expect("overload reply");
        if line.trim().is_empty() {
            // r3 was admitted (stale slots had freed mid-flood) and then
            // idle-closed — the bound held, just not against us. Retry.
            assert!(Instant::now() < deadline, "flood never hit the bound");
            std::thread::sleep(Duration::from_millis(20));
            continue;
        }
        let v = parse(line.trim()).expect("overload line parses");
        assert_eq!(
            v.get("error").and_then(Value::as_str),
            Some("overloaded"),
            "admission bound reply: {line:?}"
        );
        break;
    }
    // After all that abuse: still serving, bitwise sane, drains clean.
    // (Retry the connect: flood slots free asynchronously.)
    let deadline = Instant::now() + Duration::from_secs(10);
    let (mut w, mut r) = loop {
        let (mut w, mut r) = daemon.connect();
        match try_roundtrip(&mut w, &mut r, r#"{"op":"ping","id":"alive"}"#) {
            Some(pong) if pong.get("ok") == Some(&Value::Bool(true)) => break (w, r),
            Some(pong) => assert_eq!(
                pong.get("error").and_then(Value::as_str),
                Some("overloaded"),
                "unexpected ping reply: {pong:?}"
            ),
            None => {} // refusal raced the send; try again
        }
        assert!(Instant::now() < deadline, "connection slots never freed");
        std::thread::sleep(Duration::from_millis(20));
    };
    let v = roundtrip(&mut w, &mut r, &run_line(99));
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "run after chaos: {v:?}");
    drop((w, r));
    daemon.shutdown_and_join();
}
