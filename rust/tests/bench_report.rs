//! Acceptance tests for the machine-readable bench-results subsystem:
//! the JSON report round trip is byte-stable, corrupt input always
//! errors, and `cagra bench diff` (library *and* CLI exit code) flags an
//! injected slowdown while passing within-noise jitter.

use cagra::bench::diff::{Diff, DiffOptions, Verdict};
use cagra::bench::report::{BenchFile, BenchReport, CaseResult, UNIT_SECS};
use std::path::PathBuf;
use std::process::Command;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cagra-benchrep-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn timed(name: &str, median: f64, stddev: f64) -> CaseResult {
    CaseResult {
        name: name.into(),
        unit: UNIT_SECS.into(),
        reps: 3,
        median,
        mean: median * 1.01,
        stddev,
        min: median - stddev,
        max: median + 2.0 * stddev,
        work: Some(1_000_000),
    }
}

fn report(suite: &str, cases: Vec<CaseResult>) -> BenchReport {
    BenchReport {
        suite: suite.into(),
        git_sha: "cafef00d".into(),
        scale: 0.25,
        threads: 2,
        cases,
    }
}

#[test]
fn round_trip_is_byte_stable_across_suites() {
    let file = BenchFile {
        note: "two suites".into(),
        suites: vec![
            report(
                "table2_pagerank",
                vec![
                    timed("twitter-sim/optimized", 0.141, 0.002),
                    timed("twitter-sim/baseline", 0.397, 0.004),
                    CaseResult::single("twitter-sim/q", "q", 2.31),
                ],
            ),
            report("fig7_expansion", vec![CaseResult::single("rmat27-sim/original/k=8", "q", 3.7)]),
        ],
    };
    let encoded = file.to_json().unwrap();
    let parsed = BenchFile::parse(&encoded).unwrap();
    assert_eq!(parsed, file);
    assert_eq!(
        parsed.to_json().unwrap(),
        encoded,
        "encode→parse→encode must be byte-stable"
    );
}

#[test]
fn every_truncation_and_bitflip_errors_or_changes_meaning() {
    let encoded = BenchFile::single(report(
        "table3_cf",
        vec![timed("netflix-sim/optimized", 0.2, 0.01)],
    ))
    .to_json()
    .unwrap();
    // Truncations: never a silent partial parse.
    for cut in 0..encoded.len() - 1 {
        assert!(
            BenchFile::parse(&encoded[..cut]).is_err(),
            "accepted truncated report at byte {cut}"
        );
    }
    // Structural corruption: a few representative mutations.
    for (from, to) in [
        ("\"median\"", "\"mediam\""),
        ("\"suites\"", "\"suires\""),
        ("\"version\": 1", "\"version\": 2"),
        ("\"format\": \"cagra-bench\"", "\"format\": \"x\""),
        ("{", "["),
    ] {
        let bad = encoded.replacen(from, to, 1);
        assert!(BenchFile::parse(&bad).is_err(), "accepted corruption {from} -> {to}");
    }
}

#[test]
fn diff_flags_injected_slowdown_and_passes_jitter() {
    let baseline = BenchFile::single(report(
        "table2_pagerank",
        vec![
            timed("twitter-sim/optimized", 0.100, 0.002),
            timed("twitter-sim/baseline", 0.300, 0.002),
        ],
    ));
    // 2x slowdown on one case, +3% jitter on the other.
    let slow = BenchFile::single(report(
        "table2_pagerank",
        vec![
            timed("twitter-sim/optimized", 0.200, 0.002),
            timed("twitter-sim/baseline", 0.309, 0.002),
        ],
    ));
    let d = Diff::compare(&baseline, &slow, DiffOptions::default());
    assert!(d.is_regression());
    let failures = d.failures();
    assert_eq!(failures.len(), 1);
    assert_eq!(failures[0].name, "twitter-sim/optimized");
    assert_eq!(failures[0].verdict, Verdict::Regressed);

    // Pure jitter: both cases inside tolerance + noise.
    let jitter = BenchFile::single(report(
        "table2_pagerank",
        vec![
            timed("twitter-sim/optimized", 0.104, 0.002),
            timed("twitter-sim/baseline", 0.293, 0.002),
        ],
    ));
    assert!(!Diff::compare(&baseline, &jitter, DiffOptions::default()).is_regression());
}

#[test]
fn cli_diff_exit_codes_gate_regressions() {
    let dir = temp_dir("cli");
    let base_path = dir.join("base.json");
    let ok_path = dir.join("ok.json");
    let bad_path = dir.join("bad.json");
    let baseline = BenchFile::single(report(
        "table3_cf",
        vec![timed("netflix-sim/optimized", 0.100, 0.0)],
    ));
    let ok = BenchFile::single(report(
        "table3_cf",
        vec![timed("netflix-sim/optimized", 0.102, 0.0)],
    ));
    let bad = BenchFile::single(report(
        "table3_cf",
        vec![timed("netflix-sim/optimized", 0.250, 0.0)],
    ));
    std::fs::write(&base_path, baseline.to_json().unwrap()).unwrap();
    std::fs::write(&ok_path, ok.to_json().unwrap()).unwrap();
    std::fs::write(&bad_path, bad.to_json().unwrap()).unwrap();

    let exe = env!("CARGO_BIN_EXE_cagra");
    let run = |new: &PathBuf| {
        Command::new(exe)
            .args(["bench", "diff"])
            .arg(&base_path)
            .arg(new)
            .output()
            .expect("running cagra bench diff")
    };
    let good = run(&ok_path);
    assert!(
        good.status.success(),
        "within-noise diff must exit 0: {}",
        String::from_utf8_lossy(&good.stdout)
    );
    let regressed = run(&bad_path);
    assert_eq!(
        regressed.status.code(),
        Some(2),
        "regression must exit 2: {}",
        String::from_utf8_lossy(&regressed.stdout)
    );
    assert!(String::from_utf8_lossy(&regressed.stdout).contains("REGRESSED"));

    // A corrupt file is a hard error (exit 1), not a pass.
    std::fs::write(&bad_path, "{\"format\": \"cagra-bench\", \"versio").unwrap();
    let corrupt = run(&bad_path);
    assert_eq!(corrupt.status.code(), Some(1));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn write_report_and_directory_load_round_trip() {
    let dir = temp_dir("emit");
    // write_report emits under CAGRA_BENCH_OUT; emulate it with the same
    // filename convention without mutating process env (tests run in
    // parallel threads).
    let a = BenchFile::single(report("table2_pagerank", vec![timed("x/optimized", 0.1, 0.0)]));
    let b = BenchFile::single(report("table3_cf", vec![timed("y/optimized", 0.2, 0.0)]));
    std::fs::write(
        dir.join(cagra::bench::report::report_filename("table2_pagerank")),
        a.to_json().unwrap(),
    )
    .unwrap();
    std::fs::write(
        dir.join(cagra::bench::report::report_filename("table3_cf")),
        b.to_json().unwrap(),
    )
    .unwrap();
    // Unrelated files are ignored by the directory loader.
    std::fs::write(dir.join("notes.txt"), "not a report").unwrap();

    let merged = BenchFile::load_path(&dir).unwrap();
    assert_eq!(merged.suites.len(), 2);
    assert!(merged.suite("table2_pagerank").is_some());
    assert!(merged.suite("table3_cf").is_some());
    assert_eq!(merged.case_count(), 2);

    // Self-diff of a merged directory: everything Within, no failures.
    let d = Diff::compare(&merged, &merged, DiffOptions::default());
    assert!(!d.is_regression());
    assert!(d.deltas.iter().all(|c| c.verdict == Verdict::Within));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn empty_committed_baseline_bootstrap_passes() {
    // The committed rust/bench-baseline.json starts with zero suites so
    // the CI gate can run before real numbers exist: every smoke case
    // shows up as "new" and the diff passes.
    let committed = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench-baseline.json");
    let baseline = BenchFile::load(&committed).expect("committed baseline parses");
    let smoke = BenchFile::single(report(
        "table2_pagerank",
        vec![timed("twitter-sim/optimized", 0.1, 0.0)],
    ));
    let d = Diff::compare(&baseline, &smoke, DiffOptions::default());
    assert!(!d.is_regression());
    assert!(d.deltas.iter().all(|c| c.verdict == Verdict::New));
}
