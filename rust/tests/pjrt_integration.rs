//! Cross-layer integration: the L1/L2 AOT artifacts executed from the L3
//! runtime must agree with the native Rust engine on the same graph.
//!
//! Requires `make artifacts` (skips with a message when absent so plain
//! `cargo test` before the artifact build doesn't fail spuriously).

use cagra::coordinator::SystemConfig;
use cagra::graph::{generators, Csr, VertexId};
use cagra::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::from_env() {
        Ok(rt) if !rt.available().is_empty() => Some(rt),
        _ => {
            eprintln!("skipping PJRT integration test: no artifacts (run `make artifacts`)");
            None
        }
    }
}

/// Dense f32 adjacency a[v*n + u] = 1.0 iff edge u→v, plus inv out-degree.
fn densify(g: &Csr) -> (Vec<f32>, Vec<f32>) {
    let n = g.num_vertices();
    let mut a = vec![0.0f32; n * n];
    for (u, v) in g.edges() {
        a[v as usize * n + u as usize] = 1.0;
    }
    let inv: Vec<f32> = (0..n)
        .map(|u| {
            let d = g.degree(u as VertexId);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f32
            }
        })
        .collect();
    (a, inv)
}

#[test]
fn pjrt_pagerank_matches_native_engine() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.load("pagerank_step").expect("loading pagerank_step");
    let n = exe.meta.param_usize("n").unwrap();
    // Graph sized exactly to the artifact's static shape; CsrBuilder
    // dedups so the dense adjacency is 0/1.
    let (_, edges) = generators::rmat(n.trailing_zeros(), 8, generators::RmatParams::graph500(), 123);
    let mut b = cagra::graph::CsrBuilder::new(n);
    b.extend(edges);
    let g = b.build();
    let (a, inv) = densify(&g);
    let mut rank: Vec<f32> = vec![1.0 / n as f32; n];
    let iters = 5;
    for _ in 0..iters {
        let out = exe
            .run_f32(&[(&a, &[n, n]), (&rank, &[n]), (&inv, &[n])])
            .expect("executing pagerank_step");
        rank = out[0].clone();
    }
    // Native engine, f64, same damping (0.85 is baked into the artifact).
    let cfg = SystemConfig::default();
    let native = cagra::apps::pagerank::run(
        &g,
        &cfg,
        cagra::apps::pagerank::Variant::ReorderedSegmented,
        iters,
    );
    let mut max_rel = 0.0f64;
    for v in 0..n {
        let x = rank[v] as f64;
        let y = native.values[v];
        let rel = (x - y).abs() / y.abs().max(1e-9);
        max_rel = max_rel.max(rel);
    }
    assert!(
        max_rel < 1e-3,
        "PJRT vs native diverged: max rel err {max_rel}"
    );
}

#[test]
fn pjrt_cf_step_descends() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.load("cf_step").expect("loading cf_step");
    let nu = exe.meta.param_usize("nu").unwrap();
    let ni = exe.meta.param_usize("ni").unwrap();
    let k = exe.meta.param_usize("k").unwrap();
    let mut rng = cagra::util::rng::Rng::new(9);
    let mut u: Vec<f32> = (0..nu * k).map(|_| rng.next_f32() * 0.2).collect();
    let mut v: Vec<f32> = (0..ni * k).map(|_| rng.next_f32() * 0.2).collect();
    let mut r = vec![0.0f32; nu * ni];
    let mut mask = vec![0.0f32; nu * ni];
    for e in 0..nu * 4 {
        let uu = e % nu;
        let ii = rng.next_below(ni as u64) as usize;
        r[uu * ni + ii] = 1.0 + (rng.next_below(5)) as f32;
        mask[uu * ni + ii] = 1.0;
    }
    let mut sses = Vec::new();
    for _ in 0..8 {
        let out = exe
            .run_f32(&[
                (&u, &[nu, k]),
                (&v, &[ni, k]),
                (&r, &[nu, ni]),
                (&mask, &[nu, ni]),
            ])
            .expect("executing cf_step");
        u = out[0].clone();
        v = out[1].clone();
        sses.push(out[2][0]);
    }
    assert!(
        sses.last().unwrap() < sses.first().unwrap(),
        "loss did not descend: {sses:?}"
    );
    assert!(sses.iter().all(|s| s.is_finite()));
}

#[test]
fn artifact_metadata_consistent_with_execution() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let names: Vec<String> = rt.available().iter().map(|s| s.to_string()).collect();
    assert!(names.contains(&"pagerank_step".to_string()));
    assert!(names.contains(&"cf_step".to_string()));
    for name in names {
        let exe = rt.load(&name).unwrap();
        assert!(!exe.meta.inputs.is_empty(), "{name} missing input shapes");
        assert!(!exe.meta.outputs.is_empty(), "{name} missing output shapes");
    }
}
