//! Integration tests for `cagra audit` (DESIGN.md §7): the fixture
//! suite (each lint fires on its `.bad.txt` and stays quiet on its
//! `.good.txt`), the self-check (the real tree must be clean — this is
//! the same gate CI runs), and the CLI exit-code contract.
//!
//! Fixtures are `.txt` on purpose: the tree walker only collects `.rs`,
//! so the bad fixtures can carry real violations without tripping the
//! self-check below.

use cagra::audit::{self, lints};
use std::path::{Path, PathBuf};
use std::process::Command;

fn crate_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> (PathBuf, String) {
    let path = crate_dir().join("tests/audit_fixtures").join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {}: {e}", path.display()));
    (path, src)
}

/// Lints fired by a fixture, deduplicated in order.
fn lints_hit(name: &str) -> Vec<&'static str> {
    let (_, src) = fixture(name);
    let mut hit = Vec::new();
    for d in audit::audit_source(name, &src) {
        if !hit.contains(&d.lint) {
            hit.push(d.lint);
        }
    }
    hit
}

#[test]
fn each_bad_fixture_trips_exactly_its_lint() {
    let cases = [
        ("safety_comment.bad.txt", lints::SAFETY_COMMENT),
        ("pod_allowlist.bad.txt", lints::POD_ALLOWLIST),
        ("nan_sort.bad.txt", lints::NAN_SORT),
        ("hot_path_alloc.bad.txt", lints::HOT_PATH_ALLOC),
        ("hot_path_unclosed.bad.txt", lints::HOT_PATH_ALLOC),
        ("relaxed_store.bad.txt", lints::RELAXED_STORE),
        ("lock_unwrap.bad.txt", lints::LOCK_UNWRAP),
    ];
    for (name, lint) in cases {
        assert_eq!(lints_hit(name), vec![lint], "{name}");
    }
}

#[test]
fn each_good_fixture_is_clean() {
    for name in [
        "safety_comment.good.txt",
        "pod_allowlist.good.txt",
        "nan_sort.good.txt",
        "hot_path_alloc.good.txt",
        "relaxed_store.good.txt",
        "lock_unwrap.good.txt",
        "waiver.good.txt",
    ] {
        assert_eq!(lints_hit(name), Vec::<&str>::new(), "{name}");
    }
}

#[test]
fn bad_fixture_diagnostics_carry_position_and_prose() {
    let (_, src) = fixture("nan_sort.bad.txt");
    let ds = audit::audit_source("nan_sort.bad.txt", &src);
    assert_eq!(ds.len(), 1);
    assert_eq!(ds[0].line, 2, "the sort is on line 2");
    let rendered = ds[0].to_string();
    assert!(
        rendered.starts_with("nan_sort.bad.txt:2: [nan-sort]"),
        "diagnostic renders as file:line: [lint]: {rendered}"
    );
    assert!(!ds[0].message.is_empty());
}

/// The gate itself: the real tree must audit clean. Any regression —
/// a raw-pointer write without a SAFETY comment, an allocation sneaking
/// into a hot-path region, an unjustified relaxed store — fails this
/// test before it ever reaches CI.
#[test]
fn self_check_tree_is_clean() {
    let report = audit::audit_tree(crate_dir()).expect("tree walk");
    let findings: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        report.clean(),
        "the tree must be audit-clean; findings:\n{}",
        findings.join("\n")
    );
    // Sanity: the walk actually covered the crate and its audited
    // surface (the exact numbers grow with the repo; these are floors).
    assert!(report.files_scanned >= 40, "scanned {}", report.files_scanned);
    assert!(report.unsafe_sites >= 30, "audited {}", report.unsafe_sites);
}

#[test]
fn audit_paths_accepts_explicit_files_and_dirs() {
    let base = crate_dir();
    // Explicit non-.rs file: audited even though the walker skips it.
    let bad = base.join("tests/audit_fixtures/relaxed_store.bad.txt");
    let report = audit::audit_paths(base, &[bad]).expect("audit file");
    assert_eq!(report.files_scanned, 1);
    assert!(!report.clean());
    assert_eq!(report.diagnostics[0].lint, lints::RELAXED_STORE);
    // Display path is base-relative.
    assert_eq!(
        report.diagnostics[0].file,
        "tests/audit_fixtures/relaxed_store.bad.txt"
    );
    // A directory audits its .rs files (fixtures are .txt — skipped).
    let report = audit::audit_paths(base, &[base.join("src/audit")]).expect("audit dir");
    assert!(report.files_scanned >= 3);
    assert!(report.clean(), "{:?}", report.diagnostics);
    // A missing path is an error, not silence.
    assert!(audit::audit_paths(base, &[base.join("src/nonexistent")]).is_err());
}

#[test]
fn cli_exit_codes_and_fix_list() {
    let bin = env!("CARGO_BIN_EXE_cagra");
    // Clean tree: exit 0, summary line.
    let out = Command::new(bin)
        .arg("audit")
        .current_dir(crate_dir())
        .output()
        .expect("run cagra audit");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "clean tree must exit 0; stdout:\n{stdout}"
    );
    assert!(stdout.contains("audit OK"), "{stdout}");

    // A bad fixture: nonzero exit, file:line diagnostic on stdout.
    let out = Command::new(bin)
        .args(["audit", "tests/audit_fixtures/nan_sort.bad.txt"])
        .current_dir(crate_dir())
        .output()
        .expect("run cagra audit <file>");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success(), "violations must exit nonzero");
    assert!(stdout.contains("nan_sort.bad.txt:2"), "{stdout}");
    assert!(stdout.contains("audit FAILED"), "{stdout}");

    // --fix-list: terse file:line:lint lines only.
    let out = Command::new(bin)
        .args(["audit", "--fix-list", "tests/audit_fixtures/nan_sort.bad.txt"])
        .current_dir(crate_dir())
        .output()
        .expect("run cagra audit --fix-list");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success());
    assert_eq!(
        stdout.trim(),
        "tests/audit_fixtures/nan_sort.bad.txt:2:nan-sort"
    );
}
