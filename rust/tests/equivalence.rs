//! Golden equivalence suite: every optimized path must produce the same
//! answer as the naive reference on the same input — across apps,
//! orderings, segment sizes, and baseline frameworks — and the dyn
//! `GraphApp` pipeline must agree with the typed per-app paths it wraps.

use cagra::apps::{bc, bfs, cc, pagerank, pagerank_delta, registry, sssp, triangle};
use cagra::apps::{AppKind, PreparedApp};
use cagra::baselines::{graphmat_style, gridgraph_style, hilbert, ligra_style, xstream_style};
use cagra::coordinator::SystemConfig;
use cagra::graph::{generators, Csr};
use cagra::reorder;
use cagra::store::StoreCtx;

/// Prepare an app variant through the registry, exactly as `run_job`
/// does (no artifact store).
fn registry_prepare(
    app: &str,
    variant: &str,
    g: &Csr,
    cfg: &SystemConfig,
) -> Box<dyn PreparedApp> {
    let kind = AppKind::parse(app, variant).unwrap();
    registry::app_for(kind)
        .prepare(g, cfg, kind, &StoreCtx::disabled())
        .unwrap()
}

fn graph(seed: u64) -> Csr {
    let (n, e) = generators::rmat(11, 8, generators::RmatParams::graph500(), seed);
    Csr::from_edges(n, &e)
}

fn assert_close(tag: &str, a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * y.abs().max(1e-12),
            "{tag} idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn every_pagerank_implementation_agrees() {
    let g = graph(1001);
    let cfg = SystemConfig {
        llc_bytes: 64 * 1024,
        ..Default::default()
    };
    let iters = 4;
    let want = pagerank::reference(&g, cfg.damping, iters);
    // Our four variants.
    for &v in pagerank::Variant::all() {
        let got = pagerank::run(&g, &cfg, v, iters);
        assert_close(v.name(), &got.values, &want, 1e-9);
    }
    // All five baseline frameworks.
    assert_close(
        "ligra-style",
        &ligra_style::Prepared::new(&g, &cfg).run(iters),
        &want,
        1e-9,
    );
    assert_close(
        "graphmat-style",
        &graphmat_style::Prepared::new(&g, &cfg).run(iters),
        &want,
        1e-9,
    );
    assert_close(
        "gridgraph-style",
        &gridgraph_style::Prepared::new(&g, &cfg).run(iters),
        &want,
        1e-9,
    );
    assert_close(
        "xstream-style",
        &xstream_style::Prepared::new(&g, &cfg).run(iters),
        &want,
        1e-9,
    );
    for mode in [hilbert::Mode::HSerial, hilbert::Mode::HAtomic, hilbert::Mode::HMerge] {
        assert_close(
            mode.name(),
            &hilbert::Prepared::new(&g, &cfg, mode).run(iters),
            &want,
            1e-9,
        );
    }
}

#[test]
fn pagerank_invariant_under_any_ordering() {
    // Relabeling the graph then mapping ranks back must be a no-op.
    let g = graph(1002);
    let cfg = SystemConfig::default();
    let want = pagerank::run(&g, &cfg, pagerank::Variant::Baseline, 3).values;
    for &o in reorder::Ordering::all() {
        let (h, perm) = reorder::reorder(&g, o);
        let ranks_new_space = pagerank::run(&h, &cfg, pagerank::Variant::Baseline, 3).values;
        let back = reorder::unpermute(&ranks_new_space, &perm);
        assert_close(o.name(), &back, &want, 1e-9);
    }
}

#[test]
fn pagerank_invariant_under_segment_size() {
    let g = graph(1003);
    let mut cfg = SystemConfig::default();
    let want = pagerank::reference(&g, cfg.damping, 3);
    for llc in [2 * 1024, 16 * 1024, 256 * 1024, 64 * 1024 * 1024] {
        cfg.llc_bytes = llc;
        let got = pagerank::run(&g, &cfg, pagerank::Variant::Segmented, 3);
        assert_close(&format!("llc={llc}"), &got.values, &want, 1e-9);
    }
}

#[test]
fn bfs_and_bc_and_sssp_agree_with_references() {
    let g = graph(1004);
    let src = bc::default_sources(&g, 1)[0];
    // BFS levels.
    let want_levels = bfs::reference_levels(&g, src);
    for &v in bfs::Variant::all() {
        let mut p = bfs::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
        let parents = p.run(src);
        let got = bfs::levels_from_parents(&g, src, &parents);
        assert_eq!(got, want_levels, "bfs {}", v.name());
    }
    // BC.
    let sources = bc::default_sources(&g, 3);
    let want_bc = bc::reference(&g, &sources);
    let got_bc = bc::Prepared::prepare(
        &g,
        &SystemConfig::default(),
        bc::Variant::ReorderedBitvector,
        &StoreCtx::disabled(),
    )
    .run(&sources);
    assert_close("bc", &got_bc, &want_bc, 1e-7);
    // SSSP.
    let want_d = sssp::reference(&g, src);
    let got_d =
        sssp::Prepared::prepare(&g, &SystemConfig::default(), sssp::Variant::Reordered, &StoreCtx::disabled())
            .run(src);
    for (i, (a, b)) in got_d.iter().zip(&want_d).enumerate() {
        assert!(
            (a == b) || (a.is_infinite() && b.is_infinite()),
            "sssp v={i}: {a} vs {b}"
        );
    }
}

#[test]
fn registry_pipeline_matches_typed_paths() {
    // The dyn GraphApp surface is a refactor, not a reimplementation:
    // driving each app through prepare()/step()/run_source() must land on
    // the same numbers as the typed per-app entry points.
    let g = graph(1006);
    let cfg = SystemConfig {
        llc_bytes: 64 * 1024,
        ..Default::default()
    };
    // PageRank (all variants, including the lower bound): bitwise.
    let mut pr_variants = pagerank::Variant::all().to_vec();
    pr_variants.push(pagerank::Variant::NoRandomLowerBound);
    for &v in &pr_variants {
        let mut dyn_prep = registry_prepare("pagerank", v.name(), &g, &cfg);
        for _ in 0..4 {
            dyn_prep.step();
        }
        let typed: f64 = pagerank::run(&g, &cfg, v, 4).values.iter().sum();
        assert_eq!(
            dyn_prep.summary().to_bits(),
            typed.to_bits(),
            "pagerank/{}",
            v.name()
        );
    }
    // PageRank-Delta: bitwise against the convenience runner at the same
    // epsilon (extra steps past convergence are no-ops).
    {
        let mut dyn_prep = registry_prepare("pagerank-delta", "baseline", &g, &cfg);
        for _ in 0..30 {
            dyn_prep.step();
        }
        let typed: f64 = pagerank_delta::run(&g, &cfg, cfg.delta_epsilon, 30)
            .values
            .iter()
            .sum();
        assert_eq!(dyn_prep.summary().to_bits(), typed.to_bits(), "pagerank-delta");
    }
    // BFS: reached count over sources.
    let sources = bc::default_sources(&g, 3);
    for &v in bfs::Variant::all() {
        let mut dyn_prep = registry_prepare("bfs", v.name(), &g, &cfg);
        let mut prep = bfs::Prepared::prepare(&g, &cfg, v, &StoreCtx::disabled());
        let mut reached = 0usize;
        for &s in &sources {
            dyn_prep.run_source(s);
            reached += prep.run(s).iter().filter(|&&p| p != u32::MAX).count();
        }
        assert_eq!(dyn_prep.summary(), reached as f64, "bfs/{}", v.name());
    }
    // BC: max centrality (atomics reassociate floats; compare with
    // tolerance).
    for &v in bc::Variant::all() {
        let mut dyn_prep = registry_prepare("bc", v.name(), &g, &cfg);
        for &s in &sources {
            dyn_prep.run_source(s);
        }
        let typed = bc::Prepared::prepare(&g, &cfg, v, &StoreCtx::disabled())
            .run(&sources)
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        let got = dyn_prep.summary();
        assert!(
            (got - typed).abs() <= 1e-7 * typed.abs().max(1.0),
            "bc/{}: {got} vs {typed}",
            v.name()
        );
    }
    // SSSP: finite-distance mass (Bellman-Ford distances are unique).
    for &v in sssp::Variant::all() {
        let mut dyn_prep = registry_prepare("sssp", v.name(), &g, &cfg);
        let mut prep = sssp::Prepared::prepare(&g, &cfg, v, &StoreCtx::disabled());
        let mut total = 0.0;
        for &s in &sources {
            dyn_prep.run_source(s);
            total += prep.run(s).iter().filter(|d| d.is_finite()).sum::<f64>();
        }
        assert_eq!(dyn_prep.summary(), total, "sssp/{}", v.name());
    }
    // CC: component count at the fixpoint.
    let want_components = {
        let labels = cc::reference(&g);
        labels
            .iter()
            .enumerate()
            .filter(|&(v, &l)| l as usize == v)
            .count() as f64
    };
    for &v in cc::Variant::all() {
        let mut dyn_prep = registry_prepare("cc", v.name(), &g, &cfg);
        for _ in 0..g.num_vertices() {
            dyn_prep.step();
        }
        assert_eq!(dyn_prep.summary(), want_components, "cc/{}", v.name());
    }
    // Triangle counting: exact count, available immediately (one-shot).
    {
        let dyn_prep = registry_prepare("triangle", "degree-ordered", &g, &cfg);
        assert_eq!(dyn_prep.summary(), triangle::count(&g) as f64);
    }
}

#[test]
fn deterministic_across_runs() {
    // Same seed => byte-identical results (PRNG + parallel schedule must
    // not leak nondeterminism into *values*).
    let g = graph(1005);
    let cfg = SystemConfig::default();
    let a = pagerank::run(&g, &cfg, pagerank::Variant::ReorderedSegmented, 5).values;
    let b = pagerank::run(&g, &cfg, pagerank::Variant::ReorderedSegmented, 5).values;
    assert_eq!(a, b);
}
