//! End-to-end `cagra serve` coverage: the TCP daemon speaks the NDJSON
//! protocol (round trip + malformed rejection + graceful shutdown), N
//! concurrent clients get **bitwise** the answers a sequential `run_job`
//! produces (shared immutable artifacts, per-job owned scratch), and the
//! resident layer evicts to its byte budget without ever serving a wrong
//! value.

use cagra::coordinator::{run_job, AppKind, JobSpec, SystemConfig};
use cagra::serve::{serve, Outcome, ServeOpts, WorkerPool};
use cagra::store::Artifact;
use cagra::util::json::{parse, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cagra-serve-{tag}-{}", std::process::id()))
}

const SCALE: f64 = 1.0 / 64.0;

fn small_spec() -> JobSpec {
    JobSpec {
        dataset: "livejournal-sim".into(),
        scale: SCALE,
        iters: 2,
        ..Default::default()
    }
}

/// Send one line, read one reply line.
fn roundtrip(writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Value {
    writer
        .write_all(format!("{line}\n").as_bytes())
        .and_then(|()| writer.flush())
        .expect("send");
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("recv");
    parse(reply.trim()).unwrap_or_else(|e| panic!("unparseable reply {reply:?}: {e:#}"))
}

#[test]
fn tcp_daemon_round_trips_rejects_malformed_and_drains() {
    let port_file = temp_path("port");
    std::fs::remove_file(&port_file).ok();
    let opts = ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_cap: 8,
        mem_budget: 0,
        port_file: Some(port_file.display().to_string()),
        stdio: false,
        ..ServeOpts::default()
    };
    let daemon = std::thread::spawn(move || serve(SystemConfig::default(), &opts));
    // Port 0: discover the bound address through the port file.
    let deadline = Instant::now() + Duration::from_secs(30);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                break s;
            }
        }
        assert!(Instant::now() < deadline, "daemon never wrote the port file");
        std::thread::sleep(Duration::from_millis(10));
    };
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // Control plane round trip with id echo.
    let pong = roundtrip(&mut writer, &mut reader, r#"{"op":"ping","id":"p1"}"#);
    assert_eq!(pong.get("ok"), Some(&Value::Bool(true)));
    assert_eq!(pong.get("id").and_then(Value::as_str), Some("p1"));

    // Malformed lines are rejected per-request; the connection survives.
    for bad in [
        "not json at all",
        r#"{"op":"fly"}"#,
        r#"{"op":"run","app":"pagerank","color":"red"}"#,
        r#"{"op":"run","app":"nope"}"#,
    ] {
        let v = roundtrip(&mut writer, &mut reader, bad);
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "accepted {bad:?}");
        assert_eq!(
            v.get("error").and_then(Value::as_str),
            Some("bad_request"),
            "wrong kind for {bad:?}"
        );
    }

    // A real job: response matches the in-process pipeline bitwise.
    let expected = run_job(&small_spec(), &SystemConfig::default())
        .expect("reference job")
        .summary;
    let run = roundtrip(
        &mut writer,
        &mut reader,
        &format!(
            r#"{{"op":"run","id":7,"app":"pagerank","graph":"livejournal-sim","scale":{SCALE},"iters":2}}"#
        ),
    );
    assert_eq!(run.get("ok"), Some(&Value::Bool(true)), "run failed: {run:?}");
    assert_eq!(run.get("id").and_then(Value::as_u64), Some(7));
    let got = run.get("summary").and_then(Value::as_f64).expect("summary");
    assert_eq!(got.to_bits(), expected.to_bits(), "served summary differs");

    // A job-level error (bad knob) is a `failed` response, not a hangup.
    let v = roundtrip(
        &mut writer,
        &mut reader,
        r#"{"op":"run","app":"cf","cf_k":65}"#,
    );
    assert_eq!(v.get("error").and_then(Value::as_str), Some("failed"));

    let stats = roundtrip(&mut writer, &mut reader, r#"{"op":"stats"}"#);
    assert!(stats.get("jobs_done").and_then(Value::as_u64).unwrap() >= 1);
    assert!(stats.get("mem").is_some());

    // Graceful shutdown: acknowledged, then the daemon drains and exits.
    let ack = roundtrip(&mut writer, &mut reader, r#"{"op":"shutdown","id":9}"#);
    assert_eq!(ack.get("ok"), Some(&Value::Bool(true)));
    daemon
        .join()
        .expect("daemon thread panicked")
        .expect("daemon errored");
    std::fs::remove_file(&port_file).ok();
}

#[test]
fn concurrent_clients_match_sequential_bitwise() {
    let store_dir = temp_path("bitwise-store");
    std::fs::remove_dir_all(&store_dir).ok();
    let specs: Vec<JobSpec> = vec![
        JobSpec {
            iters: 3,
            ..small_spec()
        },
        JobSpec {
            app: AppKind::parse("cc", "segmenting").unwrap(),
            iters: 4,
            ..small_spec()
        },
        JobSpec {
            app: AppKind::parse("bfs", "both").unwrap(),
            num_sources: 2,
            ..small_spec()
        },
    ];
    // Reference: each job sequentially, cold, no shared state at all.
    let cfg = SystemConfig::default();
    let expected: Vec<u64> = specs
        .iter()
        .map(|s| run_job(s, &cfg).expect("sequential run").summary.to_bits())
        .collect();
    // Serve the same jobs from N concurrent clients over one pool that
    // shares *everything* shareable (dataset, disk store, decoded
    // artifacts). Scratch is per-job; any aliasing would corrupt results.
    let serve_cfg = SystemConfig {
        store_enabled: true,
        store_dir: store_dir.display().to_string(),
        ..SystemConfig::default()
    };
    let pool = WorkerPool::start(serve_cfg, 4, 64, 0).expect("pool");
    let replicas = 3;
    let receivers: Vec<(usize, _)> = (0..replicas)
        .flat_map(|_| specs.iter().enumerate())
        .map(|(i, s)| (i, pool.submit(s.clone(), None).expect("admitted")))
        .collect();
    for (i, rx) in receivers {
        let Outcome::Done { result, .. } = rx.recv().expect("outcome") else {
            panic!("job {i} did not complete");
        };
        let got = result.expect("served job").summary.to_bits();
        assert_eq!(
            got, expected[i],
            "spec {i}: concurrent resident result differs from sequential"
        );
    }
    let mem = pool.mem_stats();
    assert!(mem.hits > 0, "replicas must hit the resident layer: {mem:?}");
    pool.shutdown();
    std::fs::remove_dir_all(&store_dir).ok();
}

#[test]
fn resident_layer_evicts_to_budget_and_stays_correct() {
    // Budget sized to hold either dataset but never both: alternating
    // datasets forces evictions while answers must stay correct.
    let cfg = SystemConfig::default();
    let a = small_spec();
    let b = JobSpec {
        dataset: "twitter-sim".into(),
        ..small_spec()
    };
    let bytes_of = |spec: &JobSpec| {
        let ds = cagra::graph::datasets::load_scaled(&spec.dataset, spec.scale).unwrap();
        ds.graph.mem_bytes() + ds.name.len() as u64
    };
    let budget = bytes_of(&a).max(bytes_of(&b)) + 512;
    let expect_a = run_job(&a, &cfg).unwrap().summary.to_bits();
    let expect_b = run_job(&b, &cfg).unwrap().summary.to_bits();

    let pool = WorkerPool::start(cfg, 1, 8, budget).expect("pool");
    let run = |spec: &JobSpec| {
        let Outcome::Done { result, .. } = pool.run_sync(spec.clone(), None).unwrap() else {
            panic!("job incomplete");
        };
        result.unwrap().summary.to_bits()
    };
    assert_eq!(run(&a), expect_a); // miss: A resident
    assert_eq!(run(&a), expect_a); // hit
    assert_eq!(run(&b), expect_b); // miss: B evicts A
    assert_eq!(run(&a), expect_a); // miss again: A evicts B
    let mem = pool.mem_stats();
    assert!(mem.hits >= 1, "repeat request must hit: {mem:?}");
    assert!(mem.misses >= 3, "alternation must rebuild: {mem:?}");
    assert!(mem.evictions >= 2, "budget must force evictions: {mem:?}");
    assert!(
        mem.resident_bytes <= budget,
        "resident {} exceeds budget {budget}",
        mem.resident_bytes
    );
    pool.shutdown();
}
