//! Concurrent same-process `ArtifactStore` access: the store is shared by
//! `cagra serve` workers, so two threads racing on one key must build
//! once (the per-key lock), and readers racing the evictor must never
//! observe a torn or wrong value — only a hit with correct bytes or a
//! clean rebuild. Plain threads, no loom: the store's critical sections
//! are coarse (one mutex per key), so exhaustive interleaving isn't
//! needed to exercise the races that matter.

use cagra::store::{ArcSlice, ArtifactStore, StoreKey};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cagra-stress-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn perm(n: usize, rot: usize) -> Vec<u32> {
    (0..n).map(|i| ((i + rot) % n) as u32).collect()
}

#[test]
fn concurrent_same_key_builds_once() {
    let dir = temp_dir("once");
    let store = Arc::new(ArtifactStore::open(&dir, 0).unwrap());
    let builds = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(4));
    let key = StoreKey::ordering(0xfeed, "stress-once");
    let expected = perm(512, 7);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let (store, builds, barrier) = (store.clone(), builds.clone(), barrier.clone());
            let (key, expected) = (key.clone(), expected.clone());
            std::thread::spawn(move || {
                barrier.wait();
                let got: ArcSlice<u32> = store.get_or_build(&key, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    // Widen the window: losers must be blocking on the key
                    // lock, not merely losing a fast race.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    ArcSlice::from_vec(expected.clone())
                });
                assert_eq!(got, expected);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        builds.load(Ordering::SeqCst),
        1,
        "same-key misses must serialize into one build"
    );
    let s = store.stats();
    assert_eq!(s.misses, 1);
    assert_eq!(s.hits, 3);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reads_survive_concurrent_eviction() {
    let dir = temp_dir("evict");
    // Cap below two 512-entry permutations (~2 KiB each + frame): every
    // write of one key evicts the other, so readers constantly race the
    // evictor's unlink.
    let store = Arc::new(ArtifactStore::open(&dir, 3000).unwrap());
    let keys = [
        StoreKey::ordering(0xbeef, "stress-a"),
        StoreKey::ordering(0xbeef, "stress-b"),
    ];
    let handles: Vec<_> = (0..2)
        .map(|t| {
            let store = store.clone();
            let keys = keys.clone();
            std::thread::spawn(move || {
                for i in 0..60 {
                    let which = (i + t) % 2;
                    let key = &keys[which];
                    let expected = perm(512, which);
                    // A dropped scope leaves the write evictable, unlike
                    // the never-dropped instance scope.
                    let scope = store.begin_scope();
                    let got: ArcSlice<u32> = store
                        .get_or_build_scoped(key, scope.id(), || ArcSlice::from_vec(expected.clone()));
                    drop(scope);
                    assert_eq!(got, expected, "thread {t} iter {i}: wrong or torn value");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let s = store.stats();
    assert!(s.evictions > 0, "cap was sized to force evictions: {s:?}");
    std::fs::remove_dir_all(&dir).ok();
}
