//! Counting-allocator proof of the zero-allocation steady state: after
//! the first execution unit (iteration / source traversal), `step()` and
//! `run_source()` for every engine-driven app perform **zero** heap
//! allocation. A leak here means a hot loop is churning pages — exactly
//! what the cache-residency design works to avoid.
//!
//! Runs single-threaded (`CAGRA_THREADS=1`, set before the global pool
//! initializes): the multi-thread scheduler's shared work queue is
//! intentionally outside the guarantee, and one thread makes the count
//! deterministic. This file holds exactly one test so no other test can
//! race the env var or pollute the counter.

use cagra::apps::app::{default_sources, ExecutionShape};
use cagra::apps::{registry, AppKind, PreparedApp};
use cagra::coordinator::SystemConfig;
use cagra::graph::{generators, Csr};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: pure pass-through to System plus a relaxed counter bump — the
// System allocator's own contract is what callers observe.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same contract as GlobalAlloc::alloc — forwarded verbatim.
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded verbatim; caller upholds GlobalAlloc's contract.
        unsafe { System.alloc(l) }
    }
    // SAFETY: same contract as GlobalAlloc::alloc_zeroed — forwarded verbatim.
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded verbatim; caller upholds GlobalAlloc's contract.
        unsafe { System.alloc_zeroed(l) }
    }
    // SAFETY: same contract as GlobalAlloc::realloc — forwarded verbatim.
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded verbatim; caller upholds GlobalAlloc's contract.
        unsafe { System.realloc(p, l, new_size) }
    }
    // SAFETY: same contract as GlobalAlloc::dealloc — forwarded verbatim.
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        // SAFETY: forwarded verbatim; caller upholds GlobalAlloc's contract.
        unsafe { System.dealloc(p, l) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_performs_zero_heap_allocation() {
    // Must precede the first touch of the global worker pool.
    std::env::set_var("CAGRA_THREADS", "1");
    let (n, e) = generators::rmat(11, 8, generators::RmatParams::graph500(), 4242);
    let g = Csr::from_edges(n, &e);
    // Several segments for CC's segmented path.
    let cfg = SystemConfig {
        llc_bytes: 64 * 1024,
        ..Default::default()
    };
    let cases: &[(&str, &str)] = &[
        ("bfs", "baseline"),
        ("bfs", "both"),
        ("sssp", "baseline"),
        ("sssp", "reordering"),
        ("bc", "baseline"),
        ("bc", "both"),
        ("cc", "baseline"),
        ("cc", "segmenting"),
        ("pagerank-delta", "baseline"),
        // Not in the tentpole's five, but its step loop shares the same
        // discipline — gate it too.
        ("pagerank", "both"),
    ];
    for &(app, variant) in cases {
        let kind = AppKind::parse(app, variant).unwrap();
        let mut prep = registry::app_for(kind)
            .prepare(&g, &cfg, kind, &cagra::store::StoreCtx::disabled())
            .unwrap();
        match prep.shape() {
            ExecutionShape::Iterative => {
                // Warm: the first iterations size every pool/capacity.
                prep.step();
                prep.step();
                let before = allocations();
                for _ in 0..3 {
                    prep.step();
                }
                let leaked = allocations() - before;
                assert_eq!(leaked, 0, "{app}/{variant}: {leaked} steady-state step() allocations");
            }
            ExecutionShape::PerSource => {
                let src = default_sources(&g, 1)[0];
                // Warm with the same source the measurement uses: the
                // traversal shape (and so every pooled capacity) is then
                // identical in the measured window.
                prep.run_source(src);
                prep.run_source(src);
                let before = allocations();
                prep.run_source(src);
                let leaked = allocations() - before;
                assert_eq!(
                    leaked, 0,
                    "{app}/{variant}: {leaked} allocations in steady-state run_source()"
                );
            }
            ExecutionShape::OneShot => unreachable!("no one-shot apps in this list"),
        }
        assert!(
            prep.scratch_bytes() > 0,
            "{app}/{variant}: scratch_bytes should report the reusable footprint"
        );
    }
    // The serve worker's warm path: prepare through a disk store + the
    // in-memory artifact layer twice. The second prepare must be fully
    // resident — memory-layer hits, ZERO bytes decoded from disk — and
    // its steady-state step loop must still allocate nothing (the
    // resident Arc'd artifacts feed the same pooled engine scratch).
    {
        use cagra::store::{fingerprint, ArtifactStore, MemStore, StoreCtx};
        let dir = std::env::temp_dir().join(format!("cagra-zeroalloc-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = ArtifactStore::open(&dir, 0).unwrap();
        let mem = MemStore::new(0);
        let fp = fingerprint::fingerprint_dataset("zero-alloc-rmat", 1.0, &g);
        let kind = AppKind::parse("pagerank", "both").unwrap();
        let prepare = || {
            let ctx = StoreCtx::new(&store, fp).with_mem(&mem);
            registry::app_for(kind).prepare(&g, &cfg, kind, &ctx).unwrap()
        };
        drop(prepare()); // cold: builds + persists + pins
        let read_before = store.stats().bytes_read;
        let mut prep = prepare(); // warm: resident
        let m = mem.stats();
        assert!(m.hits > 0, "warm prepare must hit the resident layer: {m:?}");
        assert_eq!(
            store.stats().bytes_read - read_before,
            0,
            "warm resident prepare must decode zero bytes from disk"
        );
        prep.step();
        prep.step();
        let before = allocations();
        for _ in 0..3 {
            prep.step();
        }
        let leaked = allocations() - before;
        assert_eq!(leaked, 0, "resident serve path: {leaked} steady-state step() allocations");
        std::fs::remove_dir_all(&dir).ok();
    }
    // The engine hot paths above are instrumented with recorder spans;
    // with the recorder disabled (this process never enables it) they
    // must cost one relaxed load each — in particular, record *nothing*.
    // Combined with the zero-allocation assertions over the same loops,
    // this pins the disabled recorder's cost at effectively zero.
    assert!(!cagra::obs::recorder::enabled());
    let (events, dropped) = cagra::obs::recorder::drain();
    assert!(
        events.is_empty() && dropped == 0,
        "disabled recorder captured {} events ({dropped} dropped)",
        events.len()
    );
    // Same deal for failpoints: this process never arms any, so every
    // site the hot paths above crossed (store write/read/map, mem
    // insert/evict) must have cost one relaxed load — never a trigger,
    // never an allocation (the loops above already proved the latter).
    assert!(!cagra::fault::enabled(), "failpoints armed in a fault-free process");
    assert!(
        cagra::fault::snapshot().is_empty(),
        "disarmed failpoints recorded triggers: {:?}",
        cagra::fault::snapshot()
    );
}
