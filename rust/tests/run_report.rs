//! End-to-end `cagra-run` report check: a real (small) job, recorder
//! enabled, must produce a schema-valid report whose timeline contains
//! the engine's instrumentation — and the parser must reject truncated
//! or corrupted inputs rather than misread them.
//!
//! Lives in its own integration binary because the recorder's enable
//! flag is process-global: lib unit tests must never observe it
//! toggling underneath them.

use cagra::coordinator::{run_job, JobSpec, SystemConfig};
use cagra::obs::{recorder, RunReport};

fn small_job() -> (JobSpec, SystemConfig) {
    let spec = JobSpec {
        dataset: "livejournal-sim".into(),
        scale: 1.0 / 64.0,
        iters: 2,
        analyze_memory: true,
        ..Default::default()
    };
    (spec, SystemConfig::default())
}

#[test]
fn traced_job_round_trips_and_rejects_corruption() {
    let (spec, cfg) = small_job();
    recorder::enable();
    let result = run_job(&spec, &cfg).unwrap();
    let report = RunReport::from_job(&spec, &result);
    recorder::disable();

    // The default PageRank variant runs the segmented engine, so the
    // timeline must show the whole pipeline, not just phase markers.
    assert_eq!(report.events_dropped, 0, "tiny job overflowed the ring?");
    for kind in ["phase", "iter", "segment", "merge"] {
        assert!(
            report.events.iter().any(|e| e.kind == kind),
            "no {kind:?} event in {} recorded",
            report.events.len()
        );
    }
    assert_eq!(
        report.events.iter().filter(|e| e.kind == "iter").count(),
        spec.iters,
        "one iter span per execution unit"
    );
    assert_eq!(report.stall_source(), "simulated");
    assert!(report.simulated.is_some() && report.pmu.is_none());
    assert!(report.phases.iter().any(|p| p.name == "preprocess"));

    // Byte-stable round trip, like the bench report format.
    let json = report.to_json().unwrap();
    let back = RunReport::parse(&json).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.to_json().unwrap(), json);

    // Truncations anywhere must error, never silently misparse. Strides
    // keep the loop bounded; the tail bytes are checked exhaustively.
    // (Stopping before the closing `}`: the encoding ends "}\n", so the
    // only valid prefixes are the full text and the text minus its
    // trailing newline.)
    let end = json.len() - 1;
    for cut in (1..end).step_by(101).chain(end - 8..end) {
        assert!(
            RunReport::parse(&json[..cut]).is_err(),
            "truncation at byte {cut}/{} parsed",
            json.len()
        );
    }

    // Corruptions: wrong format tag, future version, and a stall-source
    // tag that contradicts the report's contents.
    assert!(RunReport::parse(&json.replace("cagra-run", "bogus-run")).is_err());
    assert!(RunReport::parse(&json.replace("\"version\": 1", "\"version\": 99")).is_err());
    let lied = json.replace("\"stall_source\": \"simulated\"", "\"stall_source\": \"pmu\"");
    assert_ne!(lied, json, "corruption target missing from encoding");
    assert!(RunReport::parse(&lied).is_err(), "inconsistent stall source parsed");
}
