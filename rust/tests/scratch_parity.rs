//! Scratch-poisoning parity: fill every *reused* buffer with garbage
//! between execution units and assert results are bitwise identical to a
//! fresh-allocation run, for the frontier apps (BFS, BC, SSSP) plus CC
//! and PageRank-Delta. Buffer reuse can therefore never leak stale
//! state silently: dead regions are proven irrelevant by the garbage,
//! and the engine's all-clear invariants are *asserted* (not repaired)
//! inside `EngineScratch::poison`, so a missed touched-only clear fails
//! the test loudly.

use cagra::apps::{bc, bfs, cc, pagerank_delta, sssp};
use cagra::coordinator::SystemConfig;
use cagra::graph::{generators, Csr};
use cagra::store::StoreCtx;

fn graph() -> Csr {
    let (n, e) = generators::rmat(10, 8, generators::RmatParams::graph500(), 1717);
    Csr::from_edges(n, &e)
}

fn sources(g: &Csr, k: usize) -> Vec<u32> {
    cagra::apps::app::default_sources(g, k)
}

#[test]
fn bfs_poisoned_reuse_is_bitwise_identical() {
    let g = graph();
    let srcs = sources(&g, 3);
    for &v in bfs::Variant::all() {
        // Fresh instance per source = the no-reuse baseline.
        let fresh: Vec<Vec<u32>> = srcs
            .iter()
            .map(|&s| bfs::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled()).run(s))
            .collect();
        // One instance reused across sources, poisoned between each.
        let mut p = bfs::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
        for (k, &s) in srcs.iter().enumerate() {
            p.poison_scratch(0xA11C_E000 + k as u64);
            // Parent choice can race under parallelism, so compare the
            // derived levels (deterministic) bitwise.
            let got = bfs::levels_from_parents(&g, s, &p.run(s));
            let want = bfs::levels_from_parents(&g, s, &fresh[k]);
            assert_eq!(got, want, "bfs/{} source {s}", v.name());
        }
    }
}

#[test]
fn sssp_poisoned_reuse_is_bitwise_identical() {
    let g = graph();
    let srcs = sources(&g, 3);
    for &v in sssp::Variant::all() {
        let fresh: Vec<Vec<f64>> = srcs
            .iter()
            .map(|&s| sssp::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled()).run(s))
            .collect();
        let mut p = sssp::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
        for (k, &s) in srcs.iter().enumerate() {
            p.poison_scratch(0x5E55_0000 + k as u64);
            let got = p.run(s);
            let want = &fresh[k];
            assert_eq!(got.len(), want.len());
            for i in 0..got.len() {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "sssp/{} source {s} vertex {i}",
                    v.name()
                );
            }
        }
    }
}

#[test]
fn bc_poisoned_reuse_is_bitwise_identical() {
    let g = graph();
    let srcs = sources(&g, 3);
    for &v in bc::Variant::all() {
        // Fresh instance per source; scores for one source at a time.
        let fresh: Vec<Vec<f64>> = srcs
            .iter()
            .map(|&s| bc::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled()).run(&[s]))
            .collect();
        let mut p = bc::Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
        for (k, &s) in srcs.iter().enumerate() {
            p.poison_scratch(0xBC00 + k as u64);
            let got = p.run(&[s]);
            let want = &fresh[k];
            for i in 0..got.len() {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "bc/{} source {s} vertex {i}",
                    v.name()
                );
            }
        }
    }
}

#[test]
fn cc_poisoned_stepping_is_bitwise_identical() {
    let g = graph();
    let cfg = SystemConfig {
        llc_bytes: 32 * 1024, // force several segments
        ..Default::default()
    };
    for v in [cc::Variant::Baseline, cc::Variant::Segmented] {
        let mut fresh = cc::Prepared::prepare(&g, &cfg, v, &StoreCtx::disabled());
        let mut poisoned = cc::Prepared::prepare(&g, &cfg, v, &StoreCtx::disabled());
        for sweep in 0..12u64 {
            let a = fresh.sweep();
            poisoned.poison_scratch(0xCC00 + sweep);
            let b = poisoned.sweep();
            assert_eq!(a, b, "cc/{} changed-flag diverged at sweep {sweep}", v.name());
            assert_eq!(
                fresh.labels(),
                poisoned.labels(),
                "cc/{} labels diverged at sweep {sweep}",
                v.name()
            );
        }
    }
}

#[test]
fn pagerank_delta_poisoned_stepping_is_bitwise_identical() {
    let g = graph();
    let cfg = SystemConfig::default();
    let mut fresh = pagerank_delta::Prepared::new(&g, &cfg, 1e-6);
    let mut poisoned = pagerank_delta::Prepared::new(&g, &cfg, 1e-6);
    for step in 0..20u64 {
        fresh.step();
        poisoned.poison_scratch(0xDE17A + step);
        poisoned.step();
        let a = fresh.values();
        let b = poisoned.values();
        for i in 0..a.len() {
            assert_eq!(
                a[i].to_bits(),
                b[i].to_bits(),
                "pagerank-delta vertex {i} diverged at step {step}"
            );
        }
    }
}
