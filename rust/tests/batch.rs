//! `cagra batch` acceptance: a job list runs over ONE long-lived
//! artifact store — later jobs warm-hit earlier jobs' preprocessing —
//! and each job's eviction-exemption scope is released when it
//! completes, so a shared store can actually evict a finished job's
//! artifacts instead of exempting them forever.

use cagra::apps::pagerank;
use cagra::coordinator::{parse_batch, run_batch, AppKind, JobSpec, SystemConfig};

const SCALE: f64 = 1.0 / 64.0;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cagra-batchtest-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn store_cfg(dir: &std::path::Path, cap: u64) -> SystemConfig {
    SystemConfig {
        llc_bytes: 32 * 1024, // scaled graphs still segment
        store_enabled: true,
        store_dir: dir.to_string_lossy().into_owned(),
        store_cap_bytes: cap,
        ..Default::default()
    }
}

fn pr_job(dataset: &str) -> JobSpec {
    JobSpec {
        dataset: dataset.into(),
        scale: SCALE,
        iters: 3,
        app: AppKind::PageRank(pagerank::Variant::ReorderedSegmented),
        ..Default::default()
    }
}

#[test]
fn second_job_warm_hits_first_through_one_shared_store() {
    let dir = temp_dir("warm");
    let cfg = store_cfg(&dir, 0);
    let jobs = [pr_job("livejournal-sim"), pr_job("livejournal-sim")];
    let results = run_batch(&jobs, &cfg).unwrap();
    let s1 = results[0].metrics.store.expect("job 1 store stats");
    let s2 = results[1].metrics.store.expect("job 2 store stats");
    assert_eq!(s1.hits, 0, "job 1 is cold");
    assert!(s1.misses > 0, "job 1 builds artifacts");
    // One shared instance: counters accumulate across jobs. Had each job
    // opened its own store, job 2's snapshot would start from fresh
    // counters (misses == 0 regardless); instead it must still carry
    // job 1's misses and add exactly one hit per artifact job 1 built.
    assert_eq!(s2.misses, s1.misses, "job 2 must not rebuild anything");
    assert_eq!(s2.hits, s1.misses, "job 2 must warm-hit every artifact");
    assert_eq!(
        results[0].summary.to_bits(),
        results[1].summary.to_bits(),
        "warm summary must be bitwise identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exemption_scopes_are_released_as_each_job_completes() {
    // A 1-byte cap makes every artifact overshoot the cap. While a job
    // runs, its writes are exempt (no self-thrash); once it completes its
    // scope drops, so the NEXT job's writes must be able to evict them.
    // Under the old instance-scoped own_writes exemption, a shared store
    // could never evict anything this process wrote — the set only grew.
    let dir = temp_dir("evict");
    let cfg = store_cfg(&dir, 1);
    let jobs = [pr_job("livejournal-sim"), pr_job("rmat25-sim")];
    let results = run_batch(&jobs, &cfg).unwrap();
    let s1 = results[0].metrics.store.unwrap();
    let s2 = results[1].metrics.store.unwrap();
    assert_eq!(s1.evictions, 0, "a job must never evict its own live writes");
    assert!(
        s2.evictions >= s1.misses,
        "job 2 must evict completed job 1's artifacts ({} evictions, job 1 wrote {})",
        s2.evictions,
        s1.misses
    );
    // Only job 2's own (still-exempt at snapshot time... now released)
    // artifacts remain resident.
    assert_eq!(
        s2.entries,
        s2.misses - s1.misses,
        "exactly job 2's artifacts should remain"
    );
    for r in &results {
        assert!(r.summary.is_finite() && r.summary > 0.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parsed_batch_runs_end_to_end_with_per_job_overrides() {
    // The parse → run path the CLI uses, including a per-job
    // delta-epsilon override: a tighter threshold must not converge
    // earlier than a looser one (strictly more work per run).
    let dir = temp_dir("parse");
    let cfg = store_cfg(&dir, 0);
    let text = format!(
        "# batch file as `cagra batch` reads it\n\
         app=pagerank-delta graph=livejournal-sim iters=40 scale={SCALE} delta-epsilon=1e-1\n\
         app=pagerank-delta graph=livejournal-sim iters=40 scale={SCALE} delta-epsilon=1e-8\n"
    );
    let specs = parse_batch(&text).unwrap();
    assert_eq!(specs[0].delta_epsilon, Some(1e-1));
    assert_eq!(specs[1].delta_epsilon, Some(1e-8));
    let results = run_batch(&specs, &cfg).unwrap();
    // pagerank-delta does no cacheable preprocessing: no store stats, and
    // the shared store must not even be planted on disk.
    assert!(results.iter().all(|r| r.metrics.store.is_none()));
    assert!(!dir.exists(), "no store dir for a batch with nothing to cache");
    // The override must actually reach the app: the loose-epsilon job
    // freezes its frontier almost immediately, the tight one keeps
    // propagating rank mass, so their summaries must differ (and the
    // tight run can only accumulate more).
    assert!(
        results[1].summary > results[0].summary,
        "per-job delta-epsilon override had no effect: {} vs {}",
        results[0].summary,
        results[1].summary
    );
    std::fs::remove_dir_all(&dir).ok();
}
