//! Zero-copy warm-start acceptance (DESIGN.md §6): for every app, a
//! mapped warm run and a forced-decode warm run land on the same answer
//! as the cold run, the mapped warm run decodes **zero** bytes (its
//! artifacts are served in place from the mapping), and stale-version
//! files under current store names are regenerated, never misread.

use cagra::coordinator::{run_job, AppKind, JobSpec, SystemConfig};
use cagra::store::{ArcSlice, ArtifactStore, StoreKey};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cagra-mmaptest-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn mapped_and_decoded_warm_runs_match_cold_across_all_apps() {
    // All eight apps. Store-backed variants additionally prove the
    // zero-copy property; store-less apps (pagerank-delta, triangle)
    // still pin cross-run determinism under both mmap settings.
    let cases: &[(&str, &str, &str, f64, usize)] = &[
        ("pagerank", "both", "livejournal-sim", 1.0 / 64.0, 3),
        ("pagerank-delta", "baseline", "livejournal-sim", 1.0 / 64.0, 5),
        ("cf", "segmenting", "netflix-sim", 0.05, 2),
        ("bc", "both", "livejournal-sim", 1.0 / 64.0, 1),
        ("bfs", "both", "livejournal-sim", 1.0 / 64.0, 1),
        ("sssp", "reordering", "livejournal-sim", 1.0 / 64.0, 1),
        ("cc", "segmenting", "livejournal-sim", 1.0 / 64.0, 4),
        ("triangle", "degree-ordered", "livejournal-sim", 1.0 / 64.0, 1),
    ];
    for &(app, variant, dataset, scale, iters) in cases {
        let dir = temp_dir(&format!("warm-{app}-{variant}"));
        let mut cfg = SystemConfig {
            llc_bytes: 32 * 1024, // scaled graphs still segment
            ..Default::default()
        };
        cfg.store_enabled = true;
        cfg.store_dir = dir.to_string_lossy().into_owned();
        let spec = JobSpec {
            dataset: dataset.into(),
            scale,
            iters,
            num_sources: 2,
            app: AppKind::parse(app, variant).unwrap(),
            ..Default::default()
        };

        cfg.store_mmap = true;
        let cold = run_job(&spec, &cfg).unwrap();
        let warm_mapped = run_job(&spec, &cfg).unwrap();
        cfg.store_mmap = false;
        let warm_decoded = run_job(&spec, &cfg).unwrap();

        // BC accumulates through relaxed atomics (equal up to float
        // reassociation); every other summary must be bitwise identical
        // regardless of owned vs mapped backing.
        if app == "bc" {
            for (tag, got) in [("mapped", warm_mapped.summary), ("decoded", warm_decoded.summary)] {
                let rel = (cold.summary - got).abs() / cold.summary.abs().max(1e-12);
                assert!(rel < 1e-6, "{app} {tag} warm: {got} vs cold {}", cold.summary);
            }
        } else {
            assert_eq!(
                cold.summary.to_bits(),
                warm_mapped.summary.to_bits(),
                "{app}/{variant}: mapped warm summary differs from cold"
            );
            assert_eq!(
                cold.summary.to_bits(),
                warm_decoded.summary.to_bits(),
                "{app}/{variant}: decoded warm summary differs from cold"
            );
        }

        // run_job opens a private store per job, so each run's stats are
        // its own traffic.
        match (&warm_mapped.metrics.store, &warm_decoded.metrics.store) {
            (Some(sm), Some(sd)) => {
                assert_eq!(sm.misses, 0, "{app}: mapped warm run rebuilt an artifact");
                assert_eq!(sd.misses, 0, "{app}: decoded warm run rebuilt an artifact");
                assert!(sm.hits > 0 && sd.hits > 0);
                assert!(sd.bytes_read > 0, "{app}: decoded warm run must read bytes");
                if cagra::store::mmap_supported() {
                    assert_eq!(
                        sm.bytes_read, 0,
                        "{app}: mapped warm run must decode zero bytes"
                    );
                    assert!(sm.bytes_mapped > 0, "{app}: mapped bytes unaccounted");
                }
            }
            (None, None) => {
                // Store-less app: --store attaches no stats and plants no
                // directory (pagerank-delta, triangle).
                assert!(!dir.exists(), "{app}: store-less app planted a store");
            }
            (m, d) => panic!("{app}: inconsistent store stats across warm runs: {m:?} / {d:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn stale_version_artifact_under_current_name_is_regenerated() {
    // Version skew normally changes the store filename (`.v<codec>.art`),
    // but a file whose *content* is an old frame under the current name —
    // a partially upgraded store, a copied directory — must be treated as
    // a miss, removed, and rebuilt, never decoded by v1 rules.
    let dir = temp_dir("v1-regen");
    let store = ArtifactStore::open(&dir, 0).unwrap();
    let key = StoreKey::ordering(0x51A1E, "stale");
    let path = dir.join(key.filename::<ArcSlice<u32>>());
    std::fs::create_dir_all(&dir).unwrap();
    // A syntactically plausible v1 frame: magic, version 1, kind, the old
    // length-prefixed payload shape.
    let mut v1 = Vec::new();
    v1.extend_from_slice(b"CAGART01");
    v1.extend_from_slice(&1u32.to_le_bytes());
    v1.extend_from_slice(b"PERM");
    v1.extend_from_slice(&3u64.to_le_bytes());
    v1.extend_from_slice(&[0u8; 12]);
    std::fs::write(&path, &v1).unwrap();

    let want: Vec<u32> = vec![1, 0, 2];
    let got: ArcSlice<u32> = store.get_or_build(&key, || want.clone().into());
    assert_eq!(got, want);
    let s = store.stats();
    assert_eq!(
        (s.hits, s.misses),
        (0, 1),
        "v1 content must be a miss (drop + rebuild), not a hit"
    );
    // The rebuilt file is current-version and serves warm from here on.
    let warm: ArcSlice<u32> = store.get_or_build(&key, || panic!("must not rebuild"));
    assert_eq!(warm, want);
    assert_eq!(store.stats().hits, 1);
    let infos = store.list_artifacts();
    assert_eq!(infos.len(), 1);
    assert_eq!(infos[0].version, Some(cagra::store::CODEC_VERSION));
    std::fs::remove_dir_all(&dir).ok();
}
