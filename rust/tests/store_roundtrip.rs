//! Artifact-store acceptance tests: running the same dataset twice with
//! the store enabled does the preprocessing work once — the second run
//! hits the store — and warm runs produce bitwise-identical results to
//! cold runs for PageRank, CF, and CC.

use cagra::apps::{cc, cf, pagerank};
use cagra::coordinator::{run_job, AppKind, JobSpec, SystemConfig};
use cagra::graph::datasets;
use cagra::store::{fingerprint, ArtifactStore, StoreCtx};

const SCALE: f64 = 1.0 / 64.0;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cagra-storetest-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn small_cfg() -> SystemConfig {
    SystemConfig {
        llc_bytes: 32 * 1024, // scaled graphs still segment
        ..Default::default()
    }
}

#[test]
fn pagerank_warm_run_is_bitwise_identical_and_hits() {
    let ds = datasets::load_scaled("livejournal-sim", SCALE).unwrap();
    let cfg = small_cfg();
    let dir = temp_dir("pr");
    let store = ArtifactStore::open(&dir, 0).unwrap();
    let fp = fingerprint::fingerprint_dataset(&ds.name, SCALE, &ds.graph);
    let ctx = StoreCtx::new(&store, fp);
    let variant = pagerank::Variant::ReorderedSegmented;

    // Cold: builds + persists the permutation and the segmented
    // partition (the relabeled CSR is only a cold-build intermediate for
    // this variant and is deliberately not stored).
    let mut cold = pagerank::Prepared::prepare(&ds.graph, &cfg, variant, &ctx);
    let a = cold.run(4);
    let s = store.stats();
    assert_eq!(s.hits, 0, "cold run must not hit");
    assert_eq!(s.misses, 2, "cold run builds perm + seg");
    assert!(s.entries == 2 && s.bytes_written > 0);

    // Warm: identical results, all artifacts served from disk.
    let mut warm = pagerank::Prepared::prepare(&ds.graph, &cfg, variant, &ctx);
    let b = warm.run(4);
    let s = store.stats();
    assert_eq!(s.hits, 2, "warm run must hit every artifact");
    assert_eq!(s.misses, 2, "warm run must not rebuild");
    // Bitwise: decoded artifacts drive the exact same FP operation order.
    assert_eq!(a.values.len(), b.values.len());
    for (i, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "rank {i} differs: {x} vs {y}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cf_warm_run_is_bitwise_identical_and_hits() {
    let ds = datasets::load_scaled("netflix-sim", 0.05).unwrap();
    let mut cfg = small_cfg();
    cfg.llc_bytes = 16 * 1024; // force multiple segments at K=8
    let dir = temp_dir("cf");
    let store = ArtifactStore::open(&dir, 0).unwrap();
    let fp = fingerprint::fingerprint_dataset(&ds.name, 0.05, &ds.graph);
    let ctx = StoreCtx::new(&store, fp);

    let mut cold = cf::Prepared::prepare(&ds.graph, &cfg, cf::Variant::Segmented, &ctx);
    for _ in 0..2 {
        cold.step();
    }
    let s = store.stats();
    assert_eq!((s.hits, s.misses), (0, 2), "cold run builds cf-user + cf-item");

    let mut warm = cf::Prepared::prepare(&ds.graph, &cfg, cf::Variant::Segmented, &ctx);
    for _ in 0..2 {
        warm.step();
    }
    assert_eq!(store.stats().hits, 2, "warm run must hit both partitions");
    for (i, (x, y)) in cold.factors.data.iter().zip(&warm.factors.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "factor {i} differs: {x} vs {y}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cc_warm_run_is_bitwise_identical_and_hits() {
    // CC's symmetrized working structure (segmented partition /
    // transposed pull CSR) is the last O(|E|) preprocessing to join the
    // store: a warm run must decode it — zero symmetrize work — and
    // converge to bitwise-identical labels.
    let ds = datasets::load_scaled("livejournal-sim", SCALE).unwrap();
    let cfg = small_cfg();
    for variant in [cc::Variant::Baseline, cc::Variant::Segmented] {
        let dir = temp_dir(&format!("cc-{}", variant.name()));
        let store = ArtifactStore::open(&dir, 0).unwrap();
        let fp = fingerprint::fingerprint_dataset(&ds.name, SCALE, &ds.graph);
        let ctx = StoreCtx::new(&store, fp);

        let mut cold = cc::Prepared::prepare(&ds.graph, &cfg, variant, &ctx);
        while cold.sweep() {}
        let s = store.stats();
        assert_eq!(
            (s.hits, s.misses),
            (0, 1),
            "{variant:?}: cold run builds exactly the symmetrized structure"
        );

        let mut warm = cc::Prepared::prepare(&ds.graph, &cfg, variant, &ctx);
        while warm.sweep() {}
        let s = store.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "{variant:?}: warm run must hit");
        assert_eq!(
            cold.labels(),
            warm.labels(),
            "{variant:?}: warm labels must be bitwise identical"
        );
        assert_eq!(cold.num_components(), warm.num_components());
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn run_job_second_run_hits_store_with_identical_summary() {
    let dir = temp_dir("job");
    let mut cfg = small_cfg();
    cfg.store_enabled = true;
    cfg.store_dir = dir.to_string_lossy().into_owned();
    let spec = JobSpec {
        dataset: "livejournal-sim".into(),
        scale: SCALE,
        iters: 3,
        app: AppKind::PageRank(pagerank::Variant::ReorderedSegmented),
        ..Default::default()
    };
    let r1 = run_job(&spec, &cfg).unwrap();
    let s1 = r1.metrics.store.expect("store stats attached");
    assert_eq!(s1.hits, 0);
    assert!(s1.misses > 0 && s1.entries > 0);

    let r2 = run_job(&spec, &cfg).unwrap();
    let s2 = r2.metrics.store.expect("store stats attached");
    assert_eq!(
        s2.hits, s1.misses,
        "every cold build must be a warm hit (same fingerprint across loads)"
    );
    assert_eq!(s2.misses, 0, "warm run must not redo preprocessing work");
    assert_eq!(
        r1.summary.to_bits(),
        r2.summary.to_bits(),
        "warm summary must be bitwise identical: {} vs {}",
        r1.summary,
        r2.summary
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bc_bfs_sssp_and_cc_warm_runs_hit_store_through_run_job() {
    // The reordering permutation is the cacheable preprocessing for the
    // frontier apps (ROADMAP open item, closed by the GraphApp redesign;
    // SSSP joined via reorder::cached_degree_sort_perm); CC persists its
    // symmetrized working structure. All of them build exactly one
    // artifact cold and decode it warm.
    for (app, variant) in [
        ("bc", "both"),
        ("bfs", "both"),
        ("sssp", "reordering"),
        ("cc", "baseline"),
        ("cc", "segmenting"),
    ] {
        let dir = temp_dir(&format!("frontier-{app}-{variant}"));
        let mut cfg = small_cfg();
        cfg.store_enabled = true;
        cfg.store_dir = dir.to_string_lossy().into_owned();
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: SCALE,
            iters: 1,
            num_sources: 2,
            app: AppKind::parse(app, variant).unwrap(),
            ..Default::default()
        };
        let r1 = run_job(&spec, &cfg).unwrap();
        let s1 = r1.metrics.store.unwrap_or_else(|| panic!("{app}: store stats attached"));
        assert_eq!((s1.hits, s1.misses), (0, 1), "{app}/{variant}: cold run builds one artifact");
        let r2 = run_job(&spec, &cfg).unwrap();
        let s2 = r2.metrics.store.unwrap();
        assert_eq!((s2.hits, s2.misses), (1, 0), "{app}: warm run must hit");
        if app == "bc" {
            // BC accumulates through relaxed atomics; scores are equal up
            // to float reassociation, not bitwise.
            let rel = (r1.summary - r2.summary).abs() / r1.summary.abs().max(1e-12);
            assert!(rel < 1e-6, "{app} summary {} vs {}", r1.summary, r2.summary);
        } else {
            // BFS's reached count, SSSP's converged distance vector, and
            // CC's component count are deterministic regardless of the
            // decoded artifact.
            assert_eq!(r1.summary, r2.summary, "{app} summary");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn baseline_frontier_jobs_skip_the_store() {
    // Baseline BC/BFS do no cacheable preprocessing; --store must attach
    // no stats (and plant no store) for them.
    let dir = temp_dir("frontier-baseline");
    let mut cfg = small_cfg();
    cfg.store_enabled = true;
    cfg.store_dir = dir.to_string_lossy().into_owned();
    let spec = JobSpec {
        dataset: "livejournal-sim".into(),
        scale: SCALE,
        iters: 1,
        num_sources: 1,
        app: AppKind::parse("bfs", "baseline").unwrap(),
        ..Default::default()
    };
    let r = run_job(&spec, &cfg).unwrap();
    assert!(r.metrics.store.is_none());
    assert!(!dir.exists(), "no store directory should be created");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn store_disabled_attaches_no_stats() {
    let spec = JobSpec {
        dataset: "livejournal-sim".into(),
        scale: SCALE,
        iters: 2,
        ..Default::default()
    };
    let r = run_job(&spec, &SystemConfig::default()).unwrap();
    assert!(r.metrics.store.is_none());
}
