//! Figure 2: per-optimization breakdown on PageRank/RMAT27 — running time
//! and (simulated) cycles stalled on memory, normalized to the baseline,
//! including the no-random-access lower bound ("the last bar is an
//! incorrect program where random accesses were removed").

mod common;

use cagra::apps::pagerank::Variant;
use cagra::bench::{header, Bencher, Table};
use cagra::coordinator::job::simulate_pagerank;

fn main() {
    header("Figure 2: optimization breakdown, PageRank RMAT27", "paper Figure 2");
    let cfg = common::config();
    let ds = common::load("rmat27-sim");
    let g = &ds.graph;
    let mut b = Bencher::new();

    let variants = [
        Variant::Baseline,
        Variant::Reordered,
        Variant::Segmented,
        Variant::ReorderedSegmented,
        Variant::NoRandomLowerBound,
    ];
    let mut times = Vec::new();
    let mut stalls = Vec::new();
    for v in variants {
        times.push(common::time_pagerank_iter(&mut b, v.name(), g, &cfg, v));
        // The lower bound's trace is the baseline's without random reads;
        // model it as all vertex reads hitting L1 (stalls from streams
        // only) by reusing the baseline estimate minus its random
        // component — simplest: simulate with a huge LLC.
        let est = if v == Variant::NoRandomLowerBound {
            let big = cagra::coordinator::SystemConfig {
                llc_bytes: 1 << 30,
                ..cfg.clone()
            };
            simulate_pagerank(g, &big, Variant::Baseline)
        } else {
            simulate_pagerank(g, &cfg, v)
        };
        stalls.push(est.stall_cycles);
    }
    let t0 = times[0];
    let s0 = stalls[0];
    let mut t = Table::new(&["Variant", "Time (norm.)", "Sim. stalls (norm.)"]);
    for (i, v) in variants.iter().enumerate() {
        t.row(&[
            v.name().to_string(),
            format!("{:.2}", times[i] / t0),
            format!("{:.2}", stalls[i] / s0),
        ]);
    }
    t.print();
    println!("\npaper (Figure 2): stall reduction tracks runtime reduction; optimized within 2x of the no-random lower bound");
    println!(
        "our gap to lower bound: {:.2}x (paper: ~2x)",
        times[3] / times[4]
    );
}
