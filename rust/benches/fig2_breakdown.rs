//! Figure 2: per-optimization breakdown on PageRank/RMAT27 — running time
//! and (simulated) cycles stalled on memory, normalized to the baseline,
//! including the no-random-access lower bound ("the last bar is an
//! incorrect program where random accesses were removed").

mod common;

use cagra::apps::{registry, AppKind};
use cagra::bench::Table;

fn main() {
    common::run_suite("fig2_breakdown", |s| {
        let cfg = common::config();
        let ds = common::load("rmat27-sim");
        let g = &ds.graph;

        // Every PageRank variant the registry advertises, in table order
        // (baseline, reordering, segmenting, both, lower-bound).
        let app = registry::find("pagerank").expect("pagerank registered");
        let mut names = Vec::new();
        let mut times = Vec::new();
        let mut stalls = Vec::new();
        for info in app.variants() {
            names.push(info.name);
            times.push(common::time_app_iter(s, info.name, g, &cfg, "pagerank", info.name));
            // The lower bound's trace is the baseline's without random reads;
            // model it as all vertex reads hitting L1 (stalls from streams
            // only) by reusing the baseline estimate minus its random
            // component — simplest: simulate the baseline with a huge LLC.
            let est = if info.name == "lower-bound" {
                let big = cagra::coordinator::SystemConfig {
                    llc_bytes: 1 << 30,
                    ..cfg.clone()
                };
                let base = AppKind::parse("pagerank", "baseline").unwrap();
                app.simulate(g, &big, base).expect("pagerank simulates")
            } else {
                app.simulate(g, &cfg, info.kind).expect("pagerank simulates")
            };
            s.record(&format!("{}-stalls", info.name), "cycles", est.stall_cycles);
            stalls.push(est.stall_cycles);
        }
        // Index by name, not table position — the variant order lives in
        // pagerank's registry table, another file.
        let idx = |want: &str| {
            names
                .iter()
                .position(|n| *n == want)
                .unwrap_or_else(|| panic!("pagerank variant {want:?} not in registry"))
        };
        let t0 = times[idx("baseline")];
        let s0 = stalls[idx("baseline")];
        let mut t = Table::new(&["Variant", "Time (norm.)", "Sim. stalls (norm.)"]);
        for (i, name) in names.iter().enumerate() {
            t.row(&[
                name.to_string(),
                format!("{:.2}", times[i] / t0),
                format!("{:.2}", stalls[i] / s0),
            ]);
        }
        t.print();
        println!("\npaper (Figure 2): stall reduction tracks runtime reduction; optimized within 2x of the no-random lower bound");
        println!(
            "our gap to lower bound: {:.2}x (paper: ~2x)",
            times[idx("both")] / times[idx("lower-bound")]
        );
    });
}
