//! Figure 6: segment-local computation vs cache-aware merge cost, as a
//! fraction of an optimized PageRank iteration (plus "other": the
//! contribution precompute and rank apply). Paper: merge is a small
//! slice; total segmenting overhead is well under the 2x+ speedup it
//! buys.

mod common;

use cagra::bench::Table;
use cagra::segment::{merge, SegmentBuffers, SegmentedCsr};
use cagra::util::timer::time;

fn main() {
    common::run_suite("fig6_merge_cost", |s| {
        let cfg = common::config();
        let mut t = Table::new(&["Dataset", "segment compute", "merge", "other", "total/iter"]);
        s.cap_reps(3);
        let reps = s.reps().max(1);
        for name in ["twitter-sim", "rmat27-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            let n = g.num_vertices();
            let sg = SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8));
            let mut bufs = SegmentBuffers::for_graph(&sg);
            let rank = vec![1.0 / n as f64; n];
            let inv: Vec<f64> = (0..n)
                .map(|v| {
                    let d = g.degree(v as u32);
                    if d == 0 {
                        0.0
                    } else {
                        1.0 / d as f64
                    }
                })
                .collect();
            let mut contrib = vec![0.0f64; n];
            let mut out = vec![0.0f64; n];
            let mut seg_s = 0.0;
            let mut merge_s = 0.0;
            let mut other_s = 0.0;
            for _ in 0..reps {
                let (_, t1) = time(|| {
                    for v in 0..n {
                        contrib[v] = rank[v] * inv[v];
                    }
                });
                let (_, t2) = time(|| {
                    for i in 0..sg.num_segments() {
                        sg.process_segment(i, |u| contrib[u as usize], &mut bufs.per_segment[i]);
                    }
                });
                let (_, t3) = time(|| {
                    out.fill(0.0);
                    merge(&sg, &bufs, &mut out);
                });
                let (_, t4) = time(|| {
                    for v in 0..n {
                        out[v] = 0.15 / n as f64 + 0.85 * out[v];
                    }
                });
                seg_s += t2;
                merge_s += t3;
                other_s += t1 + t4;
            }
            let total = seg_s + merge_s + other_s;
            s.set_scope(name);
            s.record("segment-compute", "s", seg_s / reps as f64);
            s.record("merge", "s", merge_s / reps as f64);
            s.record("other", "s", other_s / reps as f64);
            s.record("total-iter", "s", total / reps as f64);
            t.row(&[
                name.to_string(),
                format!("{:.1}%", seg_s / total * 100.0),
                format!("{:.1}%", merge_s / total * 100.0),
                format!("{:.1}%", other_s / total * 100.0),
                format!("{:.1}ms", total / reps as f64 * 1e3),
            ]);
        }
        t.print();
        println!("\npaper (Figure 6): merge is a minor slice of the iteration; segment-local edge processing dominates");
    });
}
