//! Figure 9: time per edge and (simulated) stall cycles per edge, for
//! PageRank and CF across graph sizes. Paper shape: segmented per-edge
//! cost is flat as graphs grow (all random reads served at fixed LLC
//! latency) while baseline/reordering per-edge cost climbs.

mod common;

use cagra::apps::{cf, pagerank};
use cagra::bench::Table;
use cagra::coordinator::job::simulate_pagerank;
use cagra::graph::datasets::GRAPH_DATASETS;
use cagra::store::StoreCtx;

fn main() {
    common::run_suite("fig9_per_edge", |s| {
        let cfg = common::config();

        println!("\nPageRank: ns/edge (measured) and stall-cycles/edge (simulated):");
        let mut t = Table::new(&[
            "Dataset",
            "edges",
            "base ns/e",
            "reord ns/e",
            "seg ns/e",
            "both ns/e",
            "base stall/e",
            "both stall/e",
        ]);
        s.cap_reps(3);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            let m = g.num_edges() as f64;
            s.set_scope(name);
            let mut times = Vec::new();
            for &v in pagerank::Variant::all() {
                let secs = common::time_app_iter(s, v.name(), g, &cfg, "pagerank", v.name());
                times.push(secs / m * 1e9);
            }
            let sim_base = simulate_pagerank(g, &cfg, pagerank::Variant::Baseline);
            let sim_both = simulate_pagerank(g, &cfg, pagerank::Variant::ReorderedSegmented);
            let spe = |e: &cagra::cache::StallEstimate| e.stall_cycles / (e.accesses as f64 / 2.0);
            t.row(&[
                name.to_string(),
                format!("{:.1}M", m / 1e6),
                format!("{:.2}", times[0]),
                format!("{:.2}", times[1]),
                format!("{:.2}", times[2]),
                format!("{:.2}", times[3]),
                format!("{:.1}", spe(&sim_base)),
                format!("{:.1}", spe(&sim_both)),
            ]);
        }
        t.print();

        println!("\nCF: ns/edge per iteration:");
        let mut t = Table::new(&["Dataset", "baseline ns/e", "segmented ns/e"]);
        s.cap_reps(2);
        for name in ["netflix-sim", "netflix2x-sim", "netflix4x-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            let m = g.num_edges() as f64;
            s.set_scope(name);
            let mut pb = cf::Prepared::prepare(g, &cfg, cf::Variant::Baseline, &StoreCtx::disabled());
            let base = s.bench("cf-base", || pb.step()).secs() / m * 1e9;
            let mut ps = cf::Prepared::prepare(g, &cfg, cf::Variant::Segmented, &StoreCtx::disabled());
            let seg = s.bench("cf-seg", || ps.step()).secs() / m * 1e9;
            t.row(&[
                name.to_string(),
                format!("{base:.2}"),
                format!("{seg:.2}"),
            ]);
        }
        t.print();
        println!("\npaper (Figure 9): segmented cycles/edge stays flat with graph size; baseline grows as more random reads hit DRAM");
    });
}
