//! Frontier churn: deep, narrow-frontier traversals where per-level
//! engine overhead — historically an O(n) scratch allocation + zero-fill
//! and an O(n) output-flag rescan per `edge_map` level — dominates the
//! actual edge work. The zero-allocation scratch engine turns these from
//! O(depth · n) into O(n + edges); this suite makes that win a gated
//! number in `BENCH_frontier_churn.json` rather than an anecdote.
//!
//! Graphs are synthetic (no dataset stand-in has a deliberately deep,
//! skinny diameter): a long chain with sparse shortcuts (frontier ≈ 1-2
//! vertices for thousands of levels) and a narrow lattice (frontier = a
//! fixed small band, many levels) for the wide-ish push path.

mod common;

use cagra::bench::table::fmt_secs;
use cagra::bench::Table;
use cagra::graph::Csr;

fn main() {
    common::run_suite("frontier_churn", |s| {
        let scale = cagra::bench::scale();
        // Depth scales with CAGRA_BENCH_SCALE like dataset sizes do, so
        // runs at different scales are never silently compared (the diff
        // gate refuses cross-scale comparisons).
        let depth = ((400_000.0 * scale) as usize).max(2_000);
        // Chain 0→1→…→depth-1 plus a shortcut every 97 vertices: ~1-2
        // active vertices per level, `depth` levels.
        let mut edges: Vec<(u32, u32)> = Vec::with_capacity(depth + depth / 97 + 8);
        for i in 0..depth as u32 - 1 {
            edges.push((i, i + 1));
        }
        let mut i = 0u32;
        while (i as usize) + 3 < depth {
            edges.push((i, i + 2));
            i += 97;
        }
        let chain = Csr::from_edges(depth, &edges);
        // Narrow lattice: `width` parallel chains with rungs — frontier
        // stays at `width` vertices for depth/width levels, exercising
        // the cost-balanced push with a multi-vertex frontier.
        let width = 64usize;
        let levels = (depth / width).max(32);
        let n2 = width * levels;
        let mut lat: Vec<(u32, u32)> = Vec::with_capacity(2 * n2);
        for l in 0..levels as u32 - 1 {
            for w in 0..width as u32 {
                let v = l * width as u32 + w;
                lat.push((v, v + width as u32));
                if w + 1 < width as u32 {
                    lat.push((v, v + 1));
                }
            }
        }
        let lattice = Csr::from_edges(n2, &lat);
        let cfg = common::config();
        s.cap_reps(3);
        let mut table = Table::new(&["Case", "Levels", "Time"]);
        let bfs_deep =
            common::time_app_sources(s, "bfs-deep", &chain, &cfg, "bfs", "baseline", &[0]);
        table.row(&["bfs-deep".into(), depth.to_string(), fmt_secs(bfs_deep)]);
        let bfs_bits = {
            let label = "bfs-deep-bitvector";
            common::time_app_sources(s, label, &chain, &cfg, "bfs", "bitvector", &[0])
        };
        table.row(&[
            "bfs-deep-bitvector".into(),
            depth.to_string(),
            fmt_secs(bfs_bits),
        ]);
        let sssp_deep =
            common::time_app_sources(s, "sssp-deep", &chain, &cfg, "sssp", "baseline", &[0]);
        table.row(&["sssp-deep".into(), depth.to_string(), fmt_secs(sssp_deep)]);
        let bfs_wide =
            common::time_app_sources(s, "bfs-wide-levels", &lattice, &cfg, "bfs", "baseline", &[0]);
        table.row(&[
            "bfs-wide-levels".into(),
            levels.to_string(),
            fmt_secs(bfs_wide),
        ]);
        table.print();
        println!(
            "\n{depth} chain levels / {levels} lattice levels; steady-state edge_map performs \
             zero heap allocation (see tests/zero_alloc.rs), so per-level cost is bounded by \
             touched state, not O(n) scratch churn"
        );
    });
}
