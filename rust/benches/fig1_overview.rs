//! Figure 1: headline overview — running time of our optimized
//! implementations vs the framework baselines on RMAT27 for PageRank,
//! Collaborative Filtering (Netflix4x in the paper; netflix-sim here to
//! bound runtime), and Betweenness Centrality.

mod common;

use cagra::apps::{bc, cf};
use cagra::baselines::{graphmat_style, gridgraph_style, ligra_style};
use cagra::bench::Table;
use cagra::store::StoreCtx;

fn main() {
    common::run_suite("fig1_overview", |s| {
        let cfg = common::config();
        let ds = common::load("rmat27-sim");
        let g = &ds.graph;
        s.cap_reps(3);

        // PageRank per-iteration across systems (ours via the app registry).
        let pr_opt = common::time_app_iter(s, "pr-opt", g, &cfg, "pagerank", "both");
        let pr_gm = {
            let mut p = graphmat_style::Prepared::new(g, &cfg);
            s.bench("pr-graphmat", || p.step()).secs()
        };
        let pr_li = {
            let mut p = ligra_style::Prepared::new(g, &cfg);
            s.bench("pr-ligra", || p.step()).secs()
        };
        let pr_gg = {
            let mut p = gridgraph_style::Prepared::new(g, &cfg);
            s.bench("pr-gridgraph", || p.step()).secs()
        };

        // CF per-iteration (ours vs GraphMat-shaped baseline).
        let nf = common::load("netflix-sim");
        let cf_opt = {
            let mut p = cf::Prepared::prepare(&nf.graph, &cfg, cf::Variant::Segmented, &StoreCtx::disabled());
            s.bench("cf-opt", || p.step()).secs()
        };
        let cf_gm = {
            let mut p = cf::Prepared::prepare(&nf.graph, &cfg, cf::Variant::Baseline, &StoreCtx::disabled());
            s.bench("cf-graphmat", || p.step()).secs()
        };

        // BC (ours vs Ligra-shaped baseline), 2 sources for time.
        let sources = bc::default_sources(g, 2);
        let mut bc_opt_p = bc::Prepared::prepare(g, &cfg, bc::Variant::ReorderedBitvector, &StoreCtx::disabled());
        let bc_opt = s.bench("bc-opt", || {
            let _ = bc_opt_p.run(&sources);
        });
        let mut bc_li_p = bc::Prepared::prepare(g, &cfg, bc::Variant::Baseline, &StoreCtx::disabled());
        let bc_li = s.bench("bc-ligra", || {
            let _ = bc_li_p.run(&sources);
        });

        let mut t = Table::new(&["App", "Ours", "GraphMat-style", "Ligra-style", "GridGraph-style"]);
        t.row(&[
            "PageRank (per iter)".into(),
            common::cell(pr_opt, pr_opt),
            common::cell(pr_gm, pr_opt),
            common::cell(pr_li, pr_opt),
            common::cell(pr_gg, pr_opt),
        ]);
        t.row(&[
            "CF (per iter)".into(),
            common::cell(cf_opt, cf_opt),
            common::cell(cf_gm, cf_opt),
            "-".into(),
            "-".into(),
        ]);
        t.row(&[
            "BC (2 sources)".into(),
            common::cell(bc_opt.secs(), bc_opt.secs()),
            "-".into(),
            common::cell(bc_li.secs(), bc_opt.secs()),
            "-".into(),
        ]);
        t.print();
        println!("\npaper (Figure 1, RMAT27): PageRank 4.3x vs GraphMat / 8.8x vs Ligra / 11.2x vs GridGraph; CF up to 4x; BC up to 2x");
    });
}
