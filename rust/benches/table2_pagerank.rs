//! Table 2: PageRank runtime per iteration — our optimized version vs our
//! baseline vs GraphMat-style vs Ligra-style vs GridGraph-style, across
//! the four graph datasets. The paper's shape: Optimized < Our Baseline <
//! GraphMat < Ligra ≈< GridGraph, with gaps growing with graph size.

mod common;

use cagra::baselines::{graphmat_style, gridgraph_style, ligra_style};
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;

fn main() {
    common::run_suite("table2_pagerank", |s| {
        let cfg = common::config();
        let mut table = Table::new(&[
            "Dataset",
            "Optimized",
            "Our Baseline",
            "GraphMat-style",
            "Ligra-style",
            "GridGraph-style",
        ]);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            s.set_scope(name);
            // Our variants run through the app registry — the same pipeline
            // the CLI uses; the baseline frameworks keep their own drivers.
            let opt = common::time_app_iter(s, "optimized", g, &cfg, "pagerank", "both");
            let base = common::time_app_iter(s, "baseline", g, &cfg, "pagerank", "baseline");
            let gm = {
                let mut p = graphmat_style::Prepared::new(g, &cfg);
                p.reset();
                s.bench_work("graphmat", Some(g.num_edges() as u64), &mut || p.step())
                    .secs()
            };
            let li = {
                let mut p = ligra_style::Prepared::new(g, &cfg);
                p.reset();
                s.bench_work("ligra", Some(g.num_edges() as u64), &mut || p.step())
                    .secs()
            };
            let gg = {
                let mut p = gridgraph_style::Prepared::new(g, &cfg);
                p.reset();
                s.bench_work("gridgraph", Some(g.num_edges() as u64), &mut || p.step())
                    .secs()
            };
            table.row(&[
                name.to_string(),
                common::cell(opt, opt),
                common::cell(base, opt),
                common::cell(gm, opt),
                common::cell(li, opt),
                common::cell(gg, opt),
            ]);
        }
        table.print();
        println!("\npaper (Table 2, RMAT27 row): optimized 0.58s, baseline 2.80x, GraphMat 4.30x, Ligra 8.53x, GridGraph 11.20x");
    });
}
