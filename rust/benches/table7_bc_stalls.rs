//! Table 7: total cycles stalled on memory for BC under the optimization
//! grid {baseline, reordering, bitvector, reordering+bitvector} × four
//! graphs. Stalls are **simulated** through the registry's per-app
//! `GraphApp::simulate` (the same estimate `cagra run --analyze`
//! reports; `--pmu` reads the hardware counters this model is validated
//! against — DESIGN.md §3). The paper's shape to reproduce: every
//! optimization reduces stalls on the big graphs, the combination is
//! best, and LiveJournal (cache-resident) barely moves.

mod common;

use cagra::apps::{registry, AppKind};
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;

const VARIANTS: [&str; 4] = ["baseline", "reordering", "bitvector", "reordering+bitvector"];

fn main() {
    common::run_suite("table7_bc_stalls", |s| {
        let cfg = common::config();
        let mut t = Table::new(&[
            "Dataset",
            "Baseline",
            "Reordering",
            "Bitvector",
            "Reordering+Bitvector",
        ]);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            // BC reads σ (8B) + frontier per edge; see apps::bc::App::simulate.
            let cells: Vec<f64> = VARIANTS
                .iter()
                .map(|variant| {
                    let kind = AppKind::parse("bc", variant)
                        .unwrap_or_else(|e| panic!("parsing bc/{variant}: {e:#}"));
                    let est = registry::app_for(kind)
                        .simulate(g, &cfg, kind)
                        .expect("bc registers a simulation");
                    est.stall_cycles / 1e9
                })
                .collect();
            s.set_scope(name);
            for (variant, cell) in VARIANTS.iter().zip(&cells) {
                s.record(variant, "GCycles", *cell);
            }
            t.row(&[
                name.to_string(),
                format!("{:.2}B", cells[0]),
                format!("{:.2}B", cells[1]),
                format!("{:.2}B", cells[2]),
                format!("{:.2}B", cells[3]),
            ]);
        }
        t.print();
        println!("\npaper (Table 7, billions of stall cycles): RMAT27 row 23,264 / 11,918 / 12,578 / 9,152");
        println!("(absolute magnitudes differ — scaled datasets and one sweep vs the paper's full runs; the ordering across columns is the reproduced shape)");
    });
}
