//! Table 7: total cycles stalled on memory for BC under the optimization
//! grid {baseline, reordering, bitvector, reordering+bitvector} × four
//! graphs. Stalls are **simulated** (no PMU in this environment —
//! DESIGN.md §3); the paper's shape to reproduce: every optimization
//! reduces stalls on the big graphs, the combination is best, and
//! LiveJournal (cache-resident) barely moves.

mod common;

use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;
use cagra::reorder::{self, Ordering as VOrdering};

const VARIANTS: [&str; 4] = ["baseline", "reordering", "bitvector", "reordering+bitvector"];

fn main() {
    common::run_suite("table7_bc_stalls", |s| {
        let cfg = common::config();
        let mut t = Table::new(&[
            "Dataset",
            "Baseline",
            "Reordering",
            "Bitvector",
            "Reordering+Bitvector",
        ]);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            let sample = (g.num_edges() / 4_000_000).max(1);
            let pull = g.transpose();
            let (reord, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            let reord_pull = reord.transpose();
            // BC reads σ (8B) + frontier per edge.
            let cells: Vec<f64> = [
                common::frontier_stall_estimate(&pull, 8, false, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&reord_pull, 8, false, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&pull, 8, true, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&reord_pull, 8, true, cfg.llc_bytes, sample),
            ]
            .iter()
            .map(|e| e.stall_cycles * sample as f64 / 1e9)
            .collect();
            s.set_scope(name);
            for (variant, cell) in VARIANTS.iter().zip(&cells) {
                s.record(variant, "GCycles", *cell);
            }
            t.row(&[
                name.to_string(),
                format!("{:.2}B", cells[0]),
                format!("{:.2}B", cells[1]),
                format!("{:.2}B", cells[2]),
                format!("{:.2}B", cells[3]),
            ]);
        }
        t.print();
        println!("\npaper (Table 7, billions of stall cycles): RMAT27 row 23,264 / 11,918 / 12,578 / 9,152");
        println!("(absolute magnitudes differ — scaled datasets and one sweep vs the paper's full runs; the ordering across columns is the reproduced shape)");
    });
}
