//! Table 10: analytic DRAM-traffic comparison — segmenting's `E + 2qV`
//! vs GridGraph's `E + (P+2)V` vs X-Stream's `3E + KV`, with the q
//! measured from our actual segmented structure (the paper's Twitter
//! figures: E = 36V, q = 2.3, P = 32).

mod common;

use cagra::bench::Table;
use cagra::segment::expansion::{self, traffic};
use cagra::segment::SegmentedCsr;

fn main() {
    common::run_suite("table10_traffic", |s| {
        let cfg = common::config();
        let mut t = Table::new(&[
            "Dataset",
            "q (measured)",
            "P (grid)",
            "Ours E+2qV",
            "GridGraph E+(P+2)V",
            "X-Stream 3E+KV",
        ]);
        for name in ["twitter-sim", "rmat27-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            let e = g.num_edges() as u64;
            let v = g.num_vertices() as u64;
            let sg = SegmentedCsr::build(g, cfg.segment_size(8));
            let q = expansion::expansion_factor(&sg);
            let p = (v * 8).div_ceil((cfg.llc_bytes / 2) as u64).max(1);
            let ours = traffic::segmenting(e, v, q);
            let grid = traffic::gridgraph(e, v, p);
            let xs = traffic::xstream(e, v, q.max(2.0));
            s.set_scope(name);
            s.record("q", "q", q);
            s.record("ours", "Mwords", ours / 1e6);
            s.record("gridgraph", "Mwords", grid / 1e6);
            s.record("xstream", "Mwords", xs / 1e6);
            t.row(&[
                name.to_string(),
                format!("{q:.2}"),
                format!("{p}"),
                format!("{:.1} Mwords (1.00x)", ours / 1e6),
                format!("{:.1} Mwords ({:.2}x)", grid / 1e6, grid / ours),
                format!("{:.1} Mwords ({:.2}x)", xs / 1e6, xs / ours),
            ]);
        }
        t.print();
        println!("\npaper (Table 10): on Twitter E=36V, q=2.3, P=32 — ours E+2qV ≈ 40.6V, GridGraph ≈ 70V, X-Stream ≥ 108V");
    });
}
