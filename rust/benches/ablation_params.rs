//! Ablations for the design choices DESIGN.md calls out (not a paper
//! table; supports §3.3, §4.3 and §4.5):
//!
//! 1. **Coarsening threshold** for the stable degree sort (§3.3): exact
//!    sort (1) vs the paper's ⌊deg/10⌋ vs coarser, on a graph whose
//!    inherent order has locality (twitter-sim is BFS-relabeled).
//! 2. **Merge block size** (§4.3): L1-sized blocks vs smaller/larger.
//! 3. **Segment fill fraction** (§4.5): how much of the effective cache
//!    the segment's source data may occupy.

mod common;

use cagra::apps::pagerank::{Prepared, Variant};
use cagra::bench::Table;
use cagra::coordinator::SystemConfig;
use cagra::store::StoreCtx;

fn time_iter(s: &mut common::Suite, label: &str, g: &cagra::graph::Csr, cfg: &SystemConfig) -> f64 {
    let mut p = Prepared::prepare(g, cfg, Variant::ReorderedSegmented, &StoreCtx::disabled());
    p.reset();
    s.bench_work(label, Some(g.num_edges() as u64), &mut || p.step())
        .secs()
}

fn main() {
    common::run_suite("ablation_params", |s| {
        let ds = common::load("twitter-sim");
        let g = &ds.graph;
        s.cap_reps(3);

        println!("\n1. reordering coarsen threshold (twitter-sim, inherent locality):");
        let mut t = Table::new(&["coarsen", "per-iter"]);
        s.set_scope("coarsen");
        for coarsen in [1u32, 10, 100, 1000] {
            let cfg = SystemConfig {
                coarsen,
                ..common::config()
            };
            let secs = time_iter(s, &coarsen.to_string(), g, &cfg);
            t.row(&[coarsen.to_string(), format!("{:.1}ms", secs * 1e3)]);
        }
        t.print();
        println!("§3.3 expectation: coarse (10) ≥ exact (1) on locality-ordered graphs");

        println!("\n2. cache-aware merge block size:");
        let mut t = Table::new(&["block vertices", "bytes (f64 out)", "per-iter"]);
        s.set_scope("merge-block");
        for l1 in [2 * 1024usize, 32 * 1024, 512 * 1024] {
            let cfg = SystemConfig {
                l1_bytes: l1,
                ..common::config()
            };
            let secs = time_iter(s, &format!("l1={l1}"), g, &cfg);
            t.row(&[
                cfg.merge_block(8).to_string(),
                cagra::util::fmt_bytes(cfg.merge_block(8) * 8),
                format!("{:.1}ms", secs * 1e3),
            ]);
        }
        t.print();
        println!("§4.3 expectation: L1-sized blocks (32 KiB) at or near the optimum");

        println!("\n3. segment fill fraction of the effective cache:");
        let mut t = Table::new(&["fill", "segment vertices", "per-iter"]);
        s.set_scope("segment-fill");
        for fill in [0.125f64, 0.25, 0.5, 1.0] {
            let cfg = SystemConfig {
                segment_fill: fill,
                ..common::config()
            };
            let secs = time_iter(s, &format!("fill={fill}"), g, &cfg);
            t.row(&[
                format!("{fill}"),
                cfg.segment_size(8).to_string(),
                format!("{:.1}ms", secs * 1e3),
            ]);
        }
        t.print();
        println!("§4.5 expectation: ~0.5 optimal (room left for edge stream + output block); see EXPERIMENTS.md §Perf step 5");
    });
}
