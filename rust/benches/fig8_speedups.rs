//! Figure 8: speedup of each optimization over the app baseline, for
//! PageRank, CF, BC and BFS across datasets. Paper shape: segmenting
//! dominates for PR/CF; reordering ≈ bitvector for BC/BFS and they
//! compose; gains grow with graph size; reordering is weak on graphs
//! already in a locality-friendly order (livejournal/twitter stand-ins).

mod common;

use cagra::apps::{bc, bfs, cf};
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;
use cagra::store::StoreCtx;

fn main() {
    common::run_suite("fig8_speedups", |s| {
        let cfg = common::config();

        println!("\nPageRank (speedup vs baseline, per iteration):");
        let mut t = Table::new(&["Dataset", "reorder", "segment", "both"]);
        s.cap_reps(3);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            s.set_scope(name);
            let base = common::time_app_iter(s, "base", g, &cfg, "pagerank", "baseline");
            let r = common::time_app_iter(s, "reorder", g, &cfg, "pagerank", "reordering");
            let seg = common::time_app_iter(s, "segment", g, &cfg, "pagerank", "segmenting");
            let rs = common::time_app_iter(s, "both", g, &cfg, "pagerank", "both");
            t.row(&[
                name.to_string(),
                format!("{:.2}x", base / r),
                format!("{:.2}x", base / seg),
                format!("{:.2}x", base / rs),
            ]);
        }
        t.print();

        println!("\nCollaborative Filtering (speedup vs baseline):");
        let mut t = Table::new(&["Dataset", "segment"]);
        s.cap_reps(2);
        for name in ["netflix-sim", "netflix2x-sim"] {
            let ds = common::load(name);
            s.set_scope(name);
            let mut pb = cf::Prepared::prepare(&ds.graph, &cfg, cf::Variant::Baseline, &StoreCtx::disabled());
            let base = s.bench("cf-base", || pb.step()).secs();
            let mut ps = cf::Prepared::prepare(&ds.graph, &cfg, cf::Variant::Segmented, &StoreCtx::disabled());
            let seg = s.bench("cf-seg", || ps.step()).secs();
            t.row(&[name.to_string(), format!("{:.2}x", base / seg)]);
        }
        t.print();

        println!("\nBC and BFS (speedup vs baseline, 2 sources):");
        let mut t = Table::new(&["Dataset", "app", "reorder", "bitvector", "both"]);
        for name in ["twitter-sim", "rmat27-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            let sources = bc::default_sources(g, 2);
            s.set_scope(name);
            // BC grid (BC's own variant enum since the AppKind redesign).
            let mut bc_times = Vec::new();
            for v in bc::Variant::all() {
                let mut p = bc::Prepared::prepare(g, &cfg, *v, &StoreCtx::disabled());
                bc_times.push(
                    s.bench(&format!("bc-{}", v.name()), || {
                        let _ = p.run(&sources);
                    })
                    .secs(),
                );
            }
            t.row(&[
                name.to_string(),
                "BC".into(),
                format!("{:.2}x", bc_times[0] / bc_times[1]),
                format!("{:.2}x", bc_times[0] / bc_times[2]),
                format!("{:.2}x", bc_times[0] / bc_times[3]),
            ]);
            // BFS grid.
            let mut bfs_times = Vec::new();
            for v in bfs::Variant::all() {
                let mut p = bfs::Prepared::prepare(g, &cfg, *v, &StoreCtx::disabled());
                bfs_times.push(
                    s.bench(&format!("bfs-{}", v.name()), || {
                        for &src in &sources {
                            let _ = p.run(src);
                        }
                    })
                    .secs(),
                );
            }
            t.row(&[
                name.to_string(),
                "BFS".into(),
                format!("{:.2}x", bfs_times[0] / bfs_times[1]),
                format!("{:.2}x", bfs_times[0] / bfs_times[2]),
                format!("{:.2}x", bfs_times[0] / bfs_times[3]),
            ]);
        }
        t.print();
        println!("\npaper (Figure 8): PR/CF driven by segmenting (2x+); BC/BFS reorder ≈ bitvector, +20% combined; all grow with graph size");
    });
}
