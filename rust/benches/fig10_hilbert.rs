//! Figure 10: Hilbert-order PageRank scalability — HSerial / HAtomic /
//! HMerge vs Segmenting across thread counts. The global pool is sized at
//! process start, so the sweep re-executes this binary with
//! `CAGRA_THREADS=t --worker <mode>`.
//!
//! NOTE: this container exposes **one** CPU, so wall-clock does not
//! improve with threads; the paper's shape that *is* reproducible here —
//! HAtomic's 3x atomic penalty and HMerge's private-vector overhead vs
//! segmenting's shared working set — shows in the 1-thread column, and
//! the thread columns document scheduling overhead rather than scaling.

mod common;

use cagra::baselines::hilbert::{self, Mode};
use cagra::bench::{Bencher, Table};

const MODES: [&str; 4] = ["hserial", "hatomic", "hmerge", "segmenting"];

fn run_worker(mode: &str) {
    let cfg = common::config();
    let ds = common::load("twitter-sim");
    let g = &ds.graph;
    let mut b = Bencher::new();
    b.reps = b.reps.min(3);
    let secs = match mode {
        "hserial" => {
            let mut p = hilbert::Prepared::new(g, &cfg, Mode::HSerial);
            b.bench("x", || p.step()).secs()
        }
        "hatomic" => {
            let mut p = hilbert::Prepared::new(g, &cfg, Mode::HAtomic);
            b.bench("x", || p.step()).secs()
        }
        "hmerge" => {
            let mut p = hilbert::Prepared::new(g, &cfg, Mode::HMerge);
            b.bench("x", || p.step()).secs()
        }
        "segmenting" => {
            let mut p = cagra::apps::pagerank::Prepared::prepare(
                g,
                &cfg,
                cagra::apps::pagerank::Variant::ReorderedSegmented,
                &cagra::store::StoreCtx::disabled(),
            );
            p.reset();
            b.bench("x", || p.step()).secs()
        }
        _ => panic!("unknown mode {mode}"),
    };
    println!("RESULT {secs:.6}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--worker") {
        run_worker(&args[i + 1]);
        return;
    }
    common::run_suite("fig10_hilbert", |s| {
        let threads = [1usize, 2, 4];
        let exe = std::env::current_exe().unwrap();
        let mut t = Table::new(&["mode", "t=1", "t=2", "t=4"]);
        for mode in MODES {
            s.set_scope(mode);
            let mut row = vec![mode.to_string()];
            for &nt in &threads {
                if mode == "hserial" && nt > 1 {
                    row.push("-".into());
                    continue;
                }
                let out = std::process::Command::new(&exe)
                    .args(["--worker", mode, "--bench"])
                    .env("CAGRA_THREADS", nt.to_string())
                    .output()
                    .expect("spawning worker");
                let stdout = String::from_utf8_lossy(&out.stdout);
                let secs: f64 = stdout
                    .lines()
                    .find_map(|l| l.strip_prefix("RESULT "))
                    .unwrap_or_else(|| panic!("worker failed: {stdout}"))
                    .trim()
                    .parse()
                    .unwrap();
                s.record(&format!("t={nt}"), "s", secs);
                row.push(format!("{:.0}ms", secs * 1e3));
            }
            t.row(&row);
        }
        t.print();
        println!("\npaper (Figure 10, 12 cores): HSerial 5.4s, HAtomic 2.3s, HMerge 1.8s, Segmenting 0.5s — Hilbert variants 3x+ slower than segmenting");
        println!("(single-CPU container: compare within the t=1 column; see DESIGN.md §3)");
    });
}
