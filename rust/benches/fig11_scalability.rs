//! Figure 11: PageRank scalability vs serial — thread sweep via
//! subprocess re-exec (the pool is sized at process start).
//!
//! NOTE: one-CPU container — threads timeshare a single core, so the
//! measured "speedup" documents parallel-runtime overhead rather than
//! scaling (DESIGN.md §3). The bench additionally reports the
//! cache-model view of why segmenting scales on real multicores: all
//! threads share one segment working set, so the simulated per-access
//! stall cost is thread-count-independent, unlike Hilbert's per-thread
//! working sets (Figure 10 discussion).

mod common;

use cagra::bench::{Bencher, Table};

fn run_worker() {
    let cfg = common::config();
    let ds = common::load("twitter-sim");
    let g = &ds.graph;
    let mut b = Bencher::new();
    b.reps = b.reps.min(3);
    let mut p = cagra::apps::pagerank::Prepared::prepare(
        g,
        &cfg,
        cagra::apps::pagerank::Variant::ReorderedSegmented,
        &cagra::store::StoreCtx::disabled(),
    );
    p.reset();
    let secs = b.bench("x", || p.step()).secs();
    println!("RESULT {secs:.6}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--worker") {
        run_worker();
        return;
    }
    common::run_suite("fig11_scalability", |s| {
        let exe = std::env::current_exe().unwrap();
        let threads = [1usize, 2, 4, 8];
        let mut results = Vec::new();
        for &nt in &threads {
            let out = std::process::Command::new(&exe)
                .args(["--worker", "--bench"])
                .env("CAGRA_THREADS", nt.to_string())
                .output()
                .expect("spawning worker");
            let stdout = String::from_utf8_lossy(&out.stdout);
            let secs: f64 = stdout
                .lines()
                .find_map(|l| l.strip_prefix("RESULT "))
                .unwrap_or_else(|| panic!("worker failed: {stdout}"))
                .trim()
                .parse()
                .unwrap();
            s.record(&format!("t={nt}"), "s", secs);
            results.push(secs);
        }
        let serial = results[0];
        let mut t = Table::new(&["threads", "per-iter", "speedup vs 1 thread"]);
        for (i, &nt) in threads.iter().enumerate() {
            t.row(&[
                nt.to_string(),
                format!("{:.0}ms", results[i] * 1e3),
                format!("{:.2}x", serial / results[i]),
            ]);
        }
        t.print();
        println!("\npaper (Figure 11): 8.5x @ 12 cores, 14x @ 24 cores, 16x @ 48 SMT threads");
        println!(
            "(this container has {} CPU(s) — wall-clock cannot scale; the shared-working-set argument is validated by Figure 10's t=1 comparison and the cache simulation)",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        );
    });
}
