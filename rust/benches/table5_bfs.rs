//! Table 5: BFS runtime (multi-source) — optimized (reordering +
//! bitvector) vs Ligra-style baseline. Paper shape: ≈1x on LiveJournal
//! (reordering can even lose when the graph is already BFS-ordered),
//! growing to ~1.5x on RMAT27.

mod common;

use cagra::apps::{bc, bfs};
use cagra::bench::{header, Bencher, Table};
use cagra::graph::datasets::GRAPH_DATASETS;

fn main() {
    header("Table 5: BFS runtime", "paper Table 5");
    let sources_n = std::env::var("CAGRA_BFS_SOURCES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6usize); // paper uses 12; scaled default 6
    let mut table = Table::new(&["Dataset", "Optimized", "Ligra-style (baseline)"]);
    for name in GRAPH_DATASETS {
        let ds = common::load(name);
        let g = &ds.graph;
        let sources = bc::default_sources(g, sources_n);
        let mut b = Bencher::new();
        b.reps = b.reps.min(3);
        let opt_prep = bfs::Prepared::new(g, bfs::Variant::ReorderedBitvector);
        let opt = b
            .bench_work("optimized", Some(g.num_edges() as u64), &mut || {
                for &s in &sources {
                    let _ = opt_prep.run(s);
                }
            })
            .secs();
        let base_prep = bfs::Prepared::new(g, bfs::Variant::Baseline);
        let base = b
            .bench_work("ligra", Some(g.num_edges() as u64), &mut || {
                for &s in &sources {
                    let _ = base_prep.run(s);
                }
            })
            .secs();
        table.row(&[
            name.to_string(),
            common::cell(opt, opt),
            common::cell(base, opt),
        ]);
    }
    table.print();
    println!("\npaper (Table 5): LiveJournal 0.93x; Twitter 1.09x; RMAT25 1.24x; RMAT27 1.54x (Ligra vs optimized), 12 sources");
}
