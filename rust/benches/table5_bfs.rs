//! Table 5: BFS runtime (multi-source) — optimized (reordering +
//! bitvector) vs Ligra-style baseline. Paper shape: ≈1x on LiveJournal
//! (reordering can even lose when the graph is already BFS-ordered),
//! growing to ~1.5x on RMAT27.

mod common;

use cagra::apps::bc;
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;

fn main() {
    common::run_suite("table5_bfs", |s| {
        let sources_n = std::env::var("CAGRA_BFS_SOURCES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(6usize); // paper uses 12; scaled default 6
        let mut table = Table::new(&["Dataset", "Optimized", "Ligra-style (baseline)"]);
        s.cap_reps(3);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            let sources = bc::default_sources(g, sources_n);
            s.set_scope(name);
            // Both variants run through the app registry pipeline.
            let cfg = common::config();
            let opt = common::time_app_sources(s, "optimized", g, &cfg, "bfs", "both", &sources);
            let base = common::time_app_sources(s, "ligra", g, &cfg, "bfs", "baseline", &sources);
            table.row(&[
                name.to_string(),
                common::cell(opt, opt),
                common::cell(base, opt),
            ]);
        }
        table.print();
        println!("\npaper (Table 5): LiveJournal 0.93x; Twitter 1.09x; RMAT25 1.24x; RMAT27 1.54x (Ligra vs optimized), 12 sources");
    });
}
