//! Shared helpers for the per-table/figure bench targets.
#![allow(dead_code)] // each bench target uses a different subset

use cagra::apps::pagerank;
use cagra::coordinator::SystemConfig;
use cagra::graph::datasets::{self, Dataset};

/// Load a dataset at the bench scale (`CAGRA_BENCH_SCALE`).
pub fn load(name: &str) -> Dataset {
    datasets::load_scaled(name, cagra::bench::scale())
        .unwrap_or_else(|e| panic!("loading {name}: {e:#}"))
}

/// The standard config every bench uses (effective LLC = this host's L2).
pub fn config() -> SystemConfig {
    SystemConfig::default()
}

/// Median per-iteration seconds of a prepared PageRank variant.
pub fn time_pagerank_iter(
    b: &mut cagra::bench::Bencher,
    label: &str,
    g: &cagra::graph::Csr,
    cfg: &SystemConfig,
    variant: pagerank::Variant,
) -> f64 {
    let mut prep = pagerank::Prepared::new(g, cfg, variant);
    prep.reset();
    let m = b.bench_work(label, Some(g.num_edges() as u64), &mut || prep.step());
    m.secs()
}

/// Simulated stall estimate for one frontier-app pull sweep (BC/BFS,
/// Tables 7/8): per destination, read each in-neighbor's frontier flag
/// (dense byte, or packed bit when `bitvector`) plus `vertex_elem` bytes
/// of per-vertex data (σ for BC; 0 for BFS's activeness-only sweep).
pub fn frontier_stall_estimate(
    g_pull: &cagra::graph::Csr,
    vertex_elem: u64,
    bitvector: bool,
    llc_bytes: usize,
    sample_every: usize,
) -> cagra::cache::StallEstimate {
    use cagra::cache::trace::{Access, EDGE_BASE, OUT_BASE, VERTEX_BASE};
    let step = sample_every.max(1);
    let frontier_base: u64 = 1 << 43;
    let mut trace = Vec::new();
    for v in (0..g_pull.num_vertices()).step_by(step) {
        let lo = g_pull.offsets[v];
        for (k, &u) in g_pull.neighbors(v as u32).iter().enumerate() {
            trace.push(Access::EdgeRead(EDGE_BASE + (lo + k as u64) * 4));
            // Frontier membership probe (the bitvector optimization
            // shrinks this footprint 8x).
            let faddr = if bitvector { u as u64 / 8 } else { u as u64 };
            trace.push(Access::VertexRead(frontier_base + faddr));
            if vertex_elem > 0 {
                trace.push(Access::VertexRead(VERTEX_BASE + u as u64 * vertex_elem));
            }
        }
        trace.push(Access::OutWrite(OUT_BASE + v as u64 * 8));
    }
    let mut hier = cagra::cache::Hierarchy::scaled_default(llc_bytes);
    cagra::cache::stall::estimate(&trace, &mut hier, cagra::cache::StallModel::default())
}

/// Format "0.141s (1.75x)" like the paper's tables.
pub fn cell(secs: f64, baseline: f64) -> String {
    format!(
        "{} {}",
        cagra::bench::table::fmt_secs(secs),
        cagra::bench::table::fmt_factor(secs / baseline)
    )
}
