//! Shared helpers for the per-table/figure bench targets.
//!
//! Every target's `main` is a thin wrapper around [`run_suite`]: the
//! shared runner (re-exported from `cagra::bench::suite`) prints the
//! header, threads one [`Suite`] through the body so every timed or
//! simulated case lands in the suite's report, and emits
//! `BENCH_<suite>.json` (see `CAGRA_BENCH_OUT`) alongside the tables.
#![allow(dead_code)] // each bench target uses a different subset

use cagra::apps::{registry, AppKind, PreparedApp};
use cagra::coordinator::SystemConfig;
use cagra::graph::datasets::{self, Dataset};

pub use cagra::bench::suite::Suite;

/// Run `body` under the registered suite `name` and emit its report.
pub fn run_suite(name: &str, body: impl FnOnce(&mut Suite)) {
    cagra::bench::suite::run(name, body)
}

/// Load a dataset at the bench scale (`CAGRA_BENCH_SCALE`).
pub fn load(name: &str) -> Dataset {
    datasets::load_scaled(name, cagra::bench::scale())
        .unwrap_or_else(|e| panic!("loading {name}: {e:#}"))
}

/// The standard config every bench uses (effective LLC = this host's L2).
pub fn config() -> SystemConfig {
    SystemConfig::default()
}

/// Prepare an app variant through the registry (no artifact store).
pub fn prepare_app(
    g: &cagra::graph::Csr,
    cfg: &SystemConfig,
    app: &str,
    variant: &str,
) -> Box<dyn PreparedApp> {
    let kind = AppKind::parse(app, variant)
        .unwrap_or_else(|e| panic!("parsing {app}/{variant}: {e:#}"));
    registry::app_for(kind)
        .prepare(g, cfg, kind, &cagra::store::StoreCtx::disabled())
        .unwrap_or_else(|e| panic!("preparing {app}/{variant}: {e:#}"))
}

/// Median per-iteration seconds of an iterative app variant prepared
/// through the registry, recorded under the suite's current scope.
pub fn time_app_iter(
    s: &mut Suite,
    label: &str,
    g: &cagra::graph::Csr,
    cfg: &SystemConfig,
    app: &str,
    variant: &str,
) -> f64 {
    let mut prep = prepare_app(g, cfg, app, variant);
    let m = s.bench_work(label, Some(g.num_edges() as u64), &mut || prep.step());
    m.secs()
}

/// Median seconds for one full pass over `sources` of a per-source app
/// variant prepared through the registry.
pub fn time_app_sources(
    s: &mut Suite,
    label: &str,
    g: &cagra::graph::Csr,
    cfg: &SystemConfig,
    app: &str,
    variant: &str,
    sources: &[cagra::graph::VertexId],
) -> f64 {
    let mut prep = prepare_app(g, cfg, app, variant);
    let m = s.bench_work(label, Some(g.num_edges() as u64), &mut || {
        for &src in sources {
            prep.run_source(src);
        }
    });
    m.secs()
}

/// Format "0.141s (1.75x)" like the paper's tables.
pub fn cell(secs: f64, baseline: f64) -> String {
    format!(
        "{} {}",
        cagra::bench::table::fmt_secs(secs),
        cagra::bench::table::fmt_factor(secs / baseline)
    )
}
