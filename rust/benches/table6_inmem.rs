//! Table 6: 20 iterations of in-memory PageRank on LiveJournal —
//! GridGraph-style and X-Stream-style (out-of-core techniques applied
//! in-memory) vs GraphMat-style. The paper's point: the disk-era cache
//! frameworks are 3-4.3x *slower* than the plain in-memory SpMV even with
//! everything in RAM.

mod common;

use cagra::baselines::{graphmat_style, gridgraph_style, xstream_style};
use cagra::bench::Table;

fn main() {
    common::run_suite("table6_inmem", |s| {
        let cfg = common::config();
        let ds = common::load("livejournal-sim");
        let g = &ds.graph;
        let iters = 20;
        s.cap_reps(2);
        let gm = {
            let mut p = graphmat_style::Prepared::new(g, &cfg);
            s.bench_work("graphmat", None, &mut || {
                let _ = p.run(iters);
            })
            .secs()
        };
        let gg = {
            let mut p = gridgraph_style::Prepared::new(g, &cfg);
            s.bench_work("gridgraph", None, &mut || {
                let _ = p.run(iters);
            })
            .secs()
        };
        let xs = {
            let mut p = xstream_style::Prepared::new(g, &cfg);
            s.bench_work("xstream", None, &mut || {
                let _ = p.run(iters);
            })
            .secs()
        };
        let mut t = Table::new(&["Framework", "Running Time", "Slow Down vs GraphMat"]);
        t.row(&["GridGraph-style".into(), common::cell(gg, gg), common::cell(gg, gm)]);
        t.row(&["X-Stream-style".into(), common::cell(xs, xs), common::cell(xs, gm)]);
        t.row(&["GraphMat-style".into(), common::cell(gm, gm), "(1.00x)".into()]);
        t.print();
        println!("\npaper (Table 6): GridGraph 12.86s (3.06x), X-Stream 18.22s (4.33x), GraphMat 4.2s (1.00x)");
    });
}
