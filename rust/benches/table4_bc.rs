//! Table 4: Betweenness Centrality runtime (multi-source) — optimized
//! (reordering + bitvector) vs Ligra-style baseline. Paper shape: ~1x on
//! LiveJournal (fits cache) growing to ~2x on RMAT27.

mod common;

use cagra::apps::bc;
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;

fn main() {
    common::run_suite("table4_bc", |s| {
        let sources_n = std::env::var("CAGRA_BC_SOURCES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(4usize); // paper uses 12; scaled default 4
        let mut table = Table::new(&["Dataset", "Optimized", "Ligra-style (baseline)"]);
        s.cap_reps(3);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            let sources = bc::default_sources(g, sources_n);
            s.set_scope(name);
            // Both variants run through the app registry pipeline.
            let cfg = common::config();
            let opt = common::time_app_sources(s, "optimized", g, &cfg, "bc", "both", &sources);
            let base = common::time_app_sources(s, "ligra", g, &cfg, "bc", "baseline", &sources);
            table.row(&[
                name.to_string(),
                common::cell(opt, opt),
                common::cell(base, opt),
            ]);
        }
        table.print();
        println!("\npaper (Table 4): LiveJournal 1.00x; Twitter 1.19x; RMAT25 1.56x; RMAT27 1.95x (Ligra vs optimized), 12 sources");
    });
}
