//! Figure 7: expansion factor vs number of segments for RMAT27 and
//! Twitter in original / degree-sorted / random orders. Paper shape: q
//! stays < 5 at LLC-sized segments, grows with segment count, random
//! permutation is much worse, degree sort is best.

mod common;

use cagra::bench::Table;
use cagra::reorder::{self, Ordering as VOrdering};
use cagra::segment::expansion::expansion_sweep;

fn main() {
    common::run_suite("fig7_expansion", |s| {
        let counts = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
        for name in ["rmat27-sim", "twitter-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            println!(
                "\n{name} (V={}, d̄={:.0}):",
                g.num_vertices(),
                g.num_edges() as f64 / g.num_vertices() as f64
            );
            let mut t = Table::new(&[
                "ordering", "k=1", "2", "4", "8", "16", "32", "64", "128", "256",
            ]);
            for &o in &[VOrdering::Identity, VOrdering::DegreeSort, VOrdering::Random] {
                let (h, _) = reorder::reorder(g, o);
                let sweep = expansion_sweep(&h, &counts);
                s.set_scope(&format!("{name}/{}", o.name()));
                for &(k, q) in &sweep {
                    s.record(&format!("k={k}"), "q", q);
                }
                let mut row = vec![o.name().to_string()];
                row.extend(sweep.iter().map(|(_, q)| format!("{q:.2}")));
                t.row(&row);
            }
            t.print();
            // Mark the LLC-sized segment count.
            let cfg = common::config();
            let k_llc = g.num_vertices().div_ceil(cfg.segment_size(8));
            println!("LLC-sized segments for 8B/vertex: k = {k_llc}");
        }
        println!("\npaper (Figure 7): q < 5 at LLC-sized segments; random order much worse; sorting best (esp. Twitter)");
    });
}
