//! Table 3: Collaborative Filtering runtime per iteration — optimized
//! (segmented) vs our baseline vs GraphMat-style, on the Netflix family.
//! Paper shape: the optimized/GraphMat gap grows with the expansion
//! factor (2.50x → 4.35x from Netflix to Netflix4x).

mod common;

use cagra::bench::{header, Bencher, Table};
use cagra::graph::datasets::CF_DATASETS;

fn main() {
    header("Table 3: Collaborative Filtering per-iteration runtime", "paper Table 3");
    let cfg = common::config();
    let mut table = Table::new(&["Dataset", "Optimized", "Our Baseline (GraphMat-shape)"]);
    for name in CF_DATASETS {
        let ds = common::load(name);
        let g = &ds.graph;
        let mut b = Bencher::new();
        // Reps trimmed: CF iterations are heavy on the 4x dataset.
        b.reps = b.reps.min(3);
        // Both variants run through the app registry pipeline.
        let opt = common::time_app_iter(&mut b, "optimized", g, &cfg, "cf", "segmenting");
        let base = common::time_app_iter(&mut b, "baseline", g, &cfg, "cf", "baseline");
        table.row(&[
            name.to_string(),
            common::cell(opt, opt),
            common::cell(base, opt),
        ]);
    }
    table.print();
    println!("\npaper (Table 3): Netflix 0.20s/1.56x/2.50x; Netflix4x 1.61s/2.80x/4.35x (Optimized/OurBaseline/GraphMat)");
}
