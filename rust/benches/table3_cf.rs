//! Table 3: Collaborative Filtering runtime per iteration — optimized
//! (segmented) vs our baseline vs GraphMat-style, on the Netflix family.
//! Paper shape: the optimized/GraphMat gap grows with the expansion
//! factor (2.50x → 4.35x from Netflix to Netflix4x).

mod common;

use cagra::bench::Table;
use cagra::graph::datasets::CF_DATASETS;

fn main() {
    common::run_suite("table3_cf", |s| {
        let cfg = common::config();
        let mut table = Table::new(&["Dataset", "Optimized", "Our Baseline (GraphMat-shape)"]);
        // Reps trimmed: CF iterations are heavy on the 4x dataset.
        s.cap_reps(3);
        for name in CF_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            s.set_scope(name);
            // Both variants run through the app registry pipeline.
            let opt = common::time_app_iter(s, "optimized", g, &cfg, "cf", "segmenting");
            let base = common::time_app_iter(s, "baseline", g, &cfg, "cf", "baseline");
            table.row(&[
                name.to_string(),
                common::cell(opt, opt),
                common::cell(base, opt),
            ]);
        }
        table.print();
        println!("\npaper (Table 3): Netflix 0.20s/1.56x/2.50x; Netflix4x 1.61s/2.80x/4.35x (Optimized/OurBaseline/GraphMat)");
    });
}
