//! Figure 3: fraction of cycles stalled on memory per application —
//! PageRank, CF, BC, BFS on their baseline implementations. Paper: 60-80%
//! across the board. We report simulated stall cycles over simulated
//! total cycles (stalls + a per-access compute allowance).

mod common;

use cagra::bench::Table;

/// Compute cycles per memory access the ALU work roughly costs in these
/// kernels (one FMA + bookkeeping); only the *ratio* matters.
const COMPUTE_PER_ACCESS: f64 = 1.5;

fn main() {
    common::run_suite("fig3_stalls", |s| {
        let cfg = common::config();
        let mut t = Table::new(&["App", "Dataset", "stall %"]);
        // PageRank + CF on their natural datasets.
        let g = common::load("rmat27-sim");
        let pull = g.graph.transpose();
        let sample = (g.graph.num_edges() / 4_000_000).max(1);
        let pr = cagra::cache::stall::estimate_pull_iteration(&pull, 8, cfg.llc_bytes, sample);
        let pr_pct = stall_pct(pr.stall_cycles, pr.accesses);
        s.set_scope("pagerank");
        s.record("rmat27-sim", "stall-pct", pr_pct);
        t.row(&["PageRank".into(), "rmat27-sim".into(), format!("{pr_pct:.0}%")]);
        let nf = common::load("netflix-sim");
        let nf_pull = nf.graph.transpose();
        let cf = cagra::cache::stall::estimate_pull_iteration(
            &nf_pull,
            (8 * cfg.cf_k) as u64,
            cfg.llc_bytes,
            1,
        );
        let cf_pct = stall_pct(cf.stall_cycles, cf.accesses);
        s.set_scope("cf");
        s.record("netflix-sim", "stall-pct", cf_pct);
        t.row(&["CF".into(), "netflix-sim".into(), format!("{cf_pct:.0}%")]);
        let bc = common::frontier_stall_estimate(&pull, 8, false, cfg.llc_bytes, sample);
        let bc_pct = stall_pct(bc.stall_cycles, bc.accesses);
        s.set_scope("bc");
        s.record("rmat27-sim", "stall-pct", bc_pct);
        t.row(&["BC".into(), "rmat27-sim".into(), format!("{bc_pct:.0}%")]);
        let bfs = common::frontier_stall_estimate(&pull, 4, false, cfg.llc_bytes, sample);
        let bfs_pct = stall_pct(bfs.stall_cycles, bfs.accesses);
        s.set_scope("bfs");
        s.record("rmat27-sim", "stall-pct", bfs_pct);
        t.row(&["BFS".into(), "rmat27-sim".into(), format!("{bfs_pct:.0}%")]);
        t.print();
        println!("\npaper (Figure 3): 60-80% of cycles stalled on memory for these applications");
    });
}

fn stall_pct(stall_cycles: f64, accesses: u64) -> f64 {
    let compute = accesses as f64 * COMPUTE_PER_ACCESS;
    stall_cycles / (stall_cycles + compute) * 100.0
}
