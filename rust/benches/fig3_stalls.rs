//! Figure 3: fraction of cycles stalled on memory per application —
//! PageRank, CF, BC, BFS on their baseline implementations. Paper: 60-80%
//! across the board. We report simulated stall cycles over simulated
//! total cycles (stalls + a per-access compute allowance).

mod common;

use cagra::bench::{header, Table};

/// Compute cycles per memory access the ALU work roughly costs in these
/// kernels (one FMA + bookkeeping); only the *ratio* matters.
const COMPUTE_PER_ACCESS: f64 = 1.5;

fn main() {
    header("Figure 3: % cycles stalled on memory (simulated)", "paper Figure 3");
    let cfg = common::config();
    let mut t = Table::new(&["App", "Dataset", "stall %"]);
    // PageRank + CF on their natural datasets.
    let g = common::load("rmat27-sim");
    let pull = g.graph.transpose();
    let sample = (g.graph.num_edges() / 4_000_000).max(1);
    let pr = cagra::cache::stall::estimate_pull_iteration(&pull, 8, cfg.llc_bytes, sample);
    t.row(&[
        "PageRank".into(),
        "rmat27-sim".into(),
        format!(
            "{:.0}%",
            stall_pct(pr.stall_cycles, pr.accesses)
        ),
    ]);
    let nf = common::load("netflix-sim");
    let nf_pull = nf.graph.transpose();
    let cf = cagra::cache::stall::estimate_pull_iteration(
        &nf_pull,
        (8 * cfg.cf_k) as u64,
        cfg.llc_bytes,
        1,
    );
    t.row(&[
        "CF".into(),
        "netflix-sim".into(),
        format!("{:.0}%", stall_pct(cf.stall_cycles, cf.accesses)),
    ]);
    let bc = common::frontier_stall_estimate(&pull, 8, false, cfg.llc_bytes, sample);
    t.row(&[
        "BC".into(),
        "rmat27-sim".into(),
        format!("{:.0}%", stall_pct(bc.stall_cycles, bc.accesses)),
    ]);
    let bfs = common::frontier_stall_estimate(&pull, 4, false, cfg.llc_bytes, sample);
    t.row(&[
        "BFS".into(),
        "rmat27-sim".into(),
        format!("{:.0}%", stall_pct(bfs.stall_cycles, bfs.accesses)),
    ]);
    t.print();
    println!("\npaper (Figure 3): 60-80% of cycles stalled on memory for these applications");
}

fn stall_pct(stall_cycles: f64, accesses: u64) -> f64 {
    let compute = accesses as f64 * COMPUTE_PER_ACCESS;
    stall_cycles / (stall_cycles + compute) * 100.0
}
