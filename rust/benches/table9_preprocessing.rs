//! Table 9: one-time preprocessing costs — parallel stable coarse degree
//! sort (reordering), segment building, and baseline CSR construction.
//! Paper shape: reordering < segmenting < CSR build, all a small multiple
//! of one PageRank iteration.

mod common;

use cagra::bench::{header, table::fmt_secs, Bencher, Table};
use cagra::graph::Csr;
use cagra::reorder;
use cagra::segment::SegmentedCsr;

fn main() {
    header("Table 9: preprocessing runtime", "paper Table 9");
    let cfg = common::config();
    let mut t = Table::new(&["Dataset", "Reordering", "Segmenting", "Build CSR", "1 PR iter"]);
    for name in ["livejournal-sim", "twitter-sim", "rmat27-sim"] {
        let ds = common::load(name);
        let g = &ds.graph;
        let edges: Vec<_> = g.edges().collect();
        let mut b = Bencher::new();
        b.reps = b.reps.min(3);
        let reord = b
            .bench("reorder", || {
                let _ = reorder::degree_sort_perm(g, cfg.coarsen);
            })
            .secs();
        let seg = b
            .bench("segment", || {
                let _ = SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8));
            })
            .secs();
        let csr = b
            .bench("csr", || {
                let _ = Csr::from_edges(g.num_vertices(), &edges);
            })
            .secs();
        let iter = common::time_pagerank_iter(
            &mut b,
            "pr-iter",
            g,
            &cfg,
            cagra::apps::pagerank::Variant::Baseline,
        );
        t.row(&[
            name.to_string(),
            fmt_secs(reord),
            fmt_secs(seg),
            fmt_secs(csr),
            fmt_secs(iter),
        ]);
    }
    t.print();
    println!("\npaper (Table 9): Twitter 0.5s / 3.8s / 12.7s; RMAT27 1.4s / 6.3s / 39.3s");
    println!("(GridGraph's own grid build took 193s for Twitter — our gridgraph_style::Grid::build is measured in fig1)");
}
