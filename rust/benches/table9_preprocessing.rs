//! Table 9: one-time preprocessing costs — parallel stable coarse degree
//! sort (reordering), segment building, and baseline CSR construction.
//! Paper shape: reordering < segmenting < CSR build, all a small multiple
//! of one PageRank iteration.
//!
//! Extended with the artifact store's amortization: "Seg cold" is the
//! first `get_or_build` (build + encode + persist), "Seg warm" is a store
//! hit (read + decode) — the cost the *second and every later* run pays.
//! The paper argues preprocessing "can be amortized across many runs";
//! warm ÷ cold is that amortization made measurable.
//!
//! "Load warm" is the warm dataset load itself: `datasets::load_scaled`
//! serves the cached finished-CSR artifact — mapped in place where the
//! platform supports it, decoded otherwise — so, unlike the "Build CSR"
//! column it sits next to, it contains **zero** edge→CSR build work.
//! Before the dataset CSR cache landed, every "warm" load still paid the
//! full `Csr::from_edges` pass this column now excludes.
//!
//! "Seg warm" vs "Seg warm map" splits the warm hit by load path: the
//! former forces read-and-decode (`--no-mmap` behaviour, O(|E|)), the
//! latter mmaps the v2 artifact and hands its arrays out in place —
//! zero decoded bytes, and O(1) once the mapping is validated. Their
//! ratio is the zero-copy warm start's payoff.

mod common;

use cagra::bench::{table::fmt_secs, Table};
use cagra::graph::{datasets, Csr};
use cagra::reorder;
use cagra::segment::SegmentedCsr;
use cagra::store::{fingerprint, ArtifactStore, StoreKey};
use cagra::util::timer::time;

fn main() {
    common::run_suite("table9_preprocessing", |s| {
        let cfg = common::config();
        let store_dir =
            std::env::temp_dir().join(format!("cagra-table9-store-{}", std::process::id()));
        std::fs::remove_dir_all(&store_dir).ok();
        let store = ArtifactStore::open(&store_dir, 0).expect("opening artifact store");
        let mut t = Table::new(&[
            "Dataset",
            "Reordering",
            "Segmenting",
            "Build CSR",
            "Load warm",
            "Seg cold",
            "Seg warm",
            "Seg warm map",
            "1 PR iter",
        ]);
        s.cap_reps(3);
        for name in ["livejournal-sim", "twitter-sim", "rmat27-sim"] {
            let ds = common::load(name);
            let g = &ds.graph;
            let edges: Vec<_> = g.edges().collect();
            s.set_scope(name);
            let reord = s
                .bench("reorder", || {
                    let _ = reorder::degree_sort_perm(g, cfg.coarsen);
                })
                .secs();
            let seg = s
                .bench("segment", || {
                    let _ =
                        SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8));
                })
                .secs();
            let csr = s
                .bench("csr", || {
                    let _ = Csr::from_edges(g.num_vertices(), &edges);
                })
                .secs();
            // Warm dataset load: decodes the finished-CSR artifact that
            // common::load's cold pass persisted — no from_edges work.
            let load_warm = s
                .bench("load-warm", || {
                    let _ = datasets::load_scaled(name, cagra::bench::scale())
                        .expect("warm dataset load");
                })
                .secs();
            // Amortization measurement. Cold must run exactly once (a second
            // rep would hit the store), so it is timed single-shot; warm reps
            // all hit.
            let fp = fingerprint::fingerprint_dataset(name, cagra::bench::scale(), g);
            let key = StoreKey::segmented(fp, "table9", cfg.segment_size(8), cfg.merge_block(8));
            let (_, cold) = time(|| {
                store.get_or_build(&key, || {
                    SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8))
                })
            });
            s.record("seg-cold", "s", cold);
            // Decoded warm hit (the pre-mmap behaviour / `--no-mmap`):
            // read the file and copy every section into owned storage.
            store.set_mmap_enabled(false);
            let warm = s
                .bench("seg-warm", || {
                    let _ = store.get_or_build(&key, || {
                        SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8))
                    });
                })
                .secs();
            // Mapped warm hit: arrays served in place from the mapping —
            // zero decoded bytes (falls back to decode off-Linux, where
            // the two columns then read alike).
            store.set_mmap_enabled(true);
            let warm_mapped = s
                .bench("seg-warm-mapped", || {
                    let _ = store.get_or_build(&key, || {
                        SegmentedCsr::build_with_block(g, cfg.segment_size(8), cfg.merge_block(8))
                    });
                })
                .secs();
            let iter = common::time_app_iter(s, "pr-iter", g, &cfg, "pagerank", "baseline");
            t.row(&[
                name.to_string(),
                fmt_secs(reord),
                fmt_secs(seg),
                fmt_secs(csr),
                fmt_secs(load_warm),
                fmt_secs(cold),
                fmt_secs(warm),
                fmt_secs(warm_mapped),
                fmt_secs(iter),
            ]);
        }
        t.print();
        let stats = store.stats();
        println!(
            "\nartifact store: {} hits / {} misses, {} written, {} decoded, {} mapped",
            stats.hits,
            stats.misses,
            cagra::util::fmt_bytes(stats.bytes_written as usize),
            cagra::util::fmt_bytes(stats.bytes_read as usize),
            cagra::util::fmt_bytes(stats.bytes_mapped as usize)
        );
        println!("paper (Table 9): Twitter 0.5s / 3.8s / 12.7s; RMAT27 1.4s / 6.3s / 39.3s");
        println!("(GridGraph's own grid build took 193s for Twitter — our gridgraph_style::Grid::build is measured in fig1)");
        std::fs::remove_dir_all(&store_dir).ok();
    });
}
