//! Table 8: simulated stall cycles for BFS under the optimization grid.
//! BFS is activeness-only (no per-vertex payload beyond the parent
//! check, modeled as 4B), so the absolute stalls are smaller than BC's
//! (Table 7) and the bitvector optimization matters relatively more.

mod common;

use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;
use cagra::reorder::{self, Ordering as VOrdering};

const VARIANTS: [&str; 4] = ["baseline", "reordering", "bitvector", "reordering+bitvector"];

fn main() {
    common::run_suite("table8_bfs_stalls", |s| {
        let cfg = common::config();
        let mut t = Table::new(&[
            "Dataset",
            "Baseline",
            "Reordering",
            "Bitvector",
            "Reordering+Bitvector",
        ]);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            let sample = (g.num_edges() / 4_000_000).max(1);
            let pull = g.transpose();
            let (reord, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            let reord_pull = reord.transpose();
            // BFS: parent probe (4B) + frontier per edge.
            let cells: Vec<f64> = [
                common::frontier_stall_estimate(&pull, 4, false, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&reord_pull, 4, false, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&pull, 4, true, cfg.llc_bytes, sample),
                common::frontier_stall_estimate(&reord_pull, 4, true, cfg.llc_bytes, sample),
            ]
            .iter()
            .map(|e| e.stall_cycles * sample as f64 / 1e9)
            .collect();
            s.set_scope(name);
            for (variant, cell) in VARIANTS.iter().zip(&cells) {
                s.record(variant, "GCycles", *cell);
            }
            t.row(&[
                name.to_string(),
                format!("{:.2}B", cells[0]),
                format!("{:.2}B", cells[1]),
                format!("{:.2}B", cells[2]),
                format!("{:.2}B", cells[3]),
            ]);
        }
        t.print();
        println!("\npaper (Table 8, billions of stall cycles): RMAT27 row 3,711 / 2,056 / 2,316 / 1,728");
        println!("(absolute magnitudes differ — scaled datasets and one sweep vs the paper's full runs; the column ordering is the reproduced shape)");
    });
}
