//! Table 8: simulated stall cycles for BFS under the optimization grid,
//! routed through the registry's per-app `GraphApp::simulate` (the same
//! estimate `cagra run --analyze` reports). BFS is activeness-only (no
//! per-vertex payload beyond the 4B parent check), so the absolute
//! stalls are smaller than BC's (Table 7) and the bitvector
//! optimization matters relatively more.

mod common;

use cagra::apps::{registry, AppKind};
use cagra::bench::Table;
use cagra::graph::datasets::GRAPH_DATASETS;

const VARIANTS: [&str; 4] = ["baseline", "reordering", "bitvector", "reordering+bitvector"];

fn main() {
    common::run_suite("table8_bfs_stalls", |s| {
        let cfg = common::config();
        let mut t = Table::new(&[
            "Dataset",
            "Baseline",
            "Reordering",
            "Bitvector",
            "Reordering+Bitvector",
        ]);
        for name in GRAPH_DATASETS {
            let ds = common::load(name);
            let g = &ds.graph;
            // BFS: parent probe (4B) + frontier per edge; see apps::bfs::App::simulate.
            let cells: Vec<f64> = VARIANTS
                .iter()
                .map(|variant| {
                    let kind = AppKind::parse("bfs", variant)
                        .unwrap_or_else(|e| panic!("parsing bfs/{variant}: {e:#}"));
                    let est = registry::app_for(kind)
                        .simulate(g, &cfg, kind)
                        .expect("bfs registers a simulation");
                    est.stall_cycles / 1e9
                })
                .collect();
            s.set_scope(name);
            for (variant, cell) in VARIANTS.iter().zip(&cells) {
                s.record(variant, "GCycles", *cell);
            }
            t.row(&[
                name.to_string(),
                format!("{:.2}B", cells[0]),
                format!("{:.2}B", cells[1]),
                format!("{:.2}B", cells[2]),
                format!("{:.2}B", cells[3]),
            ]);
        }
        t.print();
        println!("\npaper (Table 8, billions of stall cycles): RMAT27 row 3,711 / 2,056 / 2,316 / 1,728");
        println!("(absolute magnitudes differ — scaled datasets and one sweep vs the paper's full runs; the column ordering is the reproduced shape)");
    });
}
