//! Serving throughput: the `cagra serve` worker pool driven closed-loop,
//! cold (fresh pool, empty artifact layer — every request pays dataset
//! load + CSR decode + preprocessing) vs resident (warm shared layer —
//! requests reuse pinned artifacts and the engines' zero-allocation
//! steady state). Records jobs/sec and p50/p99 request latency per
//! scope; the resident/cold gap is the whole point of the daemon.
//!
//! Runs in-process against [`WorkerPool`] directly (no TCP), so the
//! numbers isolate the execution pipeline from socket noise; `cagra
//! loadgen` measures the same loop end-to-end over the wire.

mod common;

use cagra::bench::suite::Suite;
use cagra::coordinator::JobSpec;
use cagra::serve::loadgen::percentile;
use cagra::serve::{Outcome, WorkerPool};
use std::time::Instant;

fn request_spec() -> JobSpec {
    JobSpec {
        dataset: "livejournal-sim".into(),
        scale: cagra::bench::scale(),
        iters: 2,
        ..Default::default()
    }
}

/// Closed loop: `clients` threads each issue `per_client` requests
/// back-to-back. Returns (elapsed seconds, per-request latencies).
fn closed_loop(pool: &WorkerPool, clients: usize, per_client: usize) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let latencies = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                scope.spawn(|| {
                    let mut lat = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let t = Instant::now();
                        match pool.run_sync(request_spec(), None).expect("admission") {
                            Outcome::Done { result, .. } => {
                                result.expect("job failed");
                            }
                            other => panic!("unexpected outcome {other:?}"),
                        }
                        lat.push(t.elapsed().as_secs_f64());
                    }
                    lat
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect::<Vec<f64>>()
    });
    (t0.elapsed().as_secs_f64(), latencies)
}

fn record_round(s: &mut Suite, elapsed: f64, mut latencies: Vec<f64>) {
    latencies.sort_by(f64::total_cmp);
    s.record(
        "jobs-per-sec",
        "jobs/s",
        latencies.len() as f64 / elapsed.max(1e-9),
    );
    s.record("p50-ms", "ms", percentile(&latencies, 50.0) * 1e3);
    s.record("p99-ms", "ms", percentile(&latencies, 99.0) * 1e3);
}

fn main() {
    common::run_suite("serve_throughput", |s| {
        let cfg = common::config();

        // Cold: each request is the *first* one a fresh pool (empty
        // artifact layer) ever sees, so it pays the full load + decode +
        // preprocess path.
        s.set_scope("cold");
        let rounds = 3;
        let mut cold_lat = Vec::with_capacity(rounds);
        let cold_t0 = Instant::now();
        for _ in 0..rounds {
            let pool = WorkerPool::start(cfg.clone(), 2, 16, 0).expect("starting pool");
            let (_, lat) = closed_loop(&pool, 1, 1);
            cold_lat.extend(lat);
            pool.shutdown();
        }
        record_round(s, cold_t0.elapsed().as_secs_f64(), cold_lat);

        // Resident: one long-lived pool, warmed, then measured under
        // concurrent closed-loop clients.
        s.set_scope("resident");
        let pool = WorkerPool::start(cfg, 2, 16, 0).expect("starting pool");
        closed_loop(&pool, 1, 2); // warm the shared layer (unmeasured)
        let (elapsed, lat) = closed_loop(&pool, 2, 4);
        let mem = pool.mem_stats();
        assert!(
            mem.hits > 0,
            "resident rounds must hit the in-memory layer: {mem:?}"
        );
        pool.shutdown();
        record_round(s, elapsed, lat);
    });
}
