//! Section 5 validation: the analytical cache model vs the trace-driven
//! simulator. The paper reports the model "predicts within 5% of the
//! simulated cache miss rates" (Dinero IV); we replicate with our own
//! set-associative LRU simulator across graphs, orderings, and cache
//! sizes, and additionally verify the Proposition 2 ordering claim
//! empirically.

mod common;

use cagra::bench::Table;
use cagra::cache::model::{predicted_miss_rate, CacheGeometry};
use cagra::cache::sim::CacheSim;
use cagra::cache::trace::vertex_trace;
use cagra::reorder::{self, Ordering as VOrdering};

fn main() {
    common::run_suite("model_validation", |s| {
        let mut t = Table::new(&["graph", "ordering", "cache", "simulated", "model", "|err| pp"]);
        let mut worst: f64 = 0.0;
        let mut worst_random: f64 = 0.0;
        for name in ["rmat25-sim", "twitter-sim"] {
            let ds = common::load(name);
            for &o in &[VOrdering::Identity, VOrdering::DegreeSort, VOrdering::Random] {
                let (h, _) = reorder::reorder(&ds.graph, o);
                let pull = h.transpose();
                let sample = (h.num_edges() / 2_000_000).max(1);
                let stream = vertex_trace(&pull, 8, sample);
                let weights: Vec<u64> = h.out_degrees().iter().map(|&d| d as u64).collect();
                s.set_scope(&format!("{name}/{}", o.name()));
                for kib in [32usize, 64, 128] {
                    let geom = CacheGeometry::new(kib * 1024, 16, 64);
                    let mut sim = CacheSim::new(geom);
                    for &a in &stream {
                        sim.access(a);
                    }
                    let model = predicted_miss_rate(&weights, 8, geom);
                    let err = (sim.miss_rate() - model).abs() * 100.0;
                    worst = worst.max(err);
                    if o == VOrdering::Random {
                        worst_random = worst_random.max(err);
                    }
                    s.record(&format!("{kib}KiB"), "pp", err);
                    t.row(&[
                        name.to_string(),
                        o.name().to_string(),
                        format!("{kib} KiB"),
                        format!("{:.1}%", sim.miss_rate() * 100.0),
                        format!("{:.1}%", model * 100.0),
                        format!("{err:.1}"),
                    ]);
                }
            }
        }
        t.print();
        println!("\nworst |error|: {worst:.1} percentage points");
        println!("within-5% claim holds in the model's own regime (working set >> cache, independent accesses = random order rows); degree-sorted rows overshoot because sorting *creates* the temporal locality the independence assumption ignores — the community-structure bias the paper itself notes (Section 5).");
        println!("note: community structure (ignored by the independent-access model) makes the simulator *hit more* than predicted on BFS-ordered graphs — the same bias the paper describes.");

        // Proposition 2 spot-check: degree sort beats 50 random permutations.
        let ds = common::load("rmat25-sim");
        let weights: Vec<u64> = ds.graph.out_degrees().iter().map(|&d| d as u64).collect();
        let geom = CacheGeometry::new(512 * 1024, 16, 64);
        let mut sorted = weights.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let best = predicted_miss_rate(&sorted, 8, geom);
        let mut rng = cagra::util::rng::Rng::new(7);
        let mut beaten = 0;
        for _ in 0..50 {
            let perm = rng.permutation(weights.len());
            let m = cagra::cache::model::predicted_miss_rate_permuted(&weights, &perm, 8, geom);
            if m < best {
                beaten += 1;
            }
        }
        s.set_scope("");
        s.record("worst-random-pp", "pp", worst_random);
        s.record("prop2-beaten", "count", beaten as f64);
        println!("random-order (iid-assumption) worst |error|: {worst_random:.1} pp (paper claim: <5)");
        assert!(worst_random < 6.0, "model outside tolerance in its own regime");
        println!("\nProposition 2 check: degree-sorted layout predicted miss {best:.3}; beaten by {beaten}/50 random permutations (expect 0)");
        assert_eq!(beaten, 0, "a random permutation beat the degree sort");
    });
}
