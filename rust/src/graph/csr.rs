//! Compressed Sparse Row graphs (§2.1).
//!
//! `offsets[v]..offsets[v+1]` indexes `targets` with vertex `v`'s
//! out-neighbors. For pull-style algorithms (PageRank reads the ranks of
//! in-neighbors) the same struct stores the transpose — by convention the
//! apps keep both directions when needed.

use super::{Edge, VertexId};
use crate::parallel::{parallel_for, parallel_ranges, UnsafeSlice};
use crate::store::ArcSlice;
use std::sync::atomic::{AtomicU32, Ordering};

/// An immutable CSR graph (out-edge adjacency unless stated otherwise).
///
/// The arrays are [`ArcSlice`]s: heap-owned when built from edges,
/// mmap-backed windows when warm-loaded from a v2 artifact (DESIGN.md
/// §6). Both deref to `&[_]`, clones are O(1), and equality is by
/// contents, so callers never observe the difference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    /// `offsets.len() == num_vertices + 1`.
    pub offsets: ArcSlice<u64>,
    /// Neighbor ids, grouped by source vertex.
    pub targets: ArcSlice<VertexId>,
}

impl Csr {
    /// Build from an unsorted edge list. Edges are bucket-sorted by source
    /// with a parallel counting pass. Does **not** dedup (see
    /// [`Csr::dedup`]); use [`CsrBuilder`] for the full clean-up pipeline.
    pub fn from_edges(num_vertices: usize, edges: &[Edge]) -> Csr {
        let n = num_vertices;
        // Count out-degrees (atomically; edge lists are large).
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        parallel_for(edges.len(), |i| {
            let (s, _) = edges[i];
            counts[s as usize].fetch_add(1, Ordering::Relaxed);
        });
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut acc = 0u64;
        for c in &counts {
            acc += c.load(Ordering::Relaxed) as u64;
            offsets.push(acc);
        }
        // Scatter edges into place; per-vertex write cursor.
        let cursors: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let mut targets = vec![0 as VertexId; edges.len()];
        let tslice = UnsafeSlice::new(&mut targets);
        parallel_for(edges.len(), |i| {
            let (s, d) = edges[i];
            let k = cursors[s as usize].fetch_add(1, Ordering::Relaxed) as u64;
            let idx = offsets[s as usize] + k;
            // SAFETY: offsets[s] + unique-cursor-ticket < offsets[s+1] ≤
            // targets.len(), and the atomic fetch_add hands each edge of
            // `s` a distinct k — so every write hits a distinct in-bounds
            // index.
            unsafe { tslice.write(idx as usize, d) };
        });
        Csr {
            offsets: offsets.into(),
            targets: targets.into(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// All out-degrees as a vector.
    pub fn out_degrees(&self) -> Vec<u32> {
        (0..self.num_vertices())
            .map(|v| self.degree(v as VertexId))
            .collect()
    }

    /// In-degrees (degree of each vertex in the transpose).
    pub fn in_degrees(&self) -> Vec<u32> {
        let counts: Vec<AtomicU32> = (0..self.num_vertices()).map(|_| AtomicU32::new(0)).collect();
        parallel_for(self.targets.len(), |i| {
            counts[self.targets[i] as usize].fetch_add(1, Ordering::Relaxed);
        });
        counts.into_iter().map(|c| c.into_inner()).collect()
    }

    /// Transpose: edge (u,v) becomes (v,u). Neighbor lists in the result
    /// are sorted by construction order (stable per source bucket).
    pub fn transpose(&self) -> Csr {
        let n = self.num_vertices();
        let in_deg = self.in_degrees();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut acc = 0u64;
        for &d in &in_deg {
            acc += d as u64;
            offsets.push(acc);
        }
        let cursors: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let mut targets = vec![0 as VertexId; self.num_edges()];
        let tslice = UnsafeSlice::new(&mut targets);
        // Parallel over source ranges so edge order within a destination
        // bucket is deterministic enough for tests after sorting.
        parallel_ranges(n, |lo, hi| {
            for u in lo..hi {
                for &v in self.neighbors(u as VertexId) {
                    let k = cursors[v as usize].fetch_add(1, Ordering::Relaxed) as u64;
                    let idx = offsets[v as usize] + k;
                    // SAFETY: idx = offsets[v] + unique cursor ticket for
                    // v, so writes are disjoint and < offsets[v+1] ≤
                    // targets.len() (offsets built from in-degrees).
                    unsafe { tslice.write(idx as usize, u as VertexId) };
                }
            }
        });
        Csr {
            offsets: offsets.into(),
            targets: targets.into(),
        }
    }

    /// Return a copy with every neighbor list sorted (canonical form; use
    /// before equality comparisons). The storage is immutable (possibly a
    /// mapped file), so this copies the targets out before sorting.
    pub fn sorted(&self) -> Csr {
        let offsets = self.offsets.clone();
        let n = self.num_vertices();
        let mut targets = self.targets.to_vec();
        {
            let ts = UnsafeSlice::new(&mut targets);
            parallel_for(n, |v| {
                let lo = offsets[v] as usize;
                let hi = offsets[v + 1] as usize;
                if lo == hi {
                    return;
                }
                // SAFETY: neighbor ranges [lo,hi) are disjoint across v
                // (offsets are monotone) and hi ≤ targets.len(). Uses
                // slice_mut — which derives from the base pointer — not a
                // widened get_mut(lo) reference, whose provenance would
                // cover a single element.
                let slice = unsafe { ts.slice_mut(lo, hi - lo) };
                slice.sort_unstable();
            });
        }
        Csr {
            offsets,
            targets: targets.into(),
        }
    }

    /// Iterate all edges (u, v).
    pub fn edges(&self) -> impl Iterator<Item = Edge> + '_ {
        (0..self.num_vertices() as VertexId)
            .flat_map(move |u| self.neighbors(u).iter().map(move |&v| (u, v)))
    }

    /// Total bytes of the graph structure (for working-set reports).
    pub fn bytes(&self) -> usize {
        self.offsets.len() * 8 + self.targets.len() * 4
    }

    /// Apply a relabeling permutation: vertex `v` becomes `perm[v]`.
    /// Rebuilds the CSR so both endpoint ids and bucket order reflect the
    /// new labels (§3.2 step 3: "create a new CSR with the vertex ordered").
    pub fn relabel(&self, perm: &[VertexId]) -> Csr {
        assert_eq!(perm.len(), self.num_vertices());
        let n = self.num_vertices();
        // New degree of new-id p = old degree of old v with perm[v]=p.
        let mut inv = vec![0 as VertexId; n];
        for (v, &p) in perm.iter().enumerate() {
            inv[p as usize] = v as VertexId;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut acc = 0u64;
        for &old in &inv {
            acc += self.degree(old) as u64;
            offsets.push(acc);
        }
        let mut targets = vec![0 as VertexId; self.num_edges()];
        let ts = UnsafeSlice::new(&mut targets);
        parallel_for(n, |p| {
            let old = inv[p];
            for (idx, &w) in (offsets[p] as usize..).zip(self.neighbors(old)) {
                // SAFETY: each new-id p owns the disjoint output range
                // offsets[p]..offsets[p+1] (length = degree(old)), so
                // writes are in-bounds and race-free across the loop.
                unsafe { ts.write(idx, perm[w as usize]) };
            }
        });
        Csr {
            offsets: offsets.into(),
            targets: targets.into(),
        }
    }
}

/// Cleaning/building pipeline: collects edges, removes self-loops and
/// duplicates (the paper: "We removed duplicated edges and self loops"),
/// then produces a [`Csr`].
#[derive(Debug, Default)]
pub struct CsrBuilder {
    num_vertices: usize,
    edges: Vec<Edge>,
    remove_self_loops: bool,
    dedup: bool,
}

impl CsrBuilder {
    pub fn new(num_vertices: usize) -> CsrBuilder {
        CsrBuilder {
            num_vertices,
            edges: Vec::new(),
            remove_self_loops: true,
            dedup: true,
        }
    }

    pub fn keep_self_loops(mut self) -> Self {
        self.remove_self_loops = false;
        self
    }

    pub fn keep_duplicates(mut self) -> Self {
        self.dedup = false;
        self
    }

    pub fn add_edge(&mut self, s: VertexId, d: VertexId) -> &mut Self {
        debug_assert!((s as usize) < self.num_vertices && (d as usize) < self.num_vertices);
        self.edges.push((s, d));
        self
    }

    pub fn extend(&mut self, edges: impl IntoIterator<Item = Edge>) -> &mut Self {
        self.edges.extend(edges);
        self
    }

    pub fn build(mut self) -> Csr {
        if self.remove_self_loops {
            self.edges.retain(|&(s, d)| s != d);
        }
        if self.dedup {
            self.edges.sort_unstable();
            self.edges.dedup();
        }
        Csr::from_edges(self.num_vertices, &self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn tiny() -> Csr {
        // The paper's Figure 5 example graph: 6 vertices.
        Csr::from_edges(
            6,
            &[(0, 1), (0, 5), (1, 2), (2, 0), (3, 0), (3, 4), (4, 5), (5, 3)],
        )
    }

    #[test]
    fn basic_shape() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 6);
        assert_eq!(g.num_edges(), 8);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(2), 1);
        assert_eq!(g.neighbors(3), &[0, 4]);
    }

    #[test]
    fn transpose_reverses_edges() {
        let g = tiny();
        let t = g.transpose();
        assert_eq!(t.num_edges(), g.num_edges());
        let mut fwd: Vec<Edge> = g.edges().collect();
        let mut rev: Vec<Edge> = t.edges().map(|(a, b)| (b, a)).collect();
        fwd.sort_unstable();
        rev.sort_unstable();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn builder_removes_loops_and_dups() {
        let mut b = CsrBuilder::new(3);
        b.add_edge(0, 1).add_edge(0, 1).add_edge(1, 1).add_edge(2, 0);
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.degree(1), 0);
    }

    #[test]
    fn relabel_identity_is_noop() {
        let g = tiny();
        let id: Vec<VertexId> = (0..6).collect();
        assert_eq!(g.relabel(&id).sorted(), g.sorted());
    }

    #[test]
    fn relabel_preserves_structure() {
        let g = tiny();
        // Swap 0 <-> 5.
        let perm: Vec<VertexId> = vec![5, 1, 2, 3, 4, 0];
        let h = g.relabel(&perm);
        assert_eq!(h.num_edges(), g.num_edges());
        // Degree multiset preserved.
        let mut dg: Vec<u32> = g.out_degrees();
        let mut dh: Vec<u32> = h.out_degrees();
        dg.sort_unstable();
        dh.sort_unstable();
        assert_eq!(dg, dh);
        // Edge (0,1) became (5,1).
        assert!(h.neighbors(5).contains(&1));
    }

    #[test]
    fn prop_transpose_twice_is_identity() {
        check("transpose twice = id", 30, |g| {
            let (n, edges) = g.edges(1..80, 4);
            let csr = Csr::from_edges(n, &edges);
            assert_eq!(csr.transpose().transpose().sorted(), csr.sorted());
        });
    }

    #[test]
    fn prop_relabel_roundtrip() {
        check("relabel by p then p^-1 = id", 30, |g| {
            let (n, edges) = g.edges(1..60, 3);
            let csr = Csr::from_edges(n, &edges);
            let perm = g.permutation(n);
            let mut inv = vec![0 as VertexId; n];
            for (v, &p) in perm.iter().enumerate() {
                inv[p as usize] = v as VertexId;
            }
            let back = csr.relabel(&perm).relabel(&inv);
            assert_eq!(back.sorted(), csr.sorted());
        });
    }

    #[test]
    fn prop_in_degrees_sum_to_edges() {
        check("sum(in_deg) == |E|", 30, |g| {
            let (n, edges) = g.edges(1..100, 5);
            let csr = Csr::from_edges(n, &edges);
            let total: u64 = csr.in_degrees().iter().map(|&d| d as u64).sum();
            assert_eq!(total, csr.num_edges() as u64);
        });
    }
}
