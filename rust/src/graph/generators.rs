//! Graph generators for the paper's evaluation inputs.
//!
//! - [`rmat`]: the Graph500 recursive-matrix generator with the paper's
//!   parameters `(a=0.57, b=c=0.19, d=0.05)` (§6.1), matching GraphMat /
//!   Galois / Ligra evaluations.
//! - [`uniform`]: Erdős–Rényi-style uniform random digraph.
//! - [`zipf_out`]: explicit power-law out-degree graph (used by the cache
//!   model validation, where the access distribution must be controlled).
//! - [`bipartite_zipf`]: Netflix-like user→item rating graph.
//! - [`expand_bipartite`]: the Sparkler-style 2x/4x expansion the paper
//!   uses for Netflix2x/Netflix4x (duplicate users/items "while
//!   maintaining similar patterns of reviews").

use super::{Edge, VertexId};
use crate::util::rng::{Rng, ZipfSampler};

/// Parameters of the RMAT recursive partition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Noise added per recursion level to avoid exact self-similarity
    /// (Graph500 reference does the same).
    pub noise: f64,
}

impl RmatParams {
    /// The paper's Graph500 parameters (§6.1).
    pub fn graph500() -> RmatParams {
        RmatParams {
            a: 0.57,
            b: 0.19,
            c: 0.19,
            noise: 0.05,
        }
    }
}

/// Generate an RMAT graph with `2^scale` vertices and `edge_factor *
/// 2^scale` edges (before dedup). Returns the raw edge list; pass through
/// [`crate::graph::CsrBuilder`] to dedup and drop self-loops.
pub fn rmat(scale: u32, edge_factor: usize, params: RmatParams, seed: u64) -> (usize, Vec<Edge>) {
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    let d = 1.0 - params.a - params.b - params.c;
    assert!(d >= 0.0, "rmat params must sum to <= 1");
    for _ in 0..m {
        let mut src = 0usize;
        let mut dst = 0usize;
        for level in 0..scale {
            // Per-level multiplicative noise keeps the distribution from
            // being perfectly self-similar.
            let jitter = 1.0 + params.noise * (2.0 * rng.next_f64() - 1.0);
            let a = params.a * jitter;
            let b = params.b * jitter;
            let c = params.c * jitter;
            let total = a + b + c + d * jitter;
            let r = rng.next_f64() * total;
            let (sbit, dbit) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            src |= sbit << (scale - 1 - level);
            dst |= dbit << (scale - 1 - level);
        }
        edges.push((src as VertexId, dst as VertexId));
    }
    (n, edges)
}

/// Uniform random digraph: `n` vertices, `m` edges.
pub fn uniform(n: usize, m: usize, seed: u64) -> (usize, Vec<Edge>) {
    let mut rng = Rng::new(seed);
    let edges = (0..m)
        .map(|_| {
            (
                rng.next_below(n as u64) as VertexId,
                rng.next_below(n as u64) as VertexId,
            )
        })
        .collect();
    (n, edges)
}

/// Power-law graph where **sources** are Zipf(exponent)-distributed (so
/// out-degree is skewed — the distribution vertex reordering exploits) and
/// destinations are uniform.
pub fn zipf_out(n: usize, m: usize, exponent: f64, seed: u64) -> (usize, Vec<Edge>) {
    let mut rng = Rng::new(seed);
    let zipf = ZipfSampler::new(n, exponent);
    // Scatter Zipf ranks over vertex ids so the hot vertices are not
    // already contiguous (that would presort the graph).
    let scatter = rng.permutation(n);
    let edges = (0..m)
        .map(|_| {
            let s = scatter[zipf.sample(&mut rng)];
            let d = rng.next_below(n as u64) as VertexId;
            (s, d)
        })
        .collect();
    (n, edges)
}

/// Bipartite user→item graph with Zipf-distributed item popularity and
/// lognormal-ish user activity: the Netflix stand-in. Vertices
/// `0..users` are users; `users..users+items` are items. Edges run
/// user→item (ratings). Returns (num_vertices, edges).
pub fn bipartite_zipf(
    users: usize,
    items: usize,
    ratings: usize,
    item_exponent: f64,
    seed: u64,
) -> (usize, Vec<Edge>) {
    let mut rng = Rng::new(seed);
    let item_pop = ZipfSampler::new(items, item_exponent);
    // User activity ~ Zipf(0.7) — mildly skewed, like real rating counts.
    let user_act = ZipfSampler::new(users, 0.7);
    let user_scatter = rng.permutation(users);
    let item_scatter = rng.permutation(items);
    let edges = (0..ratings)
        .map(|_| {
            let u = user_scatter[user_act.sample(&mut rng)];
            let i = item_scatter[item_pop.sample(&mut rng)];
            (u, users as VertexId + i)
        })
        .collect();
    (users + items, edges)
}

/// Sparkler-style expansion [16]: multiply users and items by `factor`,
/// replicating each rating into each copy-pair with a shifted item, which
/// preserves the degree distribution while scaling the graph (the paper's
/// Netflix2x doubles users *and* items and ~4x's the ratings; Netflix4x
/// quadruples).
pub fn expand_bipartite(
    users: usize,
    items: usize,
    edges: &[Edge],
    factor: usize,
    seed: u64,
) -> (usize, usize, Vec<Edge>) {
    assert!(factor >= 1);
    let mut rng = Rng::new(seed);
    let new_users = users * factor;
    let new_items = items * factor;
    let mut out = Vec::with_capacity(edges.len() * factor * factor);
    for copy_u in 0..factor {
        for copy_i in 0..factor {
            for &(u, it) in edges {
                let item_idx = it as usize - users;
                // Small random item shift inside the copy keeps copies from
                // being exactly identical (the paper: "maintaining similar
                // patterns of reviews").
                let jitter = if factor > 1 && rng.coin(0.1) {
                    rng.next_below(items as u64) as usize
                } else {
                    item_idx
                };
                let nu = (u as usize + copy_u * users) as VertexId;
                let ni = (new_users + jitter + copy_i * items) as VertexId;
                out.push((nu, ni));
            }
        }
    }
    // Keep the rating count ~ factor^2 / factor scaling the paper reports:
    // Netflix (198M) -> 2x (792M = 4x) -> 4x (1585M = 8x). 2x uses all
    // factor^2=4 copies; 4x keeps half of the 16 copies.
    if factor >= 4 {
        let keep = edges.len() * factor * factor / 2;
        out.truncate(keep);
    }
    (new_users, new_items, out)
}

/// Compute a degree histogram (log2 buckets) — used to sanity-check the
/// power-law shape of generated graphs.
pub fn degree_histogram(degrees: &[u32]) -> Vec<(u32, usize)> {
    let mut hist: Vec<(u32, usize)> = Vec::new();
    let maxd = degrees.iter().copied().max().unwrap_or(0);
    let buckets = 64 - u64::from(maxd).leading_zeros() as usize + 1;
    let mut counts = vec![0usize; buckets + 1];
    for &d in degrees {
        let b = if d == 0 { 0 } else { 64 - u64::from(d).leading_zeros() as usize };
        counts[b] += 1;
    }
    for (b, &c) in counts.iter().enumerate() {
        if c > 0 {
            hist.push((b as u32, c));
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn rmat_shape() {
        let (n, edges) = rmat(10, 8, RmatParams::graph500(), 1);
        assert_eq!(n, 1024);
        assert_eq!(edges.len(), 8192);
        for &(s, d) in &edges {
            assert!((s as usize) < n && (d as usize) < n);
        }
    }

    #[test]
    fn rmat_is_skewed() {
        let (n, edges) = rmat(12, 16, RmatParams::graph500(), 7);
        let g = Csr::from_edges(n, &edges);
        let mut degs = g.out_degrees();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let top1pct: u64 = degs[..n / 100].iter().map(|&d| d as u64).sum();
        let total: u64 = degs.iter().map(|&d| d as u64).sum();
        // Power-law: top 1% of vertices should own >15% of edges.
        assert!(
            top1pct as f64 > 0.15 * total as f64,
            "top1pct={top1pct} total={total}"
        );
    }

    #[test]
    fn rmat_deterministic() {
        let (_, e1) = rmat(8, 4, RmatParams::graph500(), 99);
        let (_, e2) = rmat(8, 4, RmatParams::graph500(), 99);
        assert_eq!(e1, e2);
        let (_, e3) = rmat(8, 4, RmatParams::graph500(), 100);
        assert_ne!(e1, e3);
    }

    #[test]
    fn uniform_is_flat() {
        let (n, edges) = uniform(1 << 12, 1 << 16, 3);
        let g = Csr::from_edges(n, &edges);
        let maxd = g.out_degrees().into_iter().max().unwrap();
        // Expected degree 16; uniform max should stay small.
        assert!(maxd < 64, "maxd={maxd}");
    }

    #[test]
    fn zipf_out_is_skewed() {
        let (n, edges) = zipf_out(1 << 12, 1 << 16, 1.0, 5);
        let g = Csr::from_edges(n, &edges);
        let maxd = g.out_degrees().into_iter().max().unwrap();
        assert!(maxd > 500, "maxd={maxd}"); // hottest vertex is hot
    }

    #[test]
    fn bipartite_respects_sides() {
        let (n, edges) = bipartite_zipf(1000, 100, 20_000, 1.1, 2);
        assert_eq!(n, 1100);
        for &(u, i) in &edges {
            assert!((u as usize) < 1000);
            assert!((1000..1100).contains(&(i as usize)));
        }
    }

    #[test]
    fn expansion_scales() {
        let (_, edges) = bipartite_zipf(500, 50, 5_000, 1.1, 2);
        let (u2, i2, e2) = expand_bipartite(500, 50, &edges, 2, 3);
        assert_eq!(u2, 1000);
        assert_eq!(i2, 100);
        assert_eq!(e2.len(), 4 * edges.len());
        for &(u, i) in &e2 {
            assert!((u as usize) < u2);
            assert!(((u2)..(u2 + i2)).contains(&(i as usize)));
        }
        let (u4, _, e4) = expand_bipartite(500, 50, &edges, 4, 3);
        assert_eq!(u4, 2000);
        assert_eq!(e4.len(), 8 * edges.len());
    }

    #[test]
    fn histogram_sums_to_n() {
        let degs = vec![0, 1, 1, 2, 5, 9, 100];
        let hist = degree_histogram(&degs);
        let total: usize = hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, degs.len());
    }
}
