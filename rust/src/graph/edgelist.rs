//! Edge-list file IO.
//!
//! Two formats:
//! - **Text**: one `src dst` pair per line, `#` comments (SNAP style — what
//!   LiveJournal/Twitter downloads look like).
//! - **Binary**: little-endian `u64 num_vertices, u64 num_edges`, then
//!   `num_edges` pairs of `u32`. Used to cache generated graphs so bench
//!   runs are repeatable without regeneration.

use super::{Edge, VertexId};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const BIN_MAGIC: &[u8; 8] = b"CAGRAEL1";

/// Parse a text edge list. Vertex count = max id + 1 unless `num_vertices`
/// is given.
pub fn read_text(path: impl AsRef<Path>, num_vertices: Option<usize>) -> Result<(usize, Vec<Edge>)> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut edges = Vec::new();
    let mut max_id: u64 = 0;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            bail!("{}:{}: expected `src dst`", path.display(), lineno + 1);
        };
        let s: u64 = a
            .parse()
            .with_context(|| format!("{}:{}: bad src {a:?}", path.display(), lineno + 1))?;
        let d: u64 = b
            .parse()
            .with_context(|| format!("{}:{}: bad dst {b:?}", path.display(), lineno + 1))?;
        if s > u32::MAX as u64 || d > u32::MAX as u64 {
            bail!("{}:{}: vertex id exceeds u32", path.display(), lineno + 1);
        }
        max_id = max_id.max(s).max(d);
        edges.push((s as VertexId, d as VertexId));
    }
    let n = num_vertices.unwrap_or((max_id + 1) as usize);
    for &(s, d) in &edges {
        if s as usize >= n || d as usize >= n {
            bail!("edge ({s},{d}) out of range for num_vertices={n}");
        }
    }
    Ok((n, edges))
}

/// Write a text edge list.
pub fn write_text(path: impl AsRef<Path>, num_vertices: usize, edges: &[Edge]) -> Result<()> {
    let path = path.as_ref();
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# cagra edge list: {num_vertices} vertices, {} edges", edges.len())?;
    for &(s, d) in edges {
        writeln!(w, "{s} {d}")?;
    }
    w.flush()?;
    Ok(())
}

/// Write the binary format.
pub fn write_binary(path: impl AsRef<Path>, num_vertices: usize, edges: &[Edge]) -> Result<()> {
    let path = path.as_ref();
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(num_vertices as u64).to_le_bytes())?;
    w.write_all(&(edges.len() as u64).to_le_bytes())?;
    // Bulk-write the pair array.
    for &(s, d) in edges {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&d.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary format.
pub fn read_binary(path: impl AsRef<Path>) -> Result<(usize, Vec<Edge>)> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("{}: not a cagra binary edge list", path.display());
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut raw = vec![0u8; m * 8];
    r.read_exact(&mut raw)?;
    let mut edges = Vec::with_capacity(m);
    for i in 0..m {
        let s = u32::from_le_bytes(raw[i * 8..i * 8 + 4].try_into().unwrap());
        let d = u32::from_le_bytes(raw[i * 8 + 4..i * 8 + 8].try_into().unwrap());
        if s as usize >= n || d as usize >= n {
            bail!("{}: corrupt edge ({s},{d}) >= n={n}", path.display());
        }
        edges.push((s, d));
    }
    Ok((n, edges))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cagra-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn text_roundtrip() {
        let p = tmp("el.txt");
        let edges = vec![(0, 1), (2, 3), (3, 0)];
        write_text(&p, 4, &edges).unwrap();
        let (n, back) = read_text(&p, None).unwrap();
        assert_eq!(n, 4);
        assert_eq!(back, edges);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_skips_comments() {
        let p = tmp("el2.txt");
        std::fs::write(&p, "# header\n0 1\n% other comment\n\n1 2\n").unwrap();
        let (n, edges) = read_text(&p, None).unwrap();
        assert_eq!(n, 3);
        assert_eq!(edges, vec![(0, 1), (1, 2)]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_rejects_garbage() {
        let p = tmp("el3.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_text(&p, None).is_err());
        std::fs::write(&p, "0 5\n").unwrap();
        assert!(read_text(&p, Some(3)).is_err()); // out of range
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let p = tmp("el.bin");
        let edges: Vec<Edge> = (0..1000u32).map(|i| (i % 97, (i * 7) % 97)).collect();
        write_binary(&p, 97, &edges).unwrap();
        let (n, back) = read_binary(&p).unwrap();
        assert_eq!(n, 97);
        assert_eq!(back, edges);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
