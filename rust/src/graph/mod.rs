//! Graph substrate: CSR representation (§2.1), edge-list IO, the graph
//! generators the evaluation uses (Graph500 RMAT, bipartite Netflix-like
//! with Sparkler-style expansion), and a registry of scaled stand-in
//! datasets for the paper's inputs.

pub mod csr;
pub mod edgelist;
pub mod generators;
pub mod datasets;

pub use csr::{Csr, CsrBuilder};

/// Vertex identifier. 32 bits covers every graph in the paper (≤134M
/// vertices) at half the vertex-array footprint of u64 — the paper's own
/// frameworks (Ligra, GraphMat) do the same.
pub type VertexId = u32;

/// An edge (source, destination).
pub type Edge = (VertexId, VertexId);

/// Degree prefix-sum helper: `prefix[v+1]-prefix[v]` = degree(v). Used by
/// the cost-based load balancer (§3.2).
pub fn degree_prefix(csr: &Csr) -> Vec<u64> {
    let mut prefix = Vec::with_capacity(csr.num_vertices() + 1);
    prefix.push(0u64);
    let mut acc = 0u64;
    for v in 0..csr.num_vertices() {
        acc += csr.degree(v as VertexId) as u64;
        prefix.push(acc);
    }
    prefix
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_prefix_counts() {
        let g = Csr::from_edges(4, &[(0, 1), (0, 2), (1, 2), (3, 0)]);
        let p = degree_prefix(&g);
        assert_eq!(p, vec![0, 2, 3, 3, 4]);
    }
}
