//! Scaled stand-in datasets for the paper's evaluation inputs (Table 1).
//!
//! The original graphs (Twitter-2010, LiveJournal, RMAT25/27, Netflix) are
//! not redistributable/available here and would not fit the container, so
//! each is replaced by a generator-backed stand-in with the same
//! *structure* (degree distribution, ordering properties, bipartiteness)
//! at ~1/100 scale — with the effective cache scaled to match (see
//! `coordinator::SystemConfig`). DESIGN.md §3 records the substitution.
//!
//! Stand-ins are cached on disk (binary edge lists under
//! `target/dataset-cache/`) so repeated bench runs skip generation.

use super::csr::{Csr, CsrBuilder};
use super::generators::{self, RmatParams};
use super::{edgelist, VertexId};
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::path::PathBuf;

/// All registered dataset names.
pub const ALL: &[&str] = &[
    "livejournal-sim",
    "twitter-sim",
    "rmat25-sim",
    "rmat27-sim",
    "netflix-sim",
    "netflix2x-sim",
    "netflix4x-sim",
];

/// The four whole-graph analytics datasets (Tables 2/4/5/7/8).
pub const GRAPH_DATASETS: &[&str] = &["livejournal-sim", "twitter-sim", "rmat25-sim", "rmat27-sim"];

/// The three CF datasets (Table 3).
pub const CF_DATASETS: &[&str] = &["netflix-sim", "netflix2x-sim", "netflix4x-sim"];

/// Mapping to the paper's dataset each stand-in represents.
pub fn paper_name(name: &str) -> &'static str {
    match name {
        "livejournal-sim" => "LiveJournal (5M/69M)",
        "twitter-sim" => "Twitter (41M/1469M)",
        "rmat25-sim" => "RMAT25 (34M/671M)",
        "rmat27-sim" => "RMAT27 (134M/2147M)",
        "netflix-sim" => "Netflix (0.5M/198M)",
        "netflix2x-sim" => "Netflix2x (1M/792M)",
        "netflix4x-sim" => "Netflix4x (2M/1585M)",
        _ => "(unknown)",
    }
}

/// A loaded dataset: the graph plus bipartite metadata for CF inputs.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    /// For bipartite (CF) datasets: number of user vertices (users are
    /// `0..users`, items `users..n`).
    pub users: Option<usize>,
}

/// Load a registered dataset at the default scale.
pub fn load(name: &str) -> Result<Dataset> {
    load_scaled(name, 1.0)
}

/// Load with a scale factor: `scale < 1` shrinks vertex counts (RMAT scale
/// shrinks logarithmically) for smoke/CI runs.
pub fn load_scaled(name: &str, scale: f64) -> Result<Dataset> {
    // Scale shifts RMAT log2-scale: 0.25 => -2 levels.
    let shift = if scale >= 1.0 {
        0
    } else {
        (-(scale.log2())).ceil() as u32
    };
    let spec = match name {
        // degree ~14 like LiveJournal (69M/5M); BFS-relabeled: LiveJournal
        // crawl order has strong community locality (§6.3: "already in BFS
        // based order").
        "livejournal-sim" => Spec::Rmat {
            scale: 18 - shift.min(9),
            edge_factor: 14,
            seed: 0x11,
            bfs_relabel: true,
        },
        // degree ~36 like Twitter (1469M/41M), BFS-relabeled (the Twitter
        // dataset "inherently has a vertex ordering that creates
        // significant amount of locality", §3.3).
        "twitter-sim" => Spec::Rmat {
            scale: 20 - shift.min(11),
            edge_factor: 36,
            seed: 0x22,
            bfs_relabel: true,
        },
        // RMAT graphs come out of the generator with random vertex labels —
        // matching the paper's observation that RMAT27 "has a random
        // ordering" (§6.2).
        "rmat25-sim" => Spec::Rmat {
            scale: 20 - shift.min(11),
            edge_factor: 20,
            seed: 0x25,
            bfs_relabel: false,
        },
        "rmat27-sim" => Spec::Rmat {
            scale: 21 - shift.min(12),
            edge_factor: 16,
            seed: 0x27,
            bfs_relabel: false,
        },
        "netflix-sim" => Spec::Netflix { factor: 1 },
        "netflix2x-sim" => Spec::Netflix { factor: 2 },
        "netflix4x-sim" => Spec::Netflix { factor: 4 },
        _ => bail!("unknown dataset {name:?}; known: {ALL:?}"),
    };
    let cache = cache_path(name, scale);
    if let Some(ds) = try_cached(name, &spec, &cache) {
        return Ok(ds);
    }
    let ds = build(name, &spec, scale)?;
    // Best-effort cache write.
    if let Some(parent) = cache.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let edges: Vec<_> = ds.graph.edges().collect();
    edgelist::write_binary(&cache, ds.graph.num_vertices(), &edges).ok();
    Ok(ds)
}

enum Spec {
    Rmat {
        scale: u32,
        edge_factor: usize,
        seed: u64,
        bfs_relabel: bool,
    },
    Netflix {
        factor: usize,
    },
}

fn cache_path(name: &str, scale: f64) -> PathBuf {
    let dir = std::env::var("CAGRA_DATASET_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/dataset-cache"));
    dir.join(format!("{name}-s{scale:.3}.bin"))
}

fn try_cached(name: &str, spec: &Spec, cache: &PathBuf) -> Option<Dataset> {
    let (n, edges) = edgelist::read_binary(cache).ok()?;
    let users = match spec {
        Spec::Netflix { factor } => Some(netflix_users(*factor)),
        _ => None,
    };
    // Cached files are already cleaned; rebuild CSR directly.
    Some(Dataset {
        name: name.to_string(),
        graph: Csr::from_edges(n, &edges),
        users,
    })
}

fn netflix_users(factor: usize) -> usize {
    (1usize << 16) * factor
}

fn build(name: &str, spec: &Spec, scale: f64) -> Result<Dataset> {
    match *spec {
        Spec::Rmat {
            scale: s,
            edge_factor,
            seed,
            bfs_relabel,
        } => {
            let (n, edges) = generators::rmat(s, edge_factor, RmatParams::graph500(), seed);
            let mut b = CsrBuilder::new(n);
            b.extend(edges);
            let mut g = b.build();
            if bfs_relabel {
                let perm = bfs_order(&g);
                g = g.relabel(&perm);
            }
            Ok(Dataset {
                name: name.to_string(),
                graph: g,
                users: None,
            })
        }
        Spec::Netflix { factor } => {
            let base_users = 1usize << 16;
            let base_items = 1usize << 12;
            let base_ratings = ((4e6 * scale.min(1.0)) as usize).max(base_users);
            let (_, edges) = generators::bipartite_zipf(base_users, base_items, base_ratings, 1.1, 0x4E);
            let (users, items, edges) = if factor > 1 {
                generators::expand_bipartite(base_users, base_items, &edges, factor, 0x4F)
            } else {
                (base_users, base_items, edges)
            };
            let mut b = CsrBuilder::new(users + items);
            // Ratings may repeat after expansion jitter; dedup like the
            // paper dedups edges.
            b.extend(edges);
            Ok(Dataset {
                name: name.to_string(),
                graph: b.build(),
                users: Some(users),
            })
        }
    }
}

/// BFS visit-order permutation (perm[old] = new id). Starts from the
/// highest-out-degree vertex, explores the symmetrized neighborhood, and
/// appends unreached vertices in id order. Mimics crawl-order locality of
/// real social-graph datasets.
pub fn bfs_order(g: &Csr) -> Vec<VertexId> {
    let n = g.num_vertices();
    let t = g.transpose();
    let start = (0..n)
        .max_by_key(|&v| g.degree(v as VertexId))
        .unwrap_or(0) as VertexId;
    let mut perm = vec![VertexId::MAX; n];
    let mut next_id: VertexId = 0;
    let mut queue = VecDeque::new();
    perm[start as usize] = next_id;
    next_id += 1;
    queue.push_back(start);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u).iter().chain(t.neighbors(u)) {
            if perm[v as usize] == VertexId::MAX {
                perm[v as usize] = next_id;
                next_id += 1;
                queue.push_back(v);
            }
        }
    }
    for p in perm.iter_mut() {
        if *p == VertexId::MAX {
            *p = next_id;
            next_id += 1;
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_order_is_permutation() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let p = bfs_order(&g);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn load_small_rmat() {
        let ds = load_scaled("rmat25-sim", 1.0 / 64.0).unwrap();
        assert!(ds.graph.num_vertices() >= 1 << 9);
        assert!(ds.graph.num_edges() > ds.graph.num_vertices());
        assert!(ds.users.is_none());
    }

    #[test]
    fn load_netflix_bipartite() {
        let ds = load_scaled("netflix-sim", 0.05).unwrap();
        let users = ds.users.unwrap();
        assert!(users > 0 && users < ds.graph.num_vertices());
        // All edges run user -> item.
        for (u, i) in ds.graph.edges() {
            assert!((u as usize) < users);
            assert!((i as usize) >= users);
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(load("no-such-graph").is_err());
    }

    #[test]
    fn cache_roundtrip_consistent() {
        // Second load must hit the cache and produce the identical graph.
        let a = load_scaled("livejournal-sim", 1.0 / 64.0).unwrap();
        let b = load_scaled("livejournal-sim", 1.0 / 64.0).unwrap();
        assert_eq!(a.graph.sorted(), b.graph.sorted());
    }
}
