//! Scaled stand-in datasets for the paper's evaluation inputs (Table 1).
//!
//! The original graphs (Twitter-2010, LiveJournal, RMAT25/27, Netflix) are
//! not redistributable/available here and would not fit the container, so
//! each is replaced by a generator-backed stand-in with the same
//! *structure* (degree distribution, ordering properties, bipartiteness)
//! at ~1/100 scale — with the effective cache scaled to match (see
//! `coordinator::SystemConfig`). DESIGN.md §3 records the substitution.
//!
//! Stand-ins are cached on disk under `target/dataset-cache/` (override
//! with `CAGRA_DATASET_CACHE`), in two layers:
//!
//! - `<name>-s<scale>.csr.art` — the **finished CSR**, framed by the
//!   artifact codec (`store/codec.rs`: magic, version, checksum). The
//!   warm fast path: a load decodes this directly and performs zero
//!   `Csr::from_edges` work.
//! - `<name>-s<scale>.bin` — the binary edge list (also what `cagra gen`
//!   emits). Fallback when the CSR artifact is absent: one
//!   `Csr::from_edges` pass, after which the CSR artifact is written so
//!   the next load is warm.
//!
//! Both layers are written atomically (unique temp file + rename) and
//! validated on read — a torn, corrupt, or stale-spec file is deleted
//! and the dataset regenerated, never silently served.

use super::csr::{Csr, CsrBuilder};
use super::generators::{self, RmatParams};
use super::{edgelist, Edge, VertexId};
use crate::store::codec;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};

/// All registered dataset names.
pub const ALL: &[&str] = &[
    "livejournal-sim",
    "twitter-sim",
    "rmat25-sim",
    "rmat27-sim",
    "netflix-sim",
    "netflix2x-sim",
    "netflix4x-sim",
];

/// The four whole-graph analytics datasets (Tables 2/4/5/7/8).
pub const GRAPH_DATASETS: &[&str] = &["livejournal-sim", "twitter-sim", "rmat25-sim", "rmat27-sim"];

/// The three CF datasets (Table 3).
pub const CF_DATASETS: &[&str] = &["netflix-sim", "netflix2x-sim", "netflix4x-sim"];

/// Mapping to the paper's dataset each stand-in represents.
pub fn paper_name(name: &str) -> &'static str {
    match name {
        "livejournal-sim" => "LiveJournal (5M/69M)",
        "twitter-sim" => "Twitter (41M/1469M)",
        "rmat25-sim" => "RMAT25 (34M/671M)",
        "rmat27-sim" => "RMAT27 (134M/2147M)",
        "netflix-sim" => "Netflix (0.5M/198M)",
        "netflix2x-sim" => "Netflix2x (1M/792M)",
        "netflix4x-sim" => "Netflix4x (2M/1585M)",
        _ => "(unknown)",
    }
}

/// A loaded dataset: the graph plus bipartite metadata for CF inputs.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    /// For bipartite (CF) datasets: number of user vertices (users are
    /// `0..users`, items `users..n`).
    pub users: Option<usize>,
}

/// Load a registered dataset at the default scale.
pub fn load(name: &str) -> Result<Dataset> {
    load_scaled(name, 1.0)
}

/// Load with a scale factor: `scale < 1` shrinks vertex counts (RMAT scale
/// shrinks logarithmically) for smoke/CI runs. Uses the default cache
/// directory (`CAGRA_DATASET_CACHE` or `target/dataset-cache`).
pub fn load_scaled(name: &str, scale: f64) -> Result<Dataset> {
    load_scaled_in(name, scale, &default_cache_dir())
}

/// [`load_scaled`] against an explicit cache directory (tests point this
/// at throwaway dirs so cache-integrity behaviour is exercised without
/// races on the process-global default).
pub fn load_scaled_in(name: &str, scale: f64, cache_dir: &Path) -> Result<Dataset> {
    let spec = spec_for(name, scale)?;
    // `{scale}` (f64 Display) is the shortest round-trip representation,
    // so distinct scales can never share a cache file. The old `{:.3}`
    // rounding let nearby scales collide — fatally for Netflix, whose
    // spec validation is scale-insensitive in vertex count and would
    // silently serve the neighbor's graph.
    let csr_cache = cache_dir.join(format!("{name}-s{scale}.csr.art"));
    let edge_cache = cache_dir.join(format!("{name}-s{scale}.bin"));
    // Warm fast path: decode the finished CSR — no edge scan, no
    // Csr::from_edges.
    if let Some(ds) = try_cached_csr(name, &spec, scale, &csr_cache) {
        return Ok(ds);
    }
    // Edge-list fallback: one CSR build from cached edges, then persist
    // the CSR so the *next* load takes the warm path.
    if let Some(ds) = try_cached(name, &spec, scale, &edge_cache) {
        persist_csr(&csr_cache, &ds.graph);
        return Ok(ds);
    }
    let ds = build(name, &spec, scale)?;
    // Best-effort cache writes (atomic: torn writes can never be read
    // back as valid cache files).
    let edges: Vec<_> = ds.graph.edges().collect();
    write_edge_cache(&edge_cache, ds.graph.num_vertices(), &edges);
    persist_csr(&csr_cache, &ds.graph);
    Ok(ds)
}

/// Generator spec for a registered dataset name at `scale`.
fn spec_for(name: &str, scale: f64) -> Result<Spec> {
    // Scale shifts RMAT log2-scale: 0.25 => -2 levels.
    let shift = if scale >= 1.0 {
        0
    } else {
        (-(scale.log2())).ceil() as u32
    };
    let spec = match name {
        // degree ~14 like LiveJournal (69M/5M); BFS-relabeled: LiveJournal
        // crawl order has strong community locality (§6.3: "already in BFS
        // based order").
        "livejournal-sim" => Spec::Rmat {
            scale: 18 - shift.min(9),
            edge_factor: 14,
            seed: 0x11,
            bfs_relabel: true,
        },
        // degree ~36 like Twitter (1469M/41M), BFS-relabeled (the Twitter
        // dataset "inherently has a vertex ordering that creates
        // significant amount of locality", §3.3).
        "twitter-sim" => Spec::Rmat {
            scale: 20 - shift.min(11),
            edge_factor: 36,
            seed: 0x22,
            bfs_relabel: true,
        },
        // RMAT graphs come out of the generator with random vertex labels —
        // matching the paper's observation that RMAT27 "has a random
        // ordering" (§6.2).
        "rmat25-sim" => Spec::Rmat {
            scale: 20 - shift.min(11),
            edge_factor: 20,
            seed: 0x25,
            bfs_relabel: false,
        },
        "rmat27-sim" => Spec::Rmat {
            scale: 21 - shift.min(12),
            edge_factor: 16,
            seed: 0x27,
            bfs_relabel: false,
        },
        "netflix-sim" => Spec::Netflix { factor: 1 },
        "netflix2x-sim" => Spec::Netflix { factor: 2 },
        "netflix4x-sim" => Spec::Netflix { factor: 4 },
        _ => bail!("unknown dataset {name:?}; known: {ALL:?}"),
    };
    Ok(spec)
}

enum Spec {
    Rmat {
        scale: u32,
        edge_factor: usize,
        seed: u64,
        bfs_relabel: bool,
    },
    Netflix {
        factor: usize,
    },
}

impl Spec {
    /// Exact vertex count every build of this spec produces (generators
    /// allocate the full id range regardless of which ids get edges).
    fn expected_vertices(&self) -> usize {
        match *self {
            Spec::Rmat { scale, .. } => 1usize << scale,
            Spec::Netflix { factor } => netflix_users(factor) + (1usize << 12) * factor,
        }
    }

    /// Upper bound on edge count (the generators emit at most this many
    /// before dedup/self-loop cleanup).
    fn max_edges(&self, load_scale: f64) -> usize {
        match *self {
            Spec::Rmat { scale, edge_factor, .. } => (1usize << scale) * edge_factor,
            Spec::Netflix { factor } => {
                let base_users = 1usize << 16;
                let base_ratings = ((4e6 * load_scale.min(1.0)) as usize).max(base_users);
                base_ratings * factor * factor
            }
        }
    }

    /// Bipartite metadata implied by the spec.
    fn users(&self) -> Option<usize> {
        match *self {
            Spec::Netflix { factor } => Some(netflix_users(factor)),
            _ => None,
        }
    }

    /// Does a cached graph's shape match what this spec would generate?
    /// The vertex count is fully determined; the edge count is bounded
    /// (cleanup dedups, so only the raw emission count is exact). A file
    /// failing this came from a different spec (e.g. generator parameters
    /// changed between versions) and must be regenerated, not served.
    fn matches(&self, n: usize, m: usize, load_scale: f64) -> std::result::Result<(), String> {
        let want_n = self.expected_vertices();
        if n != want_n {
            return Err(format!("has {n} vertices, spec generates {want_n}"));
        }
        let max_m = self.max_edges(load_scale);
        if m == 0 || m > max_m {
            return Err(format!("has {m} edges, spec generates 1..={max_m}"));
        }
        Ok(())
    }
}

fn default_cache_dir() -> PathBuf {
    std::env::var("CAGRA_DATASET_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/dataset-cache"))
}

/// Warm path: load the cached finished CSR — mapped in place (zero
/// decode/copy; the `Csr`'s arrays borrow the page cache) when the
/// platform supports it, decoded otherwise. Unreadable (torn/corrupt) or
/// spec-mismatched (stale) files are deleted and treated as a miss.
fn try_cached_csr(name: &str, spec: &Spec, scale: f64, path: &Path) -> Option<Dataset> {
    if !path.is_file() {
        return None;
    }
    let loaded = if crate::store::mmap_supported() {
        // A v1 (or corrupt) file fails validation here AND in the decode
        // fallback, so it is dropped and regenerated, never misread.
        codec::map_file::<Csr>(path)
            .map(|(g, _region)| g)
            .or_else(|_| codec::read_file::<Csr>(path).map(|(g, _)| g))
    } else {
        codec::read_file::<Csr>(path).map(|(g, _)| g)
    };
    let graph = match loaded {
        Ok(g) => g,
        Err(e) => {
            crate::log_warn!("dataset cache: dropping unreadable {}: {e:#}", path.display());
            std::fs::remove_file(path).ok();
            return None;
        }
    };
    if let Err(why) = spec.matches(graph.num_vertices(), graph.num_edges(), scale) {
        crate::log_warn!("dataset cache: dropping stale {}: {why}", path.display());
        std::fs::remove_file(path).ok();
        return None;
    }
    Some(Dataset {
        name: name.to_string(),
        graph,
        users: spec.users(),
    })
}

/// Fallback path: rebuild the CSR from the cached edge list. The decoded
/// counts are validated against the requested spec — a stale file from an
/// old spec (or a torn/corrupt one) is deleted and regenerated instead of
/// silently serving the wrong graph.
fn try_cached(name: &str, spec: &Spec, scale: f64, cache: &Path) -> Option<Dataset> {
    if !cache.is_file() {
        return None;
    }
    let (n, edges) = match edgelist::read_binary(cache) {
        Ok(v) => v,
        Err(e) => {
            crate::log_warn!("dataset cache: dropping unreadable {}: {e:#}", cache.display());
            std::fs::remove_file(cache).ok();
            return None;
        }
    };
    if let Err(why) = spec.matches(n, edges.len(), scale) {
        crate::log_warn!("dataset cache: dropping stale {}: {why}", cache.display());
        std::fs::remove_file(cache).ok();
        return None;
    }
    // Cached files are already cleaned; rebuild CSR directly.
    Some(Dataset {
        name: name.to_string(),
        graph: Csr::from_edges(n, &edges),
        users: spec.users(),
    })
}

/// Best-effort atomic edge-list cache write ([`codec::write_atomic`]:
/// unique temp file + rename), so a crash or full disk mid-write can
/// never leave a torn file under the cache name for the next run to
/// read.
fn write_edge_cache(cache: &Path, num_vertices: usize, edges: &[Edge]) {
    if let Some(parent) = cache.parent() {
        if std::fs::create_dir_all(parent).is_err() {
            return;
        }
    }
    let wrote = codec::write_atomic(cache, |tmp| edgelist::write_binary(tmp, num_vertices, edges));
    if let Err(e) = wrote {
        crate::log_warn!("dataset cache: writing {} failed: {e:#}", cache.display());
    }
}

/// Best-effort CSR artifact write (the codec's `write_file` is already
/// atomic: unique temp + rename).
fn persist_csr(path: &Path, g: &Csr) {
    if let Some(parent) = path.parent() {
        if std::fs::create_dir_all(parent).is_err() {
            return;
        }
    }
    if let Err(e) = codec::write_file(path, g) {
        crate::log_warn!("dataset cache: writing {} failed: {e:#}", path.display());
    }
}

fn netflix_users(factor: usize) -> usize {
    (1usize << 16) * factor
}

fn build(name: &str, spec: &Spec, scale: f64) -> Result<Dataset> {
    match *spec {
        Spec::Rmat {
            scale: s,
            edge_factor,
            seed,
            bfs_relabel,
        } => {
            let (n, edges) = generators::rmat(s, edge_factor, RmatParams::graph500(), seed);
            let mut b = CsrBuilder::new(n);
            b.extend(edges);
            let mut g = b.build();
            if bfs_relabel {
                let perm = bfs_order(&g);
                g = g.relabel(&perm);
            }
            Ok(Dataset {
                name: name.to_string(),
                graph: g,
                users: None,
            })
        }
        Spec::Netflix { factor } => {
            let base_users = 1usize << 16;
            let base_items = 1usize << 12;
            let base_ratings = ((4e6 * scale.min(1.0)) as usize).max(base_users);
            let (_, edges) = generators::bipartite_zipf(base_users, base_items, base_ratings, 1.1, 0x4E);
            let (users, items, edges) = if factor > 1 {
                generators::expand_bipartite(base_users, base_items, &edges, factor, 0x4F)
            } else {
                (base_users, base_items, edges)
            };
            let mut b = CsrBuilder::new(users + items);
            // Ratings may repeat after expansion jitter; dedup like the
            // paper dedups edges.
            b.extend(edges);
            Ok(Dataset {
                name: name.to_string(),
                graph: b.build(),
                users: Some(users),
            })
        }
    }
}

/// BFS visit-order permutation (perm[old] = new id). Starts from the
/// highest-out-degree vertex, explores the symmetrized neighborhood, and
/// appends unreached vertices in id order. Mimics crawl-order locality of
/// real social-graph datasets.
pub fn bfs_order(g: &Csr) -> Vec<VertexId> {
    let n = g.num_vertices();
    let t = g.transpose();
    let start = (0..n)
        .max_by_key(|&v| g.degree(v as VertexId))
        .unwrap_or(0) as VertexId;
    let mut perm = vec![VertexId::MAX; n];
    let mut next_id: VertexId = 0;
    let mut queue = VecDeque::new();
    perm[start as usize] = next_id;
    next_id += 1;
    queue.push_back(start);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u).iter().chain(t.neighbors(u)) {
            if perm[v as usize] == VertexId::MAX {
                perm[v as usize] = next_id;
                next_id += 1;
                queue.push_back(v);
            }
        }
    }
    for p in perm.iter_mut() {
        if *p == VertexId::MAX {
            *p = next_id;
            next_id += 1;
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_order_is_permutation() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let p = bfs_order(&g);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..5).collect::<Vec<_>>());
    }

    #[test]
    fn load_small_rmat() {
        let ds = load_scaled("rmat25-sim", 1.0 / 64.0).unwrap();
        assert!(ds.graph.num_vertices() >= 1 << 9);
        assert!(ds.graph.num_edges() > ds.graph.num_vertices());
        assert!(ds.users.is_none());
    }

    #[test]
    fn load_netflix_bipartite() {
        let ds = load_scaled("netflix-sim", 0.05).unwrap();
        let users = ds.users.unwrap();
        assert!(users > 0 && users < ds.graph.num_vertices());
        // All edges run user -> item.
        for (u, i) in ds.graph.edges() {
            assert!((u as usize) < users);
            assert!((i as usize) >= users);
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(load("no-such-graph").is_err());
    }

    #[test]
    fn cache_roundtrip_consistent() {
        // Second load must hit the cache and produce the identical graph.
        let a = load_scaled("livejournal-sim", 1.0 / 64.0).unwrap();
        let b = load_scaled("livejournal-sim", 1.0 / 64.0).unwrap();
        assert_eq!(a.graph.sorted(), b.graph.sorted());
    }

    const TEST_SCALE: f64 = 1.0 / 64.0;

    fn temp_cache(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cagra-dscache-{tag}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn cache_files(dir: &Path, name: &str) -> (std::path::PathBuf, std::path::PathBuf) {
        (
            dir.join(format!("{name}-s{TEST_SCALE}.csr.art")),
            dir.join(format!("{name}-s{TEST_SCALE}.bin")),
        )
    }

    #[test]
    fn nearby_scales_get_distinct_cache_files() {
        // f64 Display round-trips: scales that the old 3-decimal rounding
        // collapsed (0.05 vs 0.0504 both -> "0.050") must not share a
        // cache file, or one spec's graph gets served for the other.
        let a = format!("x-s{}.bin", 0.0500f64);
        let b = format!("x-s{}.bin", 0.0504f64);
        assert_ne!(a, b);
        assert_eq!(format!("{}", 1.0f64 / 64.0), "0.015625");
    }

    #[test]
    fn warm_load_decodes_csr_without_edge_list() {
        // The warm path must not need Csr::from_edges at all: delete the
        // edge list after the cold load and the reload must still succeed
        // (only the finished-CSR artifact can serve it), returning the
        // byte-identical CSR.
        let dir = temp_cache("warm");
        let a = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        let (art, bin) = cache_files(&dir, "rmat25-sim");
        assert!(art.is_file(), "cold load must persist the CSR artifact");
        assert!(bin.is_file(), "cold load must persist the edge list");
        std::fs::remove_file(&bin).unwrap();
        let b = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        assert_eq!(a.graph, b.graph, "decoded CSR must be byte-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn edge_list_fallback_rebuilds_and_persists_csr() {
        // With only the edge list present (e.g. written by `cagra gen` or
        // an older version), one load rebuilds the CSR and writes the
        // artifact so the next load is warm.
        let dir = temp_cache("fallback");
        let a = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        let (art, _bin) = cache_files(&dir, "rmat25-sim");
        std::fs::remove_file(&art).unwrap();
        let b = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        assert!(art.is_file(), "fallback load must repopulate the CSR artifact");
        assert_eq!(a.graph.sorted(), b.graph.sorted());
        let c = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        assert_eq!(b.graph, c.graph, "third load must decode what the second wrote");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_cache_files_are_regenerated() {
        // A crash mid-write used to be able to leave a torn edge list
        // under the final name; both cache layers must now detect
        // truncation, delete the file, and regenerate.
        let dir = temp_cache("torn");
        let a = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        let (art, bin) = cache_files(&dir, "rmat25-sim");
        for p in [&art, &bin] {
            let bytes = std::fs::read(p).unwrap();
            std::fs::write(p, &bytes[..bytes.len() / 2]).unwrap();
        }
        let b = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        assert_eq!(a.graph.sorted(), b.graph.sorted(), "regeneration must reproduce");
        // Both layers must be valid again after the regeneration.
        let c = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        assert_eq!(b.graph, c.graph);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_spec_mismatch_is_dropped_not_served() {
        // Structurally-valid cache files whose counts disagree with the
        // requested spec (e.g. generator parameters changed between
        // versions) must be deleted and regenerated, not silently served.
        let dir = temp_cache("stale");
        std::fs::create_dir_all(&dir).unwrap();
        let (art, bin) = cache_files(&dir, "rmat25-sim");
        edgelist::write_binary(&bin, 5, &[(0, 1), (1, 2)]).unwrap();
        codec::write_file(&art, &Csr::from_edges(4, &[(0, 1)])).unwrap();
        let ds = load_scaled_in("rmat25-sim", TEST_SCALE, &dir).unwrap();
        // rmat25-sim at 1/64 scale is a 2^14-vertex graph.
        assert_eq!(ds.graph.num_vertices(), 1 << 14);
        // The stale files were replaced by the regenerated graph's.
        let (n, edges) = edgelist::read_binary(&bin).unwrap();
        assert_eq!(n, ds.graph.num_vertices());
        assert_eq!(edges.len(), ds.graph.num_edges());
        let (back, _) = codec::read_file::<Csr>(&art).unwrap();
        assert_eq!(back, ds.graph);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spec_shape_validation() {
        let spec = spec_for("rmat25-sim", TEST_SCALE).unwrap();
        let n = spec.expected_vertices();
        assert!(spec.matches(n, 10, TEST_SCALE).is_ok());
        assert!(spec.matches(n - 1, 10, TEST_SCALE).is_err(), "wrong n");
        assert!(spec.matches(n, 0, TEST_SCALE).is_err(), "empty graph");
        assert!(
            spec.matches(n, spec.max_edges(TEST_SCALE) + 1, TEST_SCALE).is_err(),
            "too many edges"
        );
        let nf = spec_for("netflix2x-sim", 0.05).unwrap();
        assert_eq!(nf.expected_vertices(), 2 * ((1 << 16) + (1 << 12)));
        assert_eq!(nf.users(), Some(2 << 16));
    }
}
