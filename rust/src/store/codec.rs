//! Versioned binary codec for preprocessing artifacts.
//!
//! Matches the repo's zero-dependency idiom (`runtime/artifacts.rs`,
//! `graph/edgelist.rs`): hand-rolled little-endian framing, no serde.
//! Every artifact file is
//!
//! ```text
//! magic    [u8; 8]   "CAGART01"
//! version  u32 LE    CODEC_VERSION
//! kind     [u8; 4]   artifact type tag (Artifact::KIND)
//! length   u64 LE    payload bytes
//! payload  [u8]      type-specific, little-endian
//! checksum u64 LE    FNV-1a64 + avalanche over payload
//! ```
//!
//! Decoding is paranoid by contract: bad magic, wrong version, wrong kind,
//! inconsistent length, checksum mismatch, truncation, trailing bytes, or
//! any violated structural invariant (non-monotone offsets, out-of-range
//! ids, non-permutations, segment ranges that disagree with `seg_size`)
//! returns `Err` — never a panic, never a silently wrong value. Declared
//! lengths are validated against remaining bytes *before* allocation so a
//! corrupt header cannot trigger a huge allocation.

use super::fingerprint::hash_bytes;
use crate::graph::{Csr, VertexId};
use crate::segment::{MergePlan, Segment, SegmentedCsr};
use crate::util::ceil_div;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// File magic ("CAGra ARTifact", format generation 01).
pub const MAGIC: [u8; 8] = *b"CAGART01";

/// Bumped whenever any payload layout changes; old files are rejected
/// (and evicted by the store) rather than misread.
pub const CODEC_VERSION: u32 = 1;

/// Payload checksum: FNV-1a64 with a final avalanche.
pub fn checksum64(payload: &[u8]) -> u64 {
    hash_bytes(0x5EED_C0DE, payload)
}

/// A type that can be persisted in the artifact store.
pub trait Artifact: Sized {
    /// Four-byte header tag.
    const KIND: [u8; 4];
    /// Short name used in store filenames ("perm", "csr", "seg").
    const NAME: &'static str;
    fn encode_payload(&self, out: &mut Vec<u8>);
    fn decode_payload(r: &mut Reader) -> Result<Self>;
    /// Approximate decoded in-memory footprint (heap payload, not the
    /// encoded file size) — what the in-memory layer ([`super::MemStore`])
    /// charges against its byte budget.
    fn mem_bytes(&self) -> u64;
}

/// Bounds-checked little-endian reader over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated artifact: wanted {n} bytes, {} left", self.remaining());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Length-prefixed `u32` array. The length is validated against the
    /// remaining bytes before allocating.
    pub fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let len = self.u64()?;
        if len > (self.remaining() / 4) as u64 {
            bail!("corrupt artifact: u32 array length {len} exceeds payload");
        }
        let raw = self.bytes(len as usize * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Length-prefixed `u64` array.
    pub fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let len = self.u64()?;
        if len > (self.remaining() / 8) as u64 {
            bail!("corrupt artifact: u64 array length {len} exceeds payload");
        }
        let raw = self.bytes(len as usize * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Assert the payload was fully consumed.
    pub fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("corrupt artifact: {} trailing payload bytes", self.remaining());
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_vec_u32(out: &mut Vec<u8>, xs: &[u32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_u64(out: &mut Vec<u8>, xs: &[u64]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode `value` into a framed artifact byte buffer.
pub fn encode<T: Artifact>(value: &T) -> Vec<u8> {
    let mut payload = Vec::new();
    value.encode_payload(&mut payload);
    let mut out = Vec::with_capacity(payload.len() + 32);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&CODEC_VERSION.to_le_bytes());
    out.extend_from_slice(&T::KIND);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let checksum = checksum64(&payload);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&checksum.to_le_bytes());
    out
}

/// Decode a framed artifact, validating the full frame and every payload
/// invariant.
pub fn decode<T: Artifact>(bytes: &[u8]) -> Result<T> {
    let mut r = Reader::new(bytes);
    if r.bytes(8)? != MAGIC {
        bail!("bad magic: not an artifact file");
    }
    let version = r.u32()?;
    if version != CODEC_VERSION {
        bail!("unsupported artifact codec version {version} (this build reads v{CODEC_VERSION})");
    }
    let kind = r.bytes(4)?;
    if kind != T::KIND {
        bail!(
            "artifact kind mismatch: file has {:?}, expected {:?}",
            String::from_utf8_lossy(kind),
            String::from_utf8_lossy(&T::KIND)
        );
    }
    let len = r.u64()?;
    if r.remaining() < 8 || len != (r.remaining() - 8) as u64 {
        bail!(
            "corrupt artifact: payload length {len} inconsistent with file size ({} bytes left)",
            r.remaining()
        );
    }
    let payload = r.bytes(len as usize)?;
    let stored = r.u64()?;
    let actual = checksum64(payload);
    if stored != actual {
        bail!("artifact checksum mismatch ({stored:#018x} != {actual:#018x}): corrupt file");
    }
    let mut pr = Reader::new(payload);
    let value = T::decode_payload(&mut pr)?;
    pr.done()?;
    Ok(value)
}

/// Run `write` against a unique temp path next to `path`, then rename
/// into place — the one implementation of the crash-safe write pattern
/// (artifact files here, the dataset edge-list cache in
/// `graph/datasets.rs`). The temp name (`.tmp<pid>-<seq>`, the shape the
/// store's orphan sweep recognizes) is unique per process *and* per
/// call, so two threads racing to produce the same file can never
/// interleave into one temp (the loser's rename just replaces the
/// winner's identical bytes). The temp file is removed on failure.
pub fn write_atomic(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "tmp{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let result = write(&tmp).and_then(|()| {
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
    });
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

/// Encode + write atomically (temp file, then rename). Returns file size.
pub fn write_file<T: Artifact>(path: &Path, value: &T) -> Result<u64> {
    let bytes = encode(value);
    write_atomic(path, |tmp| {
        std::fs::write(tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))
    })?;
    Ok(bytes.len() as u64)
}

/// Read + decode a file. Returns the value and the file size.
pub fn read_file<T: Artifact>(path: &Path) -> Result<(T, u64)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let value =
        decode::<T>(&bytes).with_context(|| format!("decoding artifact {}", path.display()))?;
    Ok((value, bytes.len() as u64))
}

// ---------------------------------------------------------------------------
// Artifact implementations
// ---------------------------------------------------------------------------

impl Artifact for Csr {
    const KIND: [u8; 4] = *b"CSR_";
    const NAME: &'static str = "csr";

    fn encode_payload(&self, out: &mut Vec<u8>) {
        put_u64(out, self.num_vertices() as u64);
        put_vec_u64(out, &self.offsets);
        put_vec_u32(out, &self.targets);
    }

    fn decode_payload(r: &mut Reader) -> Result<Csr> {
        let n = r.u64()? as usize;
        // Vertex ids are u32; a larger n is corrupt and would overflow
        // id arithmetic downstream.
        if n > u32::MAX as usize {
            bail!("csr: num_vertices {n} exceeds the u32 id space");
        }
        let offsets = r.vec_u64()?;
        if offsets.len() != n + 1 {
            bail!("csr: offsets length {} != num_vertices+1 ({})", offsets.len(), n + 1);
        }
        if offsets[0] != 0 {
            bail!("csr: offsets[0] = {} != 0", offsets[0]);
        }
        if offsets.windows(2).any(|w| w[0] > w[1]) {
            bail!("csr: offsets not monotone");
        }
        let targets = r.vec_u32()?;
        if *offsets.last().unwrap() != targets.len() as u64 {
            bail!(
                "csr: last offset {} != edge count {}",
                offsets.last().unwrap(),
                targets.len()
            );
        }
        if targets.iter().any(|&t| t as usize >= n) {
            bail!("csr: target id out of range (n = {n})");
        }
        Ok(Csr { offsets, targets })
    }

    fn mem_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.targets.len() * 4) as u64
    }
}

impl Artifact for Vec<VertexId> {
    const KIND: [u8; 4] = *b"PERM";
    const NAME: &'static str = "perm";

    fn encode_payload(&self, out: &mut Vec<u8>) {
        put_vec_u32(out, self);
    }

    fn decode_payload(r: &mut Reader) -> Result<Vec<VertexId>> {
        let perm = r.vec_u32()?;
        // A relabeling must be a permutation of 0..n: anything else would
        // silently scramble results downstream.
        let n = perm.len();
        let mut seen = vec![false; n];
        for &p in &perm {
            let i = p as usize;
            if i >= n {
                bail!("perm: value {p} out of range (n = {n})");
            }
            if seen[i] {
                bail!("perm: duplicate value {p}");
            }
            seen[i] = true;
        }
        Ok(perm)
    }

    fn mem_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl Artifact for SegmentedCsr {
    const KIND: [u8; 4] = *b"SEG_";
    const NAME: &'static str = "seg";

    fn encode_payload(&self, out: &mut Vec<u8>) {
        put_u64(out, self.num_vertices as u64);
        put_u64(out, self.seg_size as u64);
        // The merge plan is derived (MergePlan::build) rather than stored:
        // only its block size is needed to reconstruct it exactly, and
        // rebuilding guarantees plan/segment consistency by construction.
        put_u64(out, self.merge_plan.block_size as u64);
        put_u64(out, self.segments.len() as u64);
        for seg in &self.segments {
            put_u32(out, seg.src_lo);
            put_u32(out, seg.src_hi);
            put_vec_u32(out, &seg.dst_ids);
            put_vec_u64(out, &seg.offsets);
            put_vec_u32(out, &seg.sources);
        }
    }

    fn decode_payload(r: &mut Reader) -> Result<SegmentedCsr> {
        let n = r.u64()? as usize;
        // Bounding n to the u32 id space also keeps the (s+1)*seg_size
        // range arithmetic below overflow-free for any decoded seg_size
        // (seg_size > n collapses to one segment).
        if n > u32::MAX as usize {
            bail!("seg: num_vertices {n} exceeds the u32 id space");
        }
        let seg_size = r.u64()? as usize;
        let block_size = r.u64()? as usize;
        if seg_size == 0 || block_size == 0 {
            bail!("seg: zero seg_size/block_size");
        }
        let k = r.u64()? as usize;
        if k != ceil_div(n.max(1), seg_size) {
            bail!("seg: {k} segments inconsistent with n={n}, seg_size={seg_size}");
        }
        let mut segments = Vec::with_capacity(k.min(1 << 20));
        for s in 0..k {
            let src_lo = r.u32()?;
            let src_hi = r.u32()?;
            // Ranges are fully determined by (n, seg_size); stored values
            // must agree or the file is corrupt.
            let want_lo = (s * seg_size) as u32;
            let want_hi = ((s + 1) * seg_size).min(n) as u32;
            if src_lo != want_lo || src_hi != want_hi {
                bail!("seg {s}: range [{src_lo},{src_hi}) != expected [{want_lo},{want_hi})");
            }
            let dst_ids = r.vec_u32()?;
            if dst_ids.windows(2).any(|w| w[0] >= w[1]) {
                bail!("seg {s}: dst_ids not strictly ascending");
            }
            if dst_ids.last().is_some_and(|&d| d as usize >= n) {
                bail!("seg {s}: dst id out of range");
            }
            let offsets = r.vec_u64()?;
            if offsets.len() != dst_ids.len() + 1 {
                bail!("seg {s}: offsets length {} != dsts+1", offsets.len());
            }
            if offsets.first() != Some(&0) || offsets.windows(2).any(|w| w[0] > w[1]) {
                bail!("seg {s}: offsets not monotone from 0");
            }
            let sources = r.vec_u32()?;
            if *offsets.last().unwrap_or(&0) != sources.len() as u64 {
                bail!("seg {s}: last offset != source count");
            }
            if sources.iter().any(|&u| u < src_lo || u >= src_hi) {
                bail!("seg {s}: source outside [{src_lo},{src_hi})");
            }
            segments.push(Segment {
                src_lo,
                src_hi,
                dst_ids,
                offsets,
                sources,
            });
        }
        let merge_plan = MergePlan::build(n, block_size, &segments);
        Ok(SegmentedCsr {
            num_vertices: n,
            seg_size,
            segments,
            merge_plan,
        })
    }

    fn mem_bytes(&self) -> u64 {
        let segs: u64 = self
            .segments
            .iter()
            .map(|s| (s.dst_ids.len() * 4 + s.offsets.len() * 8 + s.sources.len() * 4 + 8) as u64)
            .sum();
        segs + (self.merge_plan.starts.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    fn sample_csr(seed: u64) -> Csr {
        let (n, e) = generators::rmat(8, 6, generators::RmatParams::graph500(), seed);
        Csr::from_edges(n, &e)
    }

    fn roundtrip<T: Artifact + PartialEq + std::fmt::Debug>(v: &T) {
        let bytes = encode(v);
        let back: T = decode(&bytes).expect("roundtrip decode");
        assert_eq!(&back, v);
    }

    #[test]
    fn csr_roundtrip() {
        roundtrip(&sample_csr(3));
        roundtrip(&Csr::from_edges(1, &[])); // degenerate
    }

    #[test]
    fn perm_roundtrip() {
        let p: Vec<u32> = crate::util::rng::Rng::new(9).permutation(257);
        roundtrip(&p);
        roundtrip(&Vec::<u32>::new());
    }

    #[test]
    fn segmented_roundtrip_preserves_behaviour() {
        let g = sample_csr(5);
        let sg = SegmentedCsr::build_with_block(&g, 37, 16);
        let bytes = encode(&sg);
        let back: SegmentedCsr = decode(&bytes).unwrap();
        assert_eq!(back.num_vertices, sg.num_vertices);
        assert_eq!(back.seg_size, sg.seg_size);
        assert_eq!(back.num_segments(), sg.num_segments());
        // The derived merge plan must match the original exactly.
        assert_eq!(back.merge_plan.block_size, sg.merge_plan.block_size);
        assert_eq!(back.merge_plan.starts, sg.merge_plan.starts);
        // And aggregation over the decoded structure is bitwise identical.
        let vals: Vec<f64> = (0..g.num_vertices()).map(|i| (i as f64).cos()).collect();
        let mut b1 = crate::segment::SegmentBuffers::for_graph(&sg);
        let mut b2 = crate::segment::SegmentBuffers::for_graph(&back);
        let mut o1 = vec![0.0; g.num_vertices()];
        let mut o2 = vec![0.0; g.num_vertices()];
        sg.aggregate(|u| vals[u as usize], &mut b1, 0.0, &mut o1);
        back.aggregate(|u| vals[u as usize], &mut b2, 0.0, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn prop_roundtrip_generated_graphs() {
        check("codec roundtrip on generated graphs", 20, |gen| {
            let (n, edges) = gen.edges(1..120, 4);
            let g = Csr::from_edges(n, &edges);
            let bytes = encode(&g);
            assert_eq!(decode::<Csr>(&bytes).unwrap(), g);

            let perm = gen.permutation(n);
            let pbytes = encode(&perm);
            assert_eq!(decode::<Vec<u32>>(&pbytes).unwrap(), perm);

            let seg_size = gen.usize(1..n + 1);
            let sg = SegmentedCsr::build_with_block(&g, seg_size, 8);
            let sbytes = encode(&sg);
            let back = decode::<SegmentedCsr>(&sbytes).unwrap();
            assert_eq!(back.num_edges(), g.num_edges());
            assert_eq!(back.merge_plan.starts, sg.merge_plan.starts);
        });
    }

    #[test]
    fn truncation_always_errs() {
        let g = sample_csr(7);
        let bytes = encode(&g);
        // Every proper prefix must fail cleanly (never panic, never Ok).
        for cut in 0..bytes.len() {
            assert!(
                decode::<Csr>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} decoded",
                bytes.len()
            );
        }
    }

    #[test]
    fn bit_flips_always_err() {
        // Small graph so the exhaustive scan stays fast; every byte of the
        // frame is covered by magic/version/kind/length/checksum checks.
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4), (4, 0)]);
        let bytes = encode(&g);
        for i in 0..bytes.len() {
            for bit in [0u8, 3, 7] {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    decode::<Csr>(&bad).is_err(),
                    "flip at byte {i} bit {bit} decoded"
                );
            }
        }
    }

    #[test]
    fn kind_mismatch_rejected() {
        let p: Vec<u32> = vec![0, 1, 2];
        let bytes = encode(&p);
        assert!(decode::<Csr>(&bytes).is_err());
    }

    #[test]
    fn corrupt_perm_rejected() {
        // Duplicate + out-of-range values with a *valid* frame: rebuild
        // the frame around a hand-corrupted payload.
        for values in [vec![0u32, 0, 1], vec![0u32, 5, 1]] {
            let mut payload = Vec::new();
            put_vec_u32(&mut payload, &values);
            let mut bytes = Vec::new();
            bytes.extend_from_slice(&MAGIC);
            bytes.extend_from_slice(&CODEC_VERSION.to_le_bytes());
            bytes.extend_from_slice(&<Vec<VertexId> as Artifact>::KIND);
            bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            bytes.extend_from_slice(&payload);
            bytes.extend_from_slice(&checksum64(&payload).to_le_bytes());
            assert!(decode::<Vec<u32>>(&bytes).is_err(), "{values:?} accepted");
        }
    }

    #[test]
    fn file_roundtrip_and_missing_file() {
        let dir = std::env::temp_dir().join(format!("cagra-codec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.art");
        let g = sample_csr(11);
        let written = write_file(&path, &g).unwrap();
        let (back, read) = read_file::<Csr>(&path).unwrap();
        assert_eq!(back, g);
        assert_eq!(written, read);
        assert!(read_file::<Csr>(&dir.join("absent.art")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
