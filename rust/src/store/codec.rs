//! Versioned binary codec for preprocessing artifacts — v2 **section
//! layout**, designed so the on-disk bytes *are* the in-memory arrays.
//!
//! Matches the repo's zero-dependency idiom (`runtime/artifacts.rs`,
//! `graph/edgelist.rs`): hand-rolled little-endian framing, no serde.
//! Every artifact file is
//!
//! ```text
//! magic        [u8; 8]  "CAGART01"
//! version      u32 LE   CODEC_VERSION (= 2)
//! kind         [u8; 4]  artifact type tag (Artifact::KIND)
//! n_sections   u32 LE
//! meta_len     u32 LE
//! payload_len  u64 LE   bytes of the aligned section area
//! payload_crc  u64 LE   FNV-1a64+avalanche over the section area
//! table        n_sections × { elems u64, elem_size u32 }
//! meta         [u8]     type-specific metadata (counts, parameters)
//! header_crc   u64 LE   checksum over every byte above
//! zero pad     to the next 64-byte boundary
//! sections     each section starts 64-byte-aligned, raw LE elements,
//!              zero-padded to 64 between and after (canonical packing:
//!              section offsets are *implicit*, so the table cannot
//!              express overlap or misalignment)
//! footer       "CAGAREND" [8] + header_crc echo u64 + footer_crc u64
//! ```
//!
//! Because sections are 64-byte-aligned raw arrays, `ArtifactStore` can
//! `mmap` a file and hand the arrays out in place as
//! [`ArcSlice::Mapped`] windows — the zero-copy warm start (DESIGN.md
//! §6). The same frame decodes on platforms without mapping by copying
//! each section into owned storage.
//!
//! Decoding and mapping are paranoid by contract: bad magic, wrong
//! version, wrong kind, inconsistent lengths, checksum mismatch,
//! truncation, nonzero padding, trailing bytes, or any violated
//! structural invariant (non-monotone offsets, out-of-range ids,
//! non-permutations, section shapes that disagree with the metadata)
//! returns `Err` — never a panic, never a silently wrong value. Every
//! byte of the file is covered by one of the three checksums plus the
//! explicit zero-pad check, so *any* bit flip fails at map time.
//! Declared lengths are validated against the file size *before*
//! allocation so a corrupt header cannot trigger a huge allocation.

use super::fingerprint::hash_bytes;
use super::mmap::MappedRegion;
use super::slice::ArcSlice;
use crate::graph::{Csr, VertexId};
use crate::segment::{MergePlan, Segment, SegmentedCsr};
use crate::util::ceil_div;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// File magic ("CAGra ARTifact", format generation 01).
pub const MAGIC: [u8; 8] = *b"CAGART01";

/// End-of-file commit marker.
pub const FOOTER_MAGIC: [u8; 8] = *b"CAGAREND";

/// Bumped whenever any payload layout changes; old files are rejected
/// (and rebuilt by the store) rather than misread. v2 = section layout.
pub const CODEC_VERSION: u32 = 2;

/// Every section starts on this boundary (cache line; superset of any
/// element alignment we store).
pub const SECTION_ALIGN: usize = 64;

const HEADER_FIXED: usize = 40; // magic..payload_crc
const TABLE_ENTRY: usize = 12; // elems u64 + elem_size u32
const FOOTER_LEN: usize = 24; // footer magic + echo + crc

/// Sanity caps applied before any size arithmetic.
const MAX_SECTIONS: u32 = 1 << 24;
const MAX_META: u32 = 1 << 24;

/// Payload checksum: FNV-1a64 with a final avalanche.
pub fn checksum64(payload: &[u8]) -> u64 {
    hash_bytes(0x5EED_C0DE, payload)
}

fn align_up(x: usize, a: usize) -> Option<usize> {
    x.checked_add(a - 1).map(|v| v & !(a - 1))
}

/// One array of an artifact, borrowed for encoding.
pub enum SectionData<'a> {
    U32(&'a [u32]),
    U64(&'a [u64]),
}

impl SectionData<'_> {
    fn elems(&self) -> usize {
        match self {
            SectionData::U32(s) => s.len(),
            SectionData::U64(s) => s.len(),
        }
    }

    fn elem_size(&self) -> usize {
        match self {
            SectionData::U32(_) => 4,
            SectionData::U64(_) => 8,
        }
    }
}

/// A type that can be persisted in the artifact store.
pub trait Artifact: Sized {
    /// Four-byte header tag.
    const KIND: [u8; 4];
    /// Short name used in store filenames ("perm", "csr", "seg").
    const NAME: &'static str;
    /// Small type-specific metadata (counts, parameters) — covered by the
    /// header checksum.
    fn encode_meta(&self, out: &mut Vec<u8>);
    /// The array sections in canonical order.
    fn sections(&self) -> Vec<SectionData<'_>>;
    /// Rebuild from a validated frame view (mapped or heap-backed).
    fn from_view(view: &ArtifactView<'_>) -> Result<Self>;
    /// Approximate in-memory working-set footprint (array bytes,
    /// regardless of owned/mapped backing) — what the in-memory layer
    /// ([`super::MemStore`]) charges against its byte budget.
    fn mem_bytes(&self) -> u64;
    /// Bytes of `mem_bytes` that are mmap-backed (0 for decoded values):
    /// file pages shared across workers rather than private heap.
    fn mapped_bytes(&self) -> u64;
}

/// Bounds-checked little-endian reader over a byte slice (metadata and
/// other small variable-length regions).
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated artifact: wanted {n} bytes, {} left", self.remaining());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Assert the buffer was fully consumed.
    pub fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("corrupt artifact: {} trailing metadata bytes", self.remaining());
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Frame: encode
// ---------------------------------------------------------------------------

/// Encode `value` into a framed v2 artifact byte buffer.
pub fn encode<T: Artifact>(value: &T) -> Vec<u8> {
    let mut meta = Vec::new();
    value.encode_meta(&mut meta);
    let sections = value.sections();
    assert!(sections.len() < MAX_SECTIONS as usize && meta.len() < MAX_META as usize);

    // Section area: each section 64-aligned (relative to its own start,
    // which encode places on a 64-aligned file offset), zero-padded
    // between and after.
    let mut payload = Vec::new();
    for sec in &sections {
        debug_assert_eq!(payload.len() % SECTION_ALIGN, 0);
        match sec {
            SectionData::U32(xs) => {
                payload.reserve(xs.len() * 4);
                for &x in *xs {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            SectionData::U64(xs) => {
                payload.reserve(xs.len() * 8);
                for &x in *xs {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        payload.resize(align_up(payload.len(), SECTION_ALIGN).unwrap(), 0);
    }
    let payload_crc = checksum64(&payload);

    let mut out = Vec::with_capacity(
        HEADER_FIXED + sections.len() * TABLE_ENTRY + meta.len() + 8 + SECTION_ALIGN
            + payload.len()
            + FOOTER_LEN,
    );
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, CODEC_VERSION);
    out.extend_from_slice(&T::KIND);
    put_u32(&mut out, sections.len() as u32);
    put_u32(&mut out, meta.len() as u32);
    put_u64(&mut out, payload.len() as u64);
    put_u64(&mut out, payload_crc);
    debug_assert_eq!(out.len(), HEADER_FIXED);
    for sec in &sections {
        put_u64(&mut out, sec.elems() as u64);
        put_u32(&mut out, sec.elem_size() as u32);
    }
    out.extend_from_slice(&meta);
    let header_crc = checksum64(&out);
    put_u64(&mut out, header_crc);
    out.resize(align_up(out.len(), SECTION_ALIGN).unwrap(), 0);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&FOOTER_MAGIC);
    put_u64(&mut out, header_crc);
    let footer_crc = checksum64(&out[out.len() - 16..]);
    put_u64(&mut out, footer_crc);
    out
}

// ---------------------------------------------------------------------------
// Frame: validate + view
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct SectionInfo {
    /// Absolute byte offset in the file.
    offset: usize,
    elems: usize,
    elem_size: usize,
}

enum Backing<'a> {
    /// Full file bytes in a heap buffer — sections are copied out.
    Heap(&'a [u8]),
    /// Live mapping — sections become `ArcSlice::Mapped` windows.
    Mapped(&'a Arc<MappedRegion>),
}

/// A validated v2 frame: typed accessors over the section table.
pub struct ArtifactView<'a> {
    meta: &'a [u8],
    table: Vec<SectionInfo>,
    backing: Backing<'a>,
    /// True when this exact immutable region already passed full
    /// validation in this process (store map-cache hit): `from_view`
    /// implementations may skip pure re-validation scans, keeping repeat
    /// warm loads independent of |E|.
    trusted: bool,
}

impl<'a> ArtifactView<'a> {
    pub fn meta(&self) -> Reader<'a> {
        Reader::new(self.meta)
    }

    pub fn num_sections(&self) -> usize {
        self.table.len()
    }

    pub fn trusted(&self) -> bool {
        self.trusted
    }

    fn section(&self, idx: usize, elem_size: usize) -> Result<SectionInfo> {
        let info = *self
            .table
            .get(idx)
            .ok_or_else(|| anyhow::anyhow!("corrupt artifact: missing section {idx}"))?;
        if info.elem_size != elem_size {
            bail!(
                "corrupt artifact: section {idx} has {}-byte elements, expected {elem_size}",
                info.elem_size
            );
        }
        Ok(info)
    }

    /// Section `idx` as a `u32` array — zero-copy on mapped backings.
    pub fn section_u32(&self, idx: usize) -> Result<ArcSlice<u32>> {
        let info = self.section(idx, 4)?;
        match &self.backing {
            Backing::Mapped(region) => {
                ArcSlice::from_region((*region).clone(), info.offset, info.elems)
                    .ok_or_else(|| anyhow::anyhow!("corrupt artifact: section {idx} out of bounds"))
            }
            Backing::Heap(bytes) => {
                let raw = &bytes[info.offset..info.offset + info.elems * 4];
                Ok(raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect::<Vec<u32>>()
                    .into())
            }
        }
    }

    /// Section `idx` as a `u64` array — zero-copy on mapped backings.
    pub fn section_u64(&self, idx: usize) -> Result<ArcSlice<u64>> {
        let info = self.section(idx, 8)?;
        match &self.backing {
            Backing::Mapped(region) => {
                ArcSlice::from_region((*region).clone(), info.offset, info.elems)
                    .ok_or_else(|| anyhow::anyhow!("corrupt artifact: section {idx} out of bounds"))
            }
            Backing::Heap(bytes) => {
                let raw = &bytes[info.offset..info.offset + info.elems * 8];
                Ok(raw
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect::<Vec<u64>>()
                    .into())
            }
        }
    }
}

/// Validate the whole frame of `bytes` for artifact kind `kind`.
/// `verify_payload` controls the O(file) section-area checksum scan —
/// always on except for map-cache hits on already-validated regions.
fn validate_frame(bytes: &[u8], kind: [u8; 4], verify_payload: bool) -> Result<(Vec<SectionInfo>, std::ops::Range<usize>)> {
    if bytes.len() < HEADER_FIXED + 8 + FOOTER_LEN {
        bail!("truncated artifact: {} bytes", bytes.len());
    }
    let mut r = Reader::new(bytes);
    if r.bytes(8)? != MAGIC {
        bail!("bad magic: not an artifact file");
    }
    let version = r.u32()?;
    if version != CODEC_VERSION {
        bail!("unsupported artifact codec version {version} (this build reads v{CODEC_VERSION})");
    }
    let file_kind = r.bytes(4)?;
    if file_kind != kind {
        bail!(
            "artifact kind mismatch: file has {:?}, expected {:?}",
            String::from_utf8_lossy(file_kind),
            String::from_utf8_lossy(&kind)
        );
    }
    let n_sections = r.u32()?;
    let meta_len = r.u32()?;
    if n_sections > MAX_SECTIONS || meta_len > MAX_META {
        bail!("corrupt artifact: implausible table ({n_sections} sections, {meta_len} meta bytes)");
    }
    let payload_len = usize::try_from(r.u64()?)
        .map_err(|_| anyhow::anyhow!("corrupt artifact: payload length overflows"))?;
    let payload_crc = r.u64()?;
    let hdr_end = HEADER_FIXED + n_sections as usize * TABLE_ENTRY + meta_len as usize;
    let Some(sections_start) = align_up(hdr_end + 8, SECTION_ALIGN) else {
        bail!("corrupt artifact: header size overflows");
    };
    let footer_off = sections_start
        .checked_add(payload_len)
        .ok_or_else(|| anyhow::anyhow!("corrupt artifact: payload size overflows"))?;
    let expect_len = footer_off
        .checked_add(FOOTER_LEN)
        .ok_or_else(|| anyhow::anyhow!("corrupt artifact: file size overflows"))?;
    if bytes.len() != expect_len {
        bail!(
            "corrupt artifact: file is {} bytes, frame declares {expect_len}",
            bytes.len()
        );
    }
    // Header checksum covers fixed header + table + meta.
    let header_crc =
        u64::from_le_bytes(bytes[hdr_end..hdr_end + 8].try_into().unwrap());
    if checksum64(&bytes[..hdr_end]) != header_crc {
        bail!("artifact header checksum mismatch: corrupt file");
    }
    // Footer: commit marker tied to this header.
    let f = &bytes[footer_off..];
    if f[..8] != FOOTER_MAGIC {
        bail!("artifact footer missing: truncated or torn write");
    }
    if u64::from_le_bytes(f[8..16].try_into().unwrap()) != header_crc {
        bail!("artifact footer does not match header: torn write");
    }
    let footer_crc = u64::from_le_bytes(f[16..24].try_into().unwrap());
    if checksum64(&f[..16]) != footer_crc {
        bail!("artifact footer checksum mismatch: corrupt file");
    }
    // The pad between header_crc and the section area must be zero (it is
    // the only region no checksum covers).
    if bytes[hdr_end + 8..sections_start].iter().any(|&b| b != 0) {
        bail!("corrupt artifact: nonzero header padding");
    }
    if verify_payload && checksum64(&bytes[sections_start..footer_off]) != payload_crc {
        bail!("artifact section checksum mismatch: corrupt file");
    }
    // Walk the table; section offsets are implicit canonical packing, so
    // overlap/misalignment cannot be expressed — only total-size mismatch.
    let mut table = Vec::with_capacity(n_sections as usize);
    let mut cur = sections_start;
    for i in 0..n_sections as usize {
        let at = HEADER_FIXED + i * TABLE_ENTRY;
        let elems = u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap());
        let elem_size = u32::from_le_bytes(bytes[at + 8..at + 12].try_into().unwrap());
        if elem_size != 4 && elem_size != 8 {
            bail!("corrupt artifact: section {i} has element size {elem_size}");
        }
        let elems = usize::try_from(elems)
            .ok()
            .filter(|&e| e <= payload_len / elem_size as usize)
            .ok_or_else(|| anyhow::anyhow!("corrupt artifact: section {i} larger than payload"))?;
        let byte_len = elems * elem_size as usize;
        let end = cur
            .checked_add(byte_len)
            .and_then(|e| align_up(e, SECTION_ALIGN))
            .ok_or_else(|| anyhow::anyhow!("corrupt artifact: section {i} overflows"))?;
        if end > footer_off {
            bail!("corrupt artifact: section {i} exceeds the section area");
        }
        table.push(SectionInfo {
            offset: cur,
            elems,
            elem_size: elem_size as usize,
        });
        cur = end;
    }
    if cur != footer_off {
        bail!(
            "corrupt artifact: section area is {} bytes, table accounts for {}",
            payload_len,
            cur - sections_start
        );
    }
    Ok((table, hdr_end - meta_len as usize..hdr_end))
}

/// Decode a framed artifact from heap bytes (the read-and-decode
/// fallback): full validation, sections copied into owned storage.
pub fn decode<T: Artifact>(bytes: &[u8]) -> Result<T> {
    crate::fault::failpoint(crate::fault::Site::StoreDecode)?;
    let (table, meta_range) = validate_frame(bytes, T::KIND, true)?;
    let view = ArtifactView {
        meta: &bytes[meta_range],
        table,
        backing: Backing::Heap(bytes),
        trusted: false,
    };
    T::from_view(&view)
}

/// Build an artifact over a live mapping: the arrays are handed out in
/// place as [`ArcSlice::Mapped`] windows keeping `region` alive.
/// `trusted` skips the O(file) checksum and the structural re-validation
/// scans — only valid when this exact region already passed
/// `trusted = false` validation in this process.
pub fn from_mapped<T: Artifact>(region: &Arc<MappedRegion>, trusted: bool) -> Result<T> {
    let bytes = region.bytes();
    let (table, meta_range) = validate_frame(bytes, T::KIND, !trusted)?;
    let view = ArtifactView {
        meta: &bytes[meta_range],
        table,
        backing: Backing::Mapped(region),
        trusted,
    };
    T::from_view(&view)
}

/// Map + validate + construct in one step. Returns the value and the
/// region (for the caller's map cache).
pub fn map_file<T: Artifact>(path: &Path) -> Result<(T, Arc<MappedRegion>)> {
    let region = Arc::new(MappedRegion::map(path)?);
    let value = from_mapped::<T>(&region, false)
        .with_context(|| format!("mapping artifact {}", path.display()))?;
    Ok((value, region))
}

/// Read the frame prelude of an artifact file without decoding it:
/// `(codec_version, kind)`. Used by `cagra cache stats` to diagnose
/// mixed-version stores.
pub fn peek_version(path: &Path) -> Result<(u32, [u8; 4])> {
    use std::io::Read;
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut head = [0u8; 16];
    f.read_exact(&mut head)
        .with_context(|| format!("reading {} header", path.display()))?;
    if head[..8] != MAGIC {
        bail!("{}: not an artifact file", path.display());
    }
    let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
    let kind = [head[12], head[13], head[14], head[15]];
    Ok((version, kind))
}

/// Run `write` against a unique temp path next to `path`, then rename
/// into place — the one implementation of the crash-safe write pattern
/// (artifact files here, the dataset edge-list cache in
/// `graph/datasets.rs`). The temp name (`.tmp<pid>-<seq>`, the shape the
/// store's orphan sweep recognizes) is unique per process *and* per
/// call, so two threads racing to produce the same file can never
/// interleave into one temp (the loser's rename just replaces the
/// winner's identical bytes). Replacement is always a *new inode*, which
/// is what keeps live mappings of the old file valid (store/mmap.rs).
/// The temp file is removed on failure.
pub fn write_atomic(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "tmp{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let result = write(&tmp).and_then(|()| {
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
    });
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

/// Encode + write atomically (temp file, then rename). Returns file size.
pub fn write_file<T: Artifact>(path: &Path, value: &T) -> Result<u64> {
    crate::fault::failpoint(crate::fault::Site::StoreWrite)?;
    let bytes = encode(value);
    write_atomic(path, |tmp| {
        std::fs::write(tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))
    })?;
    Ok(bytes.len() as u64)
}

/// Read + decode a file. Returns the value and the file size.
pub fn read_file<T: Artifact>(path: &Path) -> Result<(T, u64)> {
    crate::fault::failpoint(crate::fault::Site::StoreRead)?;
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let value =
        decode::<T>(&bytes).with_context(|| format!("decoding artifact {}", path.display()))?;
    Ok((value, bytes.len() as u64))
}

// ---------------------------------------------------------------------------
// Artifact implementations
// ---------------------------------------------------------------------------

impl Artifact for Csr {
    const KIND: [u8; 4] = *b"CSR_";
    const NAME: &'static str = "csr";

    fn encode_meta(&self, out: &mut Vec<u8>) {
        put_u64(out, self.num_vertices() as u64);
    }

    fn sections(&self) -> Vec<SectionData<'_>> {
        vec![SectionData::U64(&self.offsets), SectionData::U32(&self.targets)]
    }

    fn from_view(view: &ArtifactView<'_>) -> Result<Csr> {
        let mut m = view.meta();
        let n = m.u64()? as usize;
        m.done()?;
        // Vertex ids are u32; a larger n is corrupt and would overflow
        // id arithmetic downstream.
        if n > u32::MAX as usize {
            bail!("csr: num_vertices {n} exceeds the u32 id space");
        }
        if view.num_sections() != 2 {
            bail!("csr: expected 2 sections, file has {}", view.num_sections());
        }
        let offsets = view.section_u64(0)?;
        let targets = view.section_u32(1)?;
        if offsets.len() != n + 1 {
            bail!("csr: offsets length {} != num_vertices+1 ({})", offsets.len(), n + 1);
        }
        if *offsets.last().unwrap() != targets.len() as u64 {
            bail!(
                "csr: last offset {} != edge count {}",
                offsets.last().unwrap(),
                targets.len()
            );
        }
        if !view.trusted() {
            if offsets[0] != 0 {
                bail!("csr: offsets[0] = {} != 0", offsets[0]);
            }
            if offsets.windows(2).any(|w| w[0] > w[1]) {
                bail!("csr: offsets not monotone");
            }
            if targets.iter().any(|&t| t as usize >= n) {
                bail!("csr: target id out of range (n = {n})");
            }
        }
        Ok(Csr { offsets, targets })
    }

    fn mem_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.targets.len() * 4) as u64
    }

    fn mapped_bytes(&self) -> u64 {
        self.offsets.mapped_bytes() + self.targets.mapped_bytes()
    }
}

impl Artifact for ArcSlice<VertexId> {
    const KIND: [u8; 4] = *b"PERM";
    const NAME: &'static str = "perm";

    fn encode_meta(&self, _out: &mut Vec<u8>) {}

    fn sections(&self) -> Vec<SectionData<'_>> {
        vec![SectionData::U32(self)]
    }

    fn from_view(view: &ArtifactView<'_>) -> Result<ArcSlice<VertexId>> {
        view.meta().done()?;
        if view.num_sections() != 1 {
            bail!("perm: expected 1 section, file has {}", view.num_sections());
        }
        let perm = view.section_u32(0)?;
        if !view.trusted() {
            // A relabeling must be a permutation of 0..n: anything else
            // would silently scramble results downstream.
            let n = perm.len();
            let mut seen = vec![false; n];
            for &p in perm.iter() {
                let i = p as usize;
                if i >= n {
                    bail!("perm: value {p} out of range (n = {n})");
                }
                if seen[i] {
                    bail!("perm: duplicate value {p}");
                }
                seen[i] = true;
            }
        }
        Ok(perm)
    }

    fn mem_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }

    fn mapped_bytes(&self) -> u64 {
        ArcSlice::mapped_bytes(self)
    }
}

impl Artifact for SegmentedCsr {
    const KIND: [u8; 4] = *b"SEG_";
    const NAME: &'static str = "seg";

    fn encode_meta(&self, out: &mut Vec<u8>) {
        put_u64(out, self.num_vertices as u64);
        put_u64(out, self.seg_size as u64);
        // The merge plan is derived (MergePlan::build) rather than stored:
        // only its block size is needed to reconstruct it exactly, and
        // rebuilding guarantees plan/segment consistency by construction.
        put_u64(out, self.merge_plan.block_size as u64);
        put_u64(out, self.segments.len() as u64);
    }

    fn sections(&self) -> Vec<SectionData<'_>> {
        let mut out = Vec::with_capacity(self.segments.len() * 3);
        for seg in &self.segments {
            out.push(SectionData::U32(&seg.dst_ids));
            out.push(SectionData::U64(&seg.offsets));
            out.push(SectionData::U32(&seg.sources));
        }
        out
    }

    fn from_view(view: &ArtifactView<'_>) -> Result<SegmentedCsr> {
        let mut m = view.meta();
        let n = m.u64()? as usize;
        // Bounding n to the u32 id space also keeps the (s+1)*seg_size
        // range arithmetic below overflow-free for any decoded seg_size
        // (seg_size > n collapses to one segment).
        if n > u32::MAX as usize {
            bail!("seg: num_vertices {n} exceeds the u32 id space");
        }
        let seg_size = m.u64()? as usize;
        let block_size = m.u64()? as usize;
        if seg_size == 0 || block_size == 0 {
            bail!("seg: zero seg_size/block_size");
        }
        let k = m.u64()? as usize;
        m.done()?;
        if k != ceil_div(n.max(1), seg_size) {
            bail!("seg: {k} segments inconsistent with n={n}, seg_size={seg_size}");
        }
        if view.num_sections() != k * 3 {
            bail!(
                "seg: expected {} sections for {k} segments, file has {}",
                k * 3,
                view.num_sections()
            );
        }
        let mut segments = Vec::with_capacity(k);
        for s in 0..k {
            // Ranges are fully determined by (n, seg_size).
            let src_lo = (s * seg_size) as u32;
            let src_hi = ((s + 1) * seg_size).min(n) as u32;
            let dst_ids = view.section_u32(s * 3)?;
            let offsets = view.section_u64(s * 3 + 1)?;
            let sources = view.section_u32(s * 3 + 2)?;
            if offsets.len() != dst_ids.len() + 1 {
                bail!("seg {s}: offsets length {} != dsts+1", offsets.len());
            }
            if *offsets.last().unwrap_or(&0) != sources.len() as u64 {
                bail!("seg {s}: last offset != source count");
            }
            if !view.trusted() {
                // Full structural validation: the merge kernel writes
                // through dst_ids and the per-segment SpMV reads sources
                // unchecked, so both must be proven in range before any
                // hot loop trusts them.
                if dst_ids.windows(2).any(|w| w[0] >= w[1]) {
                    bail!("seg {s}: dst_ids not strictly ascending");
                }
                if dst_ids.last().is_some_and(|&d| d as usize >= n) {
                    bail!("seg {s}: dst id out of range");
                }
                if offsets.first() != Some(&0) || offsets.windows(2).any(|w| w[0] > w[1]) {
                    bail!("seg {s}: offsets not monotone from 0");
                }
                if sources.iter().any(|&u| u < src_lo || u >= src_hi) {
                    bail!("seg {s}: source outside [{src_lo},{src_hi})");
                }
            }
            segments.push(Segment {
                src_lo,
                src_hi,
                dst_ids,
                offsets,
                sources,
            });
        }
        let merge_plan = MergePlan::build(n, block_size, &segments);
        Ok(SegmentedCsr {
            num_vertices: n,
            seg_size,
            segments,
            merge_plan,
        })
    }

    fn mem_bytes(&self) -> u64 {
        let segs: u64 = self
            .segments
            .iter()
            .map(|s| (s.dst_ids.len() * 4 + s.offsets.len() * 8 + s.sources.len() * 4 + 8) as u64)
            .sum();
        segs + (self.merge_plan.starts.len() * 8) as u64
    }

    fn mapped_bytes(&self) -> u64 {
        self.segments
            .iter()
            .map(|s| {
                s.dst_ids.mapped_bytes() + s.offsets.mapped_bytes() + s.sources.mapped_bytes()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::store::mmap;
    use crate::util::prop::check;

    fn sample_csr(seed: u64) -> Csr {
        let (n, e) = generators::rmat(8, 6, generators::RmatParams::graph500(), seed);
        Csr::from_edges(n, &e)
    }

    fn roundtrip<T: Artifact + PartialEq + std::fmt::Debug>(v: &T) {
        let bytes = encode(v);
        let back: T = decode(&bytes).expect("roundtrip decode");
        assert_eq!(&back, v);
    }

    #[test]
    fn csr_roundtrip() {
        roundtrip(&sample_csr(3));
        roundtrip(&Csr::from_edges(1, &[])); // degenerate
    }

    #[test]
    fn perm_roundtrip() {
        let p: ArcSlice<u32> = crate::util::rng::Rng::new(9).permutation(257).into();
        roundtrip(&p);
        roundtrip(&ArcSlice::<u32>::default());
    }

    #[test]
    fn sections_are_aligned() {
        let g = sample_csr(4);
        let bytes = encode(&g);
        let (table, _) = validate_frame(&bytes, Csr::KIND, true).unwrap();
        for info in &table {
            assert_eq!(info.offset % SECTION_ALIGN, 0, "section at {}", info.offset);
        }
    }

    #[test]
    fn segmented_roundtrip_preserves_behaviour() {
        let g = sample_csr(5);
        let sg = SegmentedCsr::build_with_block(&g, 37, 16);
        let bytes = encode(&sg);
        let back: SegmentedCsr = decode(&bytes).unwrap();
        assert_eq!(back.num_vertices, sg.num_vertices);
        assert_eq!(back.seg_size, sg.seg_size);
        assert_eq!(back.num_segments(), sg.num_segments());
        // The derived merge plan must match the original exactly.
        assert_eq!(back.merge_plan.block_size, sg.merge_plan.block_size);
        assert_eq!(back.merge_plan.starts, sg.merge_plan.starts);
        // And aggregation over the decoded structure is bitwise identical.
        let vals: Vec<f64> = (0..g.num_vertices()).map(|i| (i as f64).cos()).collect();
        let mut b1 = crate::segment::SegmentBuffers::for_graph(&sg);
        let mut b2 = crate::segment::SegmentBuffers::for_graph(&back);
        let mut o1 = vec![0.0; g.num_vertices()];
        let mut o2 = vec![0.0; g.num_vertices()];
        sg.aggregate(|u| vals[u as usize], &mut b1, 0.0, &mut o1);
        back.aggregate(|u| vals[u as usize], &mut b2, 0.0, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn prop_roundtrip_generated_graphs() {
        check("codec roundtrip on generated graphs", 20, |gen| {
            let (n, edges) = gen.edges(1..120, 4);
            let g = Csr::from_edges(n, &edges);
            let bytes = encode(&g);
            assert_eq!(decode::<Csr>(&bytes).unwrap(), g);

            let perm: ArcSlice<u32> = gen.permutation(n).into();
            let pbytes = encode(&perm);
            assert_eq!(decode::<ArcSlice<u32>>(&pbytes).unwrap(), perm);

            let seg_size = gen.usize(1..n + 1);
            let sg = SegmentedCsr::build_with_block(&g, seg_size, 8);
            let sbytes = encode(&sg);
            let back = decode::<SegmentedCsr>(&sbytes).unwrap();
            assert_eq!(back.num_edges(), g.num_edges());
            assert_eq!(back.merge_plan.starts, sg.merge_plan.starts);
        });
    }

    #[test]
    fn truncation_always_errs() {
        let g = sample_csr(7);
        let bytes = encode(&g);
        // Every proper prefix must fail cleanly (never panic, never Ok).
        for cut in 0..bytes.len() {
            assert!(
                decode::<Csr>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} decoded",
                bytes.len()
            );
        }
    }

    #[test]
    fn bit_flips_always_err() {
        // Small graph so the exhaustive scan stays fast; every byte of the
        // frame is covered by header/payload/footer checksums plus the
        // zero-pad check.
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4), (4, 0)]);
        let bytes = encode(&g);
        for i in 0..bytes.len() {
            for bit in [0u8, 3, 7] {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    decode::<Csr>(&bad).is_err(),
                    "flip at byte {i} bit {bit} decoded"
                );
            }
        }
    }

    #[test]
    fn kind_mismatch_rejected() {
        let p: ArcSlice<u32> = vec![0u32, 1, 2].into();
        let bytes = encode(&p);
        assert!(decode::<Csr>(&bytes).is_err());
    }

    #[test]
    fn v1_frames_are_rejected_not_misread() {
        // A syntactically plausible v1 frame (old length-prefixed layout)
        // must fail on the version check — the store then rebuilds.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"CSR_");
        bytes.extend_from_slice(&0u64.to_le_bytes()); // v1 payload length
        bytes.extend_from_slice(&checksum64(&[]).to_le_bytes());
        let err = decode::<Csr>(&bytes).unwrap_err();
        assert!(
            format!("{err:#}").contains("version"),
            "v1 rejection must name the version: {err:#}"
        );
    }

    #[test]
    fn corrupt_perm_rejected() {
        // Duplicate + out-of-range values behind a *valid* frame: encode a
        // well-formed slice, then the values themselves are the corruption.
        for values in [vec![0u32, 0, 1], vec![0u32, 5, 1]] {
            let bad: ArcSlice<u32> = values.clone().into();
            let bytes = encode(&bad);
            assert!(decode::<ArcSlice<u32>>(&bytes).is_err(), "{values:?} accepted");
        }
    }

    #[test]
    fn malformed_section_table_rejected() {
        // Corrupt the table in ways the implicit-offset design must catch:
        // a bad element size and an inflated element count (both with the
        // header checksum recomputed so only the table check can object).
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let base = encode(&g);
        let hdr_end = HEADER_FIXED + 2 * TABLE_ENTRY + 8; // 2 sections + n meta
        let refit = |mutate: &dyn Fn(&mut Vec<u8>)| {
            let mut b = base.clone();
            mutate(&mut b);
            let crc = checksum64(&b[..hdr_end]);
            b[hdr_end..hdr_end + 8].copy_from_slice(&crc.to_le_bytes());
            let flen = b.len();
            b[flen - 16..flen - 8].copy_from_slice(&crc.to_le_bytes());
            let fcrc = checksum64(&b[flen - 24..flen - 8]);
            b[flen - 8..].copy_from_slice(&fcrc.to_le_bytes());
            b
        };
        // elem_size 3 on section 0.
        let bad = refit(&|b: &mut Vec<u8>| {
            b[HEADER_FIXED + 8..HEADER_FIXED + 12].copy_from_slice(&3u32.to_le_bytes());
        });
        assert!(decode::<Csr>(&bad).is_err(), "elem_size 3 accepted");
        // Element count inflated past the section area.
        let bad = refit(&|b: &mut Vec<u8>| {
            b[HEADER_FIXED..HEADER_FIXED + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        });
        assert!(decode::<Csr>(&bad).is_err(), "oversized section accepted");
    }

    #[test]
    fn mapped_equals_decoded() {
        let dir = std::env::temp_dir().join(format!("cagra-codec-map-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let g = sample_csr(21);
        let sg = SegmentedCsr::build_with_block(&g, 41, 16);
        let perm: ArcSlice<u32> = crate::util::rng::Rng::new(3).permutation(101).into();
        let pg = dir.join("g.art");
        let ps = dir.join("s.art");
        let pp = dir.join("p.art");
        write_file(&pg, &g).unwrap();
        write_file(&ps, &sg).unwrap();
        write_file(&pp, &perm).unwrap();
        if mmap::mmap_supported() {
            let (mg, _r) = map_file::<Csr>(&pg).unwrap();
            assert!(mg.offsets.is_mapped() && mg.targets.is_mapped());
            assert_eq!(mg, g, "mapped CSR == built CSR by contents");
            assert_eq!(Artifact::mapped_bytes(&mg), mg.mem_bytes());
            let (ms, _r) = map_file::<SegmentedCsr>(&ps).unwrap();
            assert_eq!(ms.merge_plan.starts, sg.merge_plan.starts);
            for (a, b) in ms.segments.iter().zip(&sg.segments) {
                assert_eq!(a.dst_ids, b.dst_ids);
                assert_eq!(a.offsets, b.offsets);
                assert_eq!(a.sources, b.sources);
                assert!(a.dst_ids.is_mapped());
            }
            let (mp, region) = map_file::<ArcSlice<u32>>(&pp).unwrap();
            assert_eq!(mp, perm);
            // Trusted re-view over the validated region matches too.
            let again = from_mapped::<ArcSlice<u32>>(&region, true).unwrap();
            assert_eq!(again, perm);
        } else {
            assert!(map_file::<Csr>(&pg).is_err(), "stub platform must fail cleanly");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mapped_corruption_always_errs_at_map_time() {
        if !mmap::mmap_supported() {
            return;
        }
        let dir = std::env::temp_dir().join(format!("cagra-codec-mapbad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4), (4, 0)]);
        let bytes = encode(&g);
        // Truncations (stride keeps the test fast; always include the
        // tail, where the footer commit marker lives).
        for cut in (0..bytes.len()).step_by(7).chain([bytes.len() - 1]) {
            let p = dir.join("t.art");
            std::fs::write(&p, &bytes[..cut]).unwrap();
            assert!(map_file::<Csr>(&p).is_err(), "mapped truncation at {cut} accepted");
        }
        // Bit flips over every byte.
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            let p = dir.join("b.art");
            std::fs::write(&p, &bad).unwrap();
            assert!(map_file::<Csr>(&p).is_err(), "mapped flip at byte {i} accepted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_roundtrip_and_missing_file() {
        let dir = std::env::temp_dir().join(format!("cagra-codec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.art");
        let g = sample_csr(11);
        let written = write_file(&path, &g).unwrap();
        let (back, read) = read_file::<Csr>(&path).unwrap();
        assert_eq!(back, g);
        assert_eq!(written, read);
        assert!(read_file::<Csr>(&dir.join("absent.art")).is_err());
        let (version, kind) = peek_version(&path).unwrap();
        assert_eq!((version, kind), (CODEC_VERSION, Csr::KIND));
        std::fs::remove_dir_all(&dir).ok();
    }
}
