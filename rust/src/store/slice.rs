//! [`ArcSlice`] — the unified storage slice behind every persistent
//! graph array (DESIGN.md §6).
//!
//! `Csr`, `SegmentedCsr`, and cached permutations no longer own
//! `Vec<u64>`/`Vec<u32>` directly; they hold `ArcSlice<T>`, which is
//! either a heap array (`Owned`) or a typed window into an mmap'd v2
//! artifact (`Mapped`). Both deref to `&[T]`, so every hot loop reads
//! through the same slice code it always did. A `Mapped` slice keeps its
//! [`MappedRegion`] alive by refcount: the mapping is unmapped when the
//! last slice over it drops.
//!
//! Ownership rules:
//! - Clones are O(1) refcount bumps for both variants — N serve workers
//!   holding the same graph share one physical copy.
//! - Equality is by *contents* (`PartialEq` via `&[T]`), exactly the
//!   semantics the old `Vec` fields had; mapped-vs-owned provenance never
//!   affects comparisons or results.
//! - The backing bytes are immutable. Anything that needs to mutate
//!   (e.g. `Csr::sorted`) copies out with [`ArcSlice::to_vec`] and
//!   rebuilds an `Owned` slice.

use super::mmap::MappedRegion;
use std::ops::Deref;
use std::sync::Arc;

/// Marker for element types whose every bit pattern is valid and whose
/// on-disk little-endian layout equals the in-memory layout on the
/// platforms where mapping is enabled (mmap.rs gates on little-endian).
///
/// # Safety
/// Implementors must be plain-old-data: `Copy`, no padding, no niches,
/// any byte pattern valid.
pub unsafe trait Pod: Copy + Send + Sync + 'static {}

// SAFETY: u8 is Copy, size 1, no padding or niches; every bit pattern is
// a valid value and the on-disk byte is the in-memory byte.
unsafe impl Pod for u8 {}
// SAFETY: u32 is Copy, fixed 4-byte little-endian layout on the
// platforms where mapping is enabled (mmap.rs gates on little-endian),
// no padding/niches, any bit pattern valid.
unsafe impl Pod for u32 {}
// SAFETY: as for u32, with a fixed 8-byte little-endian layout.
unsafe impl Pod for u64 {}

enum Repr<T: Pod> {
    /// Heap-backed. `Arc<Vec<T>>` (not a bare `Vec`) so clones stay O(1)
    /// refcount bumps — construction-time code never mutates through an
    /// `ArcSlice`, so the shared immutability is unobservable.
    Owned(Arc<Vec<T>>),
    /// A typed window into a mapped v2 artifact: `len` elements starting
    /// `byte_offset` bytes into the region. The codec validates at map
    /// time that the window is in-bounds and aligned for `T` (sections
    /// start on 64-byte boundaries).
    Mapped {
        region: Arc<MappedRegion>,
        byte_offset: usize,
        len: usize,
    },
}

/// A refcounted immutable array: owned heap storage or a window into a
/// mapped artifact file. Derefs to `&[T]`.
pub struct ArcSlice<T: Pod>(Repr<T>);

impl<T: Pod> ArcSlice<T> {
    /// Wrap an owned vector (the no-store / cold-build path).
    pub fn from_vec(v: Vec<T>) -> ArcSlice<T> {
        ArcSlice(Repr::Owned(Arc::new(v)))
    }

    /// A typed window into `region`.
    ///
    /// # Safety contract (checked, returns `None` on violation)
    /// `byte_offset` must be aligned for `T` and `byte_offset + len*size`
    /// must lie within the region. The codec upholds the stronger v2
    /// contract (64-byte-aligned sections) before calling this.
    pub fn from_region(
        region: Arc<MappedRegion>,
        byte_offset: usize,
        len: usize,
    ) -> Option<ArcSlice<T>> {
        let size = std::mem::size_of::<T>();
        let bytes = len.checked_mul(size)?;
        let end = byte_offset.checked_add(bytes)?;
        if end > region.len() || byte_offset % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(ArcSlice(Repr::Mapped {
            region,
            byte_offset,
            len,
        }))
    }

    pub fn as_slice(&self) -> &[T] {
        match &self.0 {
            Repr::Owned(v) => v.as_slice(),
            Repr::Mapped {
                region,
                byte_offset,
                len,
            } => {
                // SAFETY: from_region checked bounds + alignment against
                // the immutable PROT_READ region, which `region` keeps
                // alive; T is Pod so any bytes are a valid value.
                unsafe {
                    std::slice::from_raw_parts(
                        region.as_ptr().add(*byte_offset) as *const T,
                        *len,
                    )
                }
            }
        }
    }

    /// True when backed by a mapped artifact file (zero-copy warm load).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }

    /// Bytes of *heap* this slice pins (0 for mapped storage — the pages
    /// are file-backed and shared).
    pub fn heap_bytes(&self) -> usize {
        match &self.0 {
            Repr::Owned(v) => v.capacity() * std::mem::size_of::<T>(),
            Repr::Mapped { .. } => 0,
        }
    }

    /// Bytes of *mapped* file pages this slice covers (0 for owned
    /// storage) — the complement of [`ArcSlice::heap_bytes`], reported as
    /// the serve-side shared-resident stat.
    pub fn mapped_bytes(&self) -> u64 {
        match &self.0 {
            Repr::Owned(_) => 0,
            Repr::Mapped { len, .. } => (len * std::mem::size_of::<T>()) as u64,
        }
    }

    /// Copy the contents out into a fresh owned vector.
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }
}

impl<T: Pod> From<Vec<T>> for ArcSlice<T> {
    fn from(v: Vec<T>) -> ArcSlice<T> {
        ArcSlice::from_vec(v)
    }
}

impl<T: Pod> Deref for ArcSlice<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> Clone for ArcSlice<T> {
    fn clone(&self) -> ArcSlice<T> {
        ArcSlice(match &self.0 {
            Repr::Owned(v) => Repr::Owned(v.clone()),
            Repr::Mapped {
                region,
                byte_offset,
                len,
            } => Repr::Mapped {
                region: region.clone(),
                byte_offset: *byte_offset,
                len: *len,
            },
        })
    }
}

impl<T: Pod> Default for ArcSlice<T> {
    fn default() -> ArcSlice<T> {
        ArcSlice::from_vec(Vec::new())
    }
}

impl<T: Pod + PartialEq> PartialEq for ArcSlice<T> {
    fn eq(&self, other: &ArcSlice<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + Eq> Eq for ArcSlice<T> {}

impl<T: Pod + PartialEq> PartialEq<Vec<T>> for ArcSlice<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq, const N: usize> PartialEq<[T; N]> for ArcSlice<T> {
    fn eq(&self, other: &[T; N]) -> bool {
        self.as_slice() == other
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for ArcSlice<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

impl<T: Pod + std::hash::Hash> std::hash::Hash for ArcSlice<T> {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.as_slice().hash(state);
    }
}

impl<'a, T: Pod> IntoIterator for &'a ArcSlice<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_roundtrip_and_equality() {
        let a: ArcSlice<u32> = vec![1, 2, 3].into();
        let b: ArcSlice<u32> = ArcSlice::from_vec(vec![1, 2, 3]);
        assert_eq!(a, b);
        assert_eq!(&a[..], &[1, 2, 3]);
        assert_eq!(a.len(), 3);
        assert!(!a.is_mapped());
        assert!(a.heap_bytes() >= 12);
        let c = a.clone();
        assert_eq!(c, a);
        let d: ArcSlice<u32> = ArcSlice::default();
        assert!(d.is_empty());
        assert_ne!(d, a);
    }

    #[test]
    fn mapped_window_bounds_and_alignment_checked() {
        let dir = std::env::temp_dir().join(format!("cagra-slice-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("win.bin");
        let mut bytes = Vec::new();
        for v in [7u32, 11, 13, 17] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        if let Ok(region) = MappedRegion::map(&path) {
            let region = Arc::new(region);
            let s = ArcSlice::<u32>::from_region(region.clone(), 0, 4).unwrap();
            assert!(s.is_mapped());
            assert_eq!(s.heap_bytes(), 0);
            assert_eq!(&s[..], &[7, 11, 13, 17]);
            let owned: ArcSlice<u32> = vec![7, 11, 13, 17].into();
            assert_eq!(s, owned, "mapped == owned by contents");
            // Out of bounds and misaligned windows are rejected.
            assert!(ArcSlice::<u32>::from_region(region.clone(), 0, 5).is_none());
            assert!(ArcSlice::<u32>::from_region(region.clone(), 2, 1).is_none());
            assert!(ArcSlice::<u64>::from_region(region.clone(), 12, 1).is_none());
            // Clone shares the region; contents identical.
            let t = s.clone();
            drop(s);
            assert_eq!(t.to_vec(), vec![7, 11, 13, 17]);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
