//! The persistent artifact store: content-addressed files + LRU eviction.
//!
//! One artifact per file under the store directory, named by the full
//! cache key so lookups are a single `stat`:
//!
//! ```text
//! <fingerprint:016x>-<kind>-<label>-s<seg_size>-b<merge_block>.v<codec>.art
//! ```
//!
//! Policy decisions (mirroring the `GraphCache` exemplar's shape — key by
//! content hash, `get_or_build` entry point, stats + clear — adapted to a
//! flat-file store):
//!
//! - **Failures degrade to rebuild, never to job failure.** A missing,
//!   truncated, bit-flipped, or version-skewed file is treated as a miss
//!   (and deleted); a failed write is logged and skipped. The only hard
//!   error is an unusable store directory at [`ArtifactStore::open`].
//! - **LRU by file mtime.** Hits re-touch the file; when the store grows
//!   past `cap_bytes` after a write, oldest-mtime artifacts are removed
//!   first. Artifacts written under a **live exemption scope** (one scope
//!   per running job, see [`ArtifactStore::begin_scope`]) are never
//!   evicted — otherwise a cap smaller than one job's artifact set would
//!   make the job's second write evict its first and thrash forever;
//!   instead the store warns that the cap is below the working set.
//!   Dropping a scope (job completion) releases its artifacts back to
//!   normal LRU, so one long-lived instance can serve many jobs without
//!   the exemption set growing unboundedly. `cap_bytes == 0` disables
//!   eviction.
//! - **Atomic writes.** Encode to a temp file, then rename, so a crashed
//!   run can never leave a torn artifact under a valid name (a torn temp
//!   file is ignored by the `.art` suffix filter; stale ones are swept at
//!   open, age-gated so a live writer's in-flight file is never unlinked).
//! - **Map-first warm loads.** Where the platform supports it (and
//!   `set_mmap_enabled` hasn't turned it off), a hit `mmap`s the v2
//!   artifact and hands out its arrays in place ([`super::ArcSlice`]) —
//!   zero decoded bytes, counted under `bytes_mapped` instead of
//!   `bytes_read`. A per-path cache of already-validated regions (keyed
//!   by inode + size — *not* mtime, which LRU touching bumps on every
//!   hit) makes repeat warm loads O(1): the checksum and structural scans
//!   run once per mapping, and N serve workers share one physical copy.
//!   This is sound because the store only ever *replaces* files via
//!   temp + rename (a new inode), never in place.

use super::codec::{self, Artifact, CODEC_VERSION};
use super::mmap::{self, MappedRegion};
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, SystemTime};

/// Extension of committed artifact files.
pub const ARTIFACT_EXT: &str = "art";

/// Sibling directory (inside the store dir) where corrupt/torn artifacts
/// are moved instead of deleted — evidence for post-mortems, invisible to
/// the `.art` top-level scan.
pub const QUARANTINE_DIR: &str = ".quarantine";

/// Full cache key for one preprocessing artifact. The artifact *type*
/// (permutation / CSR / segmented) is contributed by
/// [`Artifact::NAME`] at filename time, so one key can address the
/// permutation and the relabeled CSR of the same ordering.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreKey {
    /// Dataset fingerprint ([`super::fingerprint::fingerprint_dataset`]).
    pub fingerprint: u64,
    /// Free-form discriminator: ordering name, or an app-specific label
    /// like `cf-user`.
    pub label: String,
    /// Segment size in vertices (0 for non-segmented artifacts).
    pub seg_size: usize,
    /// Merge block size in vertices (0 for non-segmented artifacts).
    pub merge_block: usize,
}

impl StoreKey {
    /// Key for ordering-level artifacts (permutation, relabeled CSR).
    pub fn ordering(fingerprint: u64, ordering: &str) -> StoreKey {
        StoreKey {
            fingerprint,
            label: ordering.to_string(),
            seg_size: 0,
            merge_block: 0,
        }
    }

    /// Key for a segmented partition.
    pub fn segmented(fingerprint: u64, label: &str, seg_size: usize, merge_block: usize) -> StoreKey {
        StoreKey {
            fingerprint,
            label: label.to_string(),
            seg_size,
            merge_block,
        }
    }

    /// Store filename for this key holding an artifact of type `T`.
    pub fn filename<T: Artifact>(&self) -> String {
        // Labels come from ordering names / app constants; sanitize anyway
        // so a config-provided label can never traverse paths.
        let label: String = self
            .label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
            .collect();
        format!(
            "{:016x}-{}-{}-s{}-b{}.v{}.{ARTIFACT_EXT}",
            self.fingerprint,
            T::NAME,
            label,
            self.seg_size,
            self.merge_block,
            CODEC_VERSION,
        )
    }
}

/// Snapshot of store counters + on-disk occupancy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Artifacts served from disk this process.
    pub hits: u64,
    /// Artifacts built (absent or unreadable) this process.
    pub misses: u64,
    /// Files removed by capacity eviction this process.
    pub evictions: u64,
    /// Bytes *decoded* from disk into fresh heap allocations. Stays zero
    /// when every warm load is served by mapping — the property the CI
    /// warm-mapped gate asserts.
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Artifact array bytes served in place from mapped files (the
    /// zero-copy path; complement of `bytes_read`).
    pub bytes_mapped: u64,
    /// Current committed artifacts on disk.
    pub entries: u64,
    /// Their total size.
    pub resident_bytes: u64,
    pub cap_bytes: u64,
    /// Corrupt/torn artifacts moved to the `.quarantine/` sibling this
    /// process (self-healing evidence — each one was rebuilt, not served).
    pub quarantined: u64,
    /// Rebuilds forced by an unreadable artifact (a subset of `misses`;
    /// plain absent-file misses are not rebuilds).
    pub rebuilds: u64,
}

#[derive(Debug, Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
    bytes_mapped: AtomicU64,
    quarantined: AtomicU64,
    rebuilds: AtomicU64,
}

/// One validated mapping in the map cache. Identity is (inode, size):
/// atomic-rename replacement always allocates a new inode, and mtime is
/// useless here because LRU touching bumps it on every hit. The region is
/// held weakly — when the last [`super::ArcSlice`] over it drops, the
/// mapping is unmapped and the next load re-maps and re-validates.
#[derive(Debug)]
struct MapEntry {
    ino: u64,
    size: u64,
    region: Weak<MappedRegion>,
}

fn file_identity(md: &std::fs::Metadata) -> (u64, u64) {
    #[cfg(unix)]
    let ino = std::os::unix::fs::MetadataExt::ino(md);
    #[cfg(not(unix))]
    let ino = 0;
    (ino, md.len())
}

/// How old a temp file must be before the open-time sweep may remove it
/// (a concurrent writer's in-flight temp is younger than this).
const TMP_SWEEP_AGE: Duration = Duration::from_secs(3600);

/// Identifier of an eviction-exemption scope. [`ScopeId::INSTANCE`] is
/// the always-live default used by callers that never begin a scope
/// (direct `get_or_build`, tests, benches); every other id comes from
/// [`ArtifactStore::begin_scope`] and dies with its [`ExemptionScope`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScopeId(u64);

impl ScopeId {
    /// The instance-lifetime scope: exempt until the store is dropped.
    pub const INSTANCE: ScopeId = ScopeId(0);
}

/// RAII handle for one job's eviction-exemption scope: artifacts written
/// under it (via [`ArtifactStore::get_or_build_scoped`]) cannot be
/// evicted by this store while the scope is alive. Dropping it releases
/// them to normal mtime-LRU, which is what lets one long-lived store
/// serve an unbounded stream of jobs without its exemption set growing
/// unboundedly (each job's set is freed when the job completes).
#[derive(Debug)]
pub struct ExemptionScope<'a> {
    store: &'a ArtifactStore,
    id: ScopeId,
}

impl ExemptionScope<'_> {
    pub fn id(&self) -> ScopeId {
        self.id
    }
}

impl Drop for ExemptionScope<'_> {
    fn drop(&mut self) {
        self.store
            .exempt
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(&self.id.0);
    }
}

/// A persistent, size-capped store of preprocessing artifacts.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    cap_bytes: u64,
    counters: Counters,
    /// Eviction-exempt artifacts, keyed by live scope. Entry 0 is the
    /// instance scope (never removed); every other entry is created by
    /// [`ArtifactStore::begin_scope`] and removed when its
    /// [`ExemptionScope`] drops — per-job scoping for a store shared
    /// across many jobs in one process (`run_job` / `cagra batch`).
    exempt: Mutex<HashMap<u64, HashSet<PathBuf>>>,
    /// Next fresh scope id (0 is reserved for [`ScopeId::INSTANCE`]).
    next_scope: AtomicU64,
    /// Per-key locks serializing probe→build→write within this process
    /// (`cagra serve` workers share one instance): two threads missing on
    /// the same key build once — the loser blocks, then hits. Distinct
    /// keys build concurrently. Entries are swept once no thread holds
    /// them, so the map stays bounded by in-flight keys. Cross-*process*
    /// races were already safe (atomic temp+rename writes; the loser
    /// rewrites identical bytes) — this removes the duplicated build.
    key_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    /// Whether warm loads may mmap (CLI `--no-mmap` turns it off; always
    /// effectively off where [`mmap::SUPPORTED`] is false).
    mmap_enabled: AtomicBool,
    /// Already-validated mappings by path (see [`MapEntry`]).
    map_cache: Mutex<HashMap<PathBuf, MapEntry>>,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `dir` with a soft size
    /// cap of `cap_bytes` (0 = unlimited). Sweeps temp files orphaned by
    /// crashed writers — they are invisible to the `.art` scan, so without
    /// this they would accumulate past the cap forever. The sweep is
    /// age-gated ([`TMP_SWEEP_AGE`]): a concurrent process's in-flight
    /// temp file is recent and must not be unlinked from under it.
    pub fn open(dir: impl AsRef<Path>, cap_bytes: u64) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating artifact store dir {}", dir.display()))?;
        let cutoff = SystemTime::now().checked_sub(TMP_SWEEP_AGE);
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for entry in rd.flatten() {
                let path = entry.path();
                // Only files matching our own temp shape (.tmp<pid>-<seq>);
                // a user-pointed directory may contain other tools' *.tmp
                // files, which are not ours to delete.
                let is_tmp = path
                    .extension()
                    .and_then(|e| e.to_str())
                    .is_some_and(is_store_tmp_ext);
                if !is_tmp {
                    continue;
                }
                let stale = match (entry.metadata().and_then(|m| m.modified()), cutoff) {
                    (Ok(mtime), Some(c)) => mtime < c,
                    _ => false,
                };
                if stale && std::fs::remove_file(&path).is_ok() {
                    crate::log_debug!("artifact store: swept orphaned {}", path.display());
                }
            }
        }
        Ok(ArtifactStore::with_dir(dir, cap_bytes))
    }

    /// Open for inspection (`cache stats|clear`): errors if the directory
    /// does not exist, creates nothing, and skips the temp sweep — a
    /// read-only query pointed at a typo'd path must not plant a store
    /// there or unlink another store's files.
    pub fn open_existing(dir: impl AsRef<Path>, cap_bytes: u64) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            anyhow::bail!("no artifact store at {}", dir.display());
        }
        Ok(ArtifactStore::with_dir(dir, cap_bytes))
    }

    fn with_dir(dir: PathBuf, cap_bytes: u64) -> ArtifactStore {
        ArtifactStore {
            dir,
            cap_bytes,
            counters: Counters::default(),
            exempt: Mutex::new(HashMap::from([(ScopeId::INSTANCE.0, HashSet::new())])),
            next_scope: AtomicU64::new(1),
            key_locks: Mutex::new(HashMap::new()),
            mmap_enabled: AtomicBool::new(mmap::SUPPORTED),
            map_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Turn mapped warm loads on or off (`SystemConfig::store_mmap` /
    /// `--no-mmap`). Off means every hit decodes — the cold-path
    /// comparison arm of the CI warm sequence.
    pub fn set_mmap_enabled(&self, enabled: bool) {
        // audit: relaxed-ok — advisory toggle; readers only choose a code
        // path, no data is published through it.
        self.mmap_enabled.store(enabled, Ordering::Relaxed);
    }

    /// Whether warm loads will try to map (platform support and the
    /// toggle together).
    pub fn mmap_enabled(&self) -> bool {
        mmap::SUPPORTED && self.mmap_enabled.load(Ordering::Relaxed)
    }

    /// The in-process lock for one artifact filename. A poisoned lock is
    /// re-entered: the `()` payload has no invariants, and a panicking
    /// builder must not wedge every later request for that key.
    fn key_lock(&self, file: &str) -> Arc<Mutex<()>> {
        let mut locks = self.key_locks.lock().unwrap_or_else(|p| p.into_inner());
        locks.retain(|_, l| Arc::strong_count(l) > 1);
        locks.entry(file.to_string()).or_default().clone()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Begin a per-job eviction-exemption scope. Writes made with the
    /// returned scope's [`ScopeId`] are exempt from this store's eviction
    /// until the [`ExemptionScope`] drops (job completion), at which point
    /// they rejoin normal mtime-LRU.
    pub fn begin_scope(&self) -> ExemptionScope<'_> {
        let id = ScopeId(self.next_scope.fetch_add(1, Ordering::Relaxed));
        self.exempt
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(id.0, HashSet::new());
        ExemptionScope { store: self, id }
    }

    /// [`ArtifactStore::get_or_build_scoped`] under the instance-lifetime
    /// scope — for callers whose store lives exactly one job (tests,
    /// benches, one-shot tools).
    pub fn get_or_build<T: Artifact>(&self, key: &StoreKey, build: impl FnOnce() -> T) -> T {
        self.get_or_build_scoped(key, ScopeId::INSTANCE, build)
    }

    /// The core entry point: return the cached artifact for `key`, or run
    /// `build`, persist the result, and return it. The write is recorded
    /// under `scope` for eviction exemption. Storage problems only ever
    /// cost a rebuild (see module docs), so this cannot fail.
    pub fn get_or_build_scoped<T: Artifact>(
        &self,
        key: &StoreKey,
        scope: ScopeId,
        build: impl FnOnce() -> T,
    ) -> T {
        let file = key.filename::<T>();
        let path = self.dir.join(&file);
        // Serialize same-key probe→build→write across this process's
        // threads (concurrent serve workers): losers block here, then
        // take the hit path below instead of re-running `build`.
        let key_lock = self.key_lock(&file);
        let _building = key_lock.lock().unwrap_or_else(|p| p.into_inner());
        let t0 = crate::obs::recorder::timestamp();
        if path.is_file() {
            match self.load::<T>(&path) {
                Ok(value) => {
                    self.counters.hits.fetch_add(1, Ordering::Relaxed);
                    touch(&path);
                    crate::log_debug!("artifact store hit: {}", path.display());
                    crate::obs::recorder::record_artifact(t0, &path, true);
                    return value;
                }
                Err(e) => {
                    crate::log_warn!(
                        "artifact store: quarantining unreadable {}: {e:#}",
                        path.display()
                    );
                    self.quarantine(&path);
                    self.counters.rebuilds.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        let value = build();
        crate::obs::recorder::record_artifact(t0, &path, false);
        match codec::write_file(&path, &value) {
            Ok(len) => {
                self.counters.bytes_written.fetch_add(len, Ordering::Relaxed);
                crate::log_debug!("artifact store write: {} ({len} bytes)", path.display());
                // A scope that was already dropped (or a foreign id)
                // degrades to no exemption, never to a lost write.
                {
                    let mut exempt = self.exempt.lock().unwrap_or_else(|p| p.into_inner());
                    if let Some(set) = exempt.get_mut(&scope.0) {
                        set.insert(path);
                    }
                }
                self.evict_to_cap();
            }
            Err(e) => {
                crate::log_warn!("artifact store: writing {} failed: {e:#}", path.display());
            }
        }
        value
    }

    /// Load one committed artifact file: map-first (zero decoded bytes,
    /// in-place arrays), falling back to read-and-decode when mapping is
    /// off, unsupported, or fails for platform reasons. A corrupt file
    /// fails *both* ways and errs — the caller treats that as a miss.
    fn load<T: Artifact>(&self, path: &Path) -> Result<T> {
        if self.mmap_enabled() {
            if let Ok(value) = self.load_mapped::<T>(path) {
                return Ok(value);
            }
        }
        let (value, len) = codec::read_file::<T>(path)?;
        self.counters.bytes_read.fetch_add(len, Ordering::Relaxed);
        Ok(value)
    }

    /// The mapped warm path. First load of a file maps + fully validates
    /// it and caches the region; while any [`super::ArcSlice`] keeps that
    /// region alive, further loads rebuild from the already-validated
    /// mapping without re-scanning the section area — O(1) in |E|.
    fn load_mapped<T: Artifact>(&self, path: &Path) -> Result<T> {
        let md = std::fs::metadata(path)
            .with_context(|| format!("stat {}", path.display()))?;
        let (ino, size) = file_identity(&md);
        let cached = {
            let cache = self.map_cache.lock().unwrap_or_else(|p| p.into_inner());
            cache
                .get(path)
                .filter(|e| e.ino == ino && e.size == size)
                .and_then(|e| e.region.upgrade())
        };
        let value = match cached {
            Some(region) => codec::from_mapped::<T>(&region, true)?,
            None => {
                let (value, region) = codec::map_file::<T>(path)?;
                let mut cache = self.map_cache.lock().unwrap_or_else(|p| p.into_inner());
                cache.retain(|_, e| e.region.strong_count() > 0);
                cache.insert(
                    path.to_path_buf(),
                    MapEntry {
                        ino,
                        size,
                        region: Arc::downgrade(&region),
                    },
                );
                value
            }
        };
        self.counters
            .bytes_mapped
            .fetch_add(value.mapped_bytes(), Ordering::Relaxed);
        Ok(value)
    }

    /// Self-healing: move an unreadable artifact into `.quarantine/`
    /// (falling back to deletion if the rename fails) so the rebuild that
    /// follows can commit a fresh file under the original name while the
    /// corrupt bytes stay available for post-mortem. The path's map-cache
    /// entry is dropped either way.
    fn quarantine(&self, path: &Path) {
        self.map_cache
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(path);
        let qdir = self.dir.join(QUARANTINE_DIR);
        let moved = path.file_name().is_some_and(|name| {
            if std::fs::create_dir_all(&qdir).is_err() {
                return false;
            }
            let target = qdir.join(name);
            // Re-quarantining the same name: keep the newest evidence.
            std::fs::remove_file(&target).ok();
            std::fs::rename(path, &target).is_ok()
        });
        if !moved {
            std::fs::remove_file(path).ok();
        }
        self.counters.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// Files currently sitting in `.quarantine/` (on-disk evidence count,
    /// independent of this process's counters — `cagra cache stats` uses
    /// it to report quarantines from earlier runs too).
    pub fn quarantine_count(&self) -> u64 {
        match std::fs::read_dir(self.dir.join(QUARANTINE_DIR)) {
            Ok(rd) => rd
                .flatten()
                .filter(|e| e.metadata().map(|m| m.is_file()).unwrap_or(false))
                .count() as u64,
            Err(_) => 0,
        }
    }

    /// Read an artifact without building on miss (tests, tooling).
    pub fn try_get<T: Artifact>(&self, key: &StoreKey) -> Result<T> {
        let file = key.filename::<T>();
        let path = self.dir.join(&file);
        let key_lock = self.key_lock(&file);
        let _reading = key_lock.lock().unwrap_or_else(|p| p.into_inner());
        let value = self.load::<T>(&path)?;
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
        touch(&path);
        Ok(value)
    }

    /// Counter snapshot plus an on-disk scan of entries/occupancy.
    pub fn stats(&self) -> StoreStats {
        let files = self.scan();
        StoreStats {
            hits: self.counters.hits.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
            evictions: self.counters.evictions.load(Ordering::Relaxed),
            bytes_read: self.counters.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.counters.bytes_written.load(Ordering::Relaxed),
            bytes_mapped: self.counters.bytes_mapped.load(Ordering::Relaxed),
            entries: files.len() as u64,
            resident_bytes: files.iter().map(|f| f.size).sum(),
            cap_bytes: self.cap_bytes,
            quarantined: self.counters.quarantined.load(Ordering::Relaxed),
            rebuilds: self.counters.rebuilds.load(Ordering::Relaxed),
        }
    }

    /// Per-artifact inventory for `cagra cache stats`: filename, size,
    /// codec version (`None` when the header is unreadable), and whether
    /// this build would serve it zero-copy. Makes mixed-version stores
    /// diagnosable after a codec bump — v1 leftovers show up as
    /// `decode-on-load` / `rebuild` rather than silently rebuilding.
    pub fn list_artifacts(&self) -> Vec<ArtifactInfo> {
        let mut out: Vec<ArtifactInfo> = self
            .scan()
            .into_iter()
            .map(|f| {
                let version = codec::peek_version(&f.path).ok();
                ArtifactInfo {
                    file: f
                        .path
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_default(),
                    size: f.size,
                    version: version.map(|(v, _)| v),
                    kind: version
                        .map(|(_, k)| String::from_utf8_lossy(&k).trim_end_matches('_').to_string()),
                    mappable: version.map(|(v, _)| v) == Some(CODEC_VERSION) && mmap::SUPPORTED,
                }
            })
            .collect();
        out.sort_by(|a, b| a.file.cmp(&b.file));
        out
    }

    /// Remove every committed artifact. Returns (files removed, bytes
    /// freed).
    pub fn clear(&self) -> Result<(u64, u64)> {
        let mut removed = 0u64;
        let mut freed = 0u64;
        self.map_cache.lock().unwrap_or_else(|p| p.into_inner()).clear();
        for f in self.scan() {
            std::fs::remove_file(&f.path)
                .with_context(|| format!("removing {}", f.path.display()))?;
            removed += 1;
            freed += f.size;
        }
        Ok((removed, freed))
    }

    /// Enumerate committed artifacts (`.art` files only — temp files and
    /// strangers are ignored).
    fn scan(&self) -> Vec<FileInfo> {
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(ARTIFACT_EXT) {
                continue;
            }
            let Ok(md) = entry.metadata() else { continue };
            if !md.is_file() {
                continue;
            }
            out.push(FileInfo {
                path,
                size: md.len(),
                mtime: md.modified().unwrap_or(SystemTime::UNIX_EPOCH),
            });
        }
        out
    }

    /// Evict oldest-mtime artifacts until the store fits `cap_bytes`.
    /// Files written under any live exemption scope are skipped —
    /// evicting them would make a running job's second artifact evict its
    /// first and thrash forever when the cap is under one job's working
    /// set; that misconfiguration is warned about instead. Artifacts
    /// whose scope has since been dropped are ordinary LRU candidates.
    fn evict_to_cap(&self) {
        if self.cap_bytes == 0 {
            return;
        }
        let mut files = self.scan();
        let mut total: u64 = files.iter().map(|f| f.size).sum();
        if total <= self.cap_bytes {
            return;
        }
        files.sort_by_key(|f| f.mtime);
        let exempt = self.exempt.lock().unwrap_or_else(|p| p.into_inner());
        // Snapshot the in-flight key locks so eviction can skip files a
        // concurrent thread is mid-build/read on (including the caller's
        // own key — `evict_to_cap` runs with that lock held, and a fresh
        // write is exempt via its scope anyway).
        let in_flight: HashMap<String, Arc<Mutex<()>>> = {
            let locks = self.key_locks.lock().unwrap_or_else(|p| p.into_inner());
            locks.clone()
        };
        for f in files {
            if total <= self.cap_bytes {
                break;
            }
            if exempt.values().any(|set| set.contains(&f.path)) {
                continue;
            }
            // Hold the file's key lock (if registered) across the unlink,
            // so no thread is between probe and read when it disappears.
            let name = f.path.file_name().and_then(|n| n.to_str());
            let _guard = match name.and_then(|n| in_flight.get(n)) {
                Some(l) => match l.try_lock() {
                    Ok(g) => Some(g),
                    Err(std::sync::TryLockError::Poisoned(p)) => Some(p.into_inner()),
                    Err(std::sync::TryLockError::WouldBlock) => continue, // in use
                },
                None => None,
            };
            if std::fs::remove_file(&f.path).is_ok() {
                total -= f.size;
                self.counters.evictions.fetch_add(1, Ordering::Relaxed);
                // Unlinking doesn't invalidate live mappings (the inode
                // survives until the last ArcSlice drops), but the path's
                // cache entry is now stale.
                self.map_cache
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .remove(&f.path);
                crate::log_debug!("artifact store evict: {} ({} bytes)", f.path.display(), f.size);
            }
        }
        if total > self.cap_bytes {
            crate::log_warn!(
                "artifact store over cap ({total} > {} bytes) with only live \
                 jobs' artifacts left — raise store_cap_bytes above one job's \
                 artifact set or warm runs cannot amortize",
                self.cap_bytes
            );
        }
    }
}

struct FileInfo {
    path: PathBuf,
    size: u64,
    mtime: SystemTime,
}

/// One row of [`ArtifactStore::list_artifacts`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub file: String,
    pub size: u64,
    /// Codec version from the file header; `None` if unreadable.
    pub version: Option<u32>,
    /// Artifact kind tag ("CSR", "PERM", "SEG"); `None` if unreadable.
    pub kind: Option<String>,
    /// Whether this build serves the file zero-copy (current codec
    /// version on an mmap-capable platform).
    pub mappable: bool,
}

/// Does `ext` match the store's own temp-file shape, `tmp<pid>-<seq>`
/// (see [`codec::write_file`])?
fn is_store_tmp_ext(ext: &str) -> bool {
    let Some(rest) = ext.strip_prefix("tmp") else {
        return false;
    };
    match rest.split_once('-') {
        Some((pid, seq)) => {
            !pid.is_empty()
                && !seq.is_empty()
                && pid.bytes().all(|b| b.is_ascii_digit())
                && seq.bytes().all(|b| b.is_ascii_digit())
        }
        None => false,
    }
}

/// Best-effort LRU touch: bump the file's mtime to now.
fn touch(path: &Path) {
    if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
        f.set_modified(SystemTime::now()).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::store::ArcSlice;

    fn temp_store(tag: &str, cap: u64) -> (PathBuf, ArtifactStore) {
        let dir = std::env::temp_dir().join(format!(
            "cagra-store-{tag}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = ArtifactStore::open(&dir, cap).unwrap();
        (dir, store)
    }

    fn perm(n: u32, seed: u64) -> ArcSlice<u32> {
        crate::util::rng::Rng::new(seed).permutation(n as usize).into()
    }

    #[test]
    fn miss_then_hit_with_stats() {
        let (dir, store) = temp_store("hit", 0);
        // Force the decode path so `bytes_read` is the counter exercised
        // here; the mapped path has its own test below.
        store.set_mmap_enabled(false);
        let key = StoreKey::ordering(0xABCD, "degree-sorted");
        let mut builds = 0;
        let a = store.get_or_build(&key, || {
            builds += 1;
            perm(100, 1)
        });
        let b = store.get_or_build(&key, || {
            builds += 1;
            perm(100, 1)
        });
        assert_eq!(builds, 1, "second call must not rebuild");
        assert_eq!(a, b);
        // Direct read without a builder sees the same artifact...
        let direct: ArcSlice<u32> = store.try_get(&key).unwrap();
        assert_eq!(direct, a);
        // ...and a key that was never written is an error, not a build.
        assert!(store.try_get::<ArcSlice<u32>>(&StoreKey::ordering(0xDEAD, "absent")).is_err());
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
        assert!(s.bytes_written > 0 && s.bytes_read > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn distinct_keys_and_types_do_not_collide() {
        let (dir, store) = temp_store("keys", 0);
        let k1 = StoreKey::ordering(1, "a");
        let k2 = StoreKey::ordering(2, "a");
        let k3 = StoreKey::segmented(1, "a", 64, 8);
        let p1 = store.get_or_build(&k1, || perm(10, 1));
        let p2 = store.get_or_build(&k2, || perm(10, 2));
        assert_ne!(p1, p2);
        // Same key, different artifact type → different file.
        let g = Csr::from_edges(4, &[(0, 1), (2, 3)]);
        let _csr: Csr = store.get_or_build(&k1, || g.clone());
        let _ = k3;
        assert_eq!(store.stats().entries, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_file_is_rebuilt_not_propagated() {
        let (dir, store) = temp_store("corrupt", 0);
        let key = StoreKey::ordering(7, "x");
        let _ = store.get_or_build(&key, || perm(50, 3));
        let path = dir.join(key.filename::<ArcSlice<u32>>());
        // Truncate the committed file.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let back = store.get_or_build(&key, || perm(50, 3));
        assert_eq!(back, perm(50, 3));
        let s = store.stats();
        assert_eq!(s.misses, 2); // initial build + rebuild
        assert_eq!(s.quarantined, 1);
        assert_eq!(s.rebuilds, 1);
        // The torn bytes moved aside, the rebuilt artifact is readable,
        // and the quarantine dir is invisible to the scan.
        assert_eq!(store.quarantine_count(), 1);
        assert!(dir
            .join(QUARANTINE_DIR)
            .join(key.filename::<ArcSlice<u32>>())
            .exists());
        let reread: ArcSlice<u32> = store.try_get(&key).unwrap();
        assert_eq!(reread, perm(50, 3));
        assert_eq!(store.stats().entries, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_removes_foreign_oldest_artifact() {
        // Cap below two artifacts: writing the second evicts a stale
        // artifact left by a *previous* process (planted directly on
        // disk, so it is in none of this store's exemption scopes).
        let one_size = codec::encode(&perm(64, 1)).len() as u64;
        let (dir, store) = temp_store("evict", one_size + one_size / 2);
        let k1 = StoreKey::ordering(1, "old");
        let old = dir.join(k1.filename::<ArcSlice<u32>>());
        codec::write_file(&old, &perm(64, 1)).unwrap();
        if let Ok(f) = std::fs::OpenOptions::new().write(true).open(&old) {
            f.set_modified(SystemTime::UNIX_EPOCH + Duration::from_secs(1)).ok();
        }
        let k2 = StoreKey::ordering(2, "new");
        let _ = store.get_or_build(&k2, || perm(64, 2));
        let s = store.stats();
        assert_eq!(s.entries, 1, "foreign stale artifact should be evicted");
        assert!(s.evictions >= 1);
        assert!(!old.exists());
        assert!(dir.join(k2.filename::<ArcSlice<u32>>()).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn own_writes_never_evict_each_other() {
        // Cap below even one artifact: the store must keep everything this
        // process wrote (and warn) rather than thrash its own working set.
        // (Instance-scope writes — the default for scope-less callers.)
        let (dir, store) = temp_store("own", 8);
        let _ = store.get_or_build(&StoreKey::ordering(1, "a"), || perm(64, 1));
        let _ = store.get_or_build(&StoreKey::ordering(2, "b"), || perm(64, 2));
        let s = store.stats();
        assert_eq!(s.entries, 2, "own writes must survive an undersized cap");
        assert_eq!(s.evictions, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn live_scope_writes_survive_an_undersized_cap() {
        // Same thrash protection as the instance scope, but per job: both
        // writes land in one live scope, so neither may be evicted.
        let (dir, store) = temp_store("scope-live", 8);
        let scope = store.begin_scope();
        let k1 = StoreKey::ordering(1, "a");
        let k2 = StoreKey::ordering(2, "b");
        let _ = store.get_or_build_scoped(&k1, scope.id(), || perm(64, 1));
        let _ = store.get_or_build_scoped(&k2, scope.id(), || perm(64, 2));
        let s = store.stats();
        assert_eq!(s.entries, 2, "a live job's writes must not be evicted");
        assert_eq!(s.evictions, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dropped_scope_releases_artifacts_to_eviction() {
        // Job 1 writes under a scope, completes (scope drops); job 2's
        // write then pushes the store over cap and must be able to evict
        // job 1's now-unprotected artifact — exactly what the old
        // instance-scoped own_writes exemption could never do.
        let one_size = codec::encode(&perm(64, 1)).len() as u64;
        let (dir, store) = temp_store("scope-drop", one_size + one_size / 2);
        let k1 = StoreKey::ordering(1, "job1");
        {
            let job1 = store.begin_scope();
            let _ = store.get_or_build_scoped(&k1, job1.id(), || perm(64, 1));
        } // job 1 completes; its exemption is released
        // Backdate job 1's artifact so LRU ordering is deterministic.
        let old = dir.join(k1.filename::<ArcSlice<u32>>());
        if let Ok(f) = std::fs::OpenOptions::new().write(true).open(&old) {
            f.set_modified(SystemTime::UNIX_EPOCH + Duration::from_secs(1)).ok();
        }
        let job2 = store.begin_scope();
        let k2 = StoreKey::ordering(2, "job2");
        let _ = store.get_or_build_scoped(&k2, job2.id(), || perm(64, 2));
        let s = store.stats();
        assert_eq!(s.entries, 1, "completed job's artifact should be evictable");
        assert!(s.evictions >= 1);
        assert!(!old.exists(), "job 1's artifact must be the one evicted");
        assert!(dir.join(k2.filename::<ArcSlice<u32>>()).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scope_ids_are_fresh_and_scope_maps_are_freed() {
        let (dir, store) = temp_store("scope-ids", 0);
        let a = store.begin_scope();
        let b = store.begin_scope();
        assert_ne!(a.id(), b.id());
        assert_ne!(a.id(), ScopeId::INSTANCE);
        drop(a);
        drop(b);
        // Only the instance scope remains registered.
        assert_eq!(store.exempt.lock().unwrap().len(), 1);
        // A write attributed to a dead scope still lands on disk.
        let dead = ScopeId(9999);
        let _ = store.get_or_build_scoped(&StoreKey::ordering(3, "c"), dead, || perm(8, 3));
        assert_eq!(store.stats().entries, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_empties_the_store() {
        let (dir, store) = temp_store("clear", 0);
        let _ = store.get_or_build(&StoreKey::ordering(1, "a"), || perm(10, 1));
        let _ = store.get_or_build(&StoreKey::ordering(2, "b"), || perm(10, 2));
        let (n, bytes) = store.clear().unwrap();
        assert_eq!(n, 2);
        assert!(bytes > 0);
        assert_eq!(store.stats().entries, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tmp_pattern_matches_only_our_shape() {
        assert!(is_store_tmp_ext("tmp123-0"));
        assert!(is_store_tmp_ext("tmp4567-89"));
        for foreign in ["tmp", "tmp123", "tmpfile", "tmp-1", "tmp123-", "tmp12a-3", "art"] {
            assert!(!is_store_tmp_ext(foreign), "{foreign:?} must not match");
        }
    }

    #[test]
    fn open_existing_requires_directory() {
        let missing =
            std::env::temp_dir().join(format!("cagra-store-missing-{}", std::process::id()));
        std::fs::remove_dir_all(&missing).ok();
        assert!(ArtifactStore::open_existing(&missing, 0).is_err());
        assert!(!missing.exists(), "open_existing must not create the dir");
        let (dir, store) = temp_store("existing", 0);
        let _ = store.get_or_build(&StoreKey::ordering(1, "a"), || perm(8, 1));
        let ro = ArtifactStore::open_existing(&dir, 0).unwrap();
        assert_eq!(ro.stats().entries, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mapped_warm_hit_decodes_zero_bytes() {
        let (dir, store) = temp_store("mapped", 0);
        let key = StoreKey::ordering(0x1234, "mapped");
        let cold = store.get_or_build(&key, || perm(4096, 9));
        assert_eq!(store.stats().bytes_read, 0, "cold build decodes nothing");
        if store.mmap_enabled() {
            let warm: ArcSlice<u32> = store.try_get(&key).unwrap();
            assert!(warm.is_mapped(), "warm hit must be served in place");
            assert_eq!(warm, cold);
            let s = store.stats();
            assert_eq!(s.bytes_read, 0, "mapped warm load must decode zero bytes");
            assert!(s.bytes_mapped >= 4096 * 4, "{s:?}");
            // Second load while the first mapping is alive: served from
            // the validated map cache — still zero decoded bytes, one
            // shared physical region.
            let again: ArcSlice<u32> = store.try_get(&key).unwrap();
            assert!(again.is_mapped());
            assert_eq!(store.stats().bytes_read, 0);
            // Forcing the decode path returns identical contents.
            store.set_mmap_enabled(false);
            let decoded: ArcSlice<u32> = store.try_get(&key).unwrap();
            assert!(!decoded.is_mapped());
            assert_eq!(decoded, warm);
            assert!(store.stats().bytes_read > 0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_artifacts_reports_version_and_mappability() {
        let (dir, store) = temp_store("list", 0);
        let _ = store.get_or_build(&StoreKey::ordering(1, "p"), || perm(16, 1));
        let g = Csr::from_edges(4, &[(0, 1), (2, 3)]);
        let _: Csr = store.get_or_build(&StoreKey::ordering(1, "g"), || g.clone());
        // A foreign/unreadable .art file is listed but has no version and
        // is never claimed mappable.
        std::fs::write(dir.join("junk.art"), b"not an artifact").unwrap();
        let infos = store.list_artifacts();
        assert_eq!(infos.len(), 3);
        let junk = infos.iter().find(|i| i.file == "junk.art").unwrap();
        assert_eq!(junk.version, None);
        assert!(!junk.mappable);
        for i in infos.iter().filter(|i| i.file != "junk.art") {
            assert_eq!(i.version, Some(CODEC_VERSION));
            assert_eq!(i.mappable, mmap::SUPPORTED);
            assert!(i.size > 0);
        }
        let kinds: Vec<String> = infos.iter().filter_map(|i| i.kind.clone()).collect();
        assert!(
            kinds.contains(&"PERM".to_string()) && kinds.contains(&"CSR".to_string()),
            "{kinds:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn filename_is_path_safe() {
        let key = StoreKey::segmented(0xFF, "weird/../label with spaces", 4, 2);
        let name = key.filename::<Csr>();
        assert!(!name.contains('/') && !name.contains("..") && !name.contains(' '), "{name}");
    }
}
