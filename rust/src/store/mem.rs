//! In-memory artifact layer: decoded artifacts pinned behind [`Arc`].
//!
//! The disk [`super::ArtifactStore`] amortizes preprocessing across
//! *processes*; this layer amortizes the remaining warm-path cost — CSR
//! decode — across *requests* inside one resident process (`cagra
//! serve`). Entries are type-erased `Arc`s keyed by the same string the
//! disk store uses for filenames (fingerprint + artifact kind + prep
//! label + codec version), so versioned invalidation falls out of the
//! key: bumping `CODEC_VERSION` changes every key, and
//! [`MemStore::invalidate_prefix`] drops one fingerprint's entries when
//! a dataset is regenerated.
//!
//! Policy:
//! - **byte-budget LRU** — each entry carries its decoded size; inserts
//!   evict least-recently-used entries until the cache fits the budget.
//!   Eviction only drops the cache's `Arc`: jobs that already hold a
//!   clone keep working on the pinned value, memory is reclaimed when
//!   the last job finishes. The newest entry is never evicted by its own
//!   insert, so a single over-budget artifact still serves warm hits.
//! - **TTL** — optional; an entry older than the TTL is treated as a
//!   miss and rebuilt (counted under `expirations`, not `evictions`).
//! - **per-key build locks** — two requests missing on the same key
//!   build once; the loser blocks and then hits. Distinct keys build
//!   concurrently.
//!
//! Every lookup is recorded as an obs artifact span with a `mem:` path
//! prefix, so `cagra trace` interleaves memory-layer hits with disk
//! store activity.

use crate::obs::recorder;
use std::any::Any;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Hit/miss/eviction counters plus occupancy, mirroring
/// [`super::StoreStats`] for the in-memory layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// TTL expirations (counted separately from budget evictions).
    pub expirations: u64,
    pub entries: u64,
    pub resident_bytes: u64,
    /// Bytes of `resident_bytes` whose arrays are mmap-backed rather than
    /// private heap: one physical copy of the file pages serves every
    /// worker holding the entry, so a serve daemon's true private
    /// footprint is `resident_bytes - mapped_bytes` (plus one shared copy
    /// of the mapped pages across the whole pool).
    pub mapped_bytes: u64,
    pub budget_bytes: u64,
}

struct Entry {
    value: Arc<dyn Any + Send + Sync>,
    bytes: u64,
    /// Bytes of `bytes` that are mmap-backed (see [`MemStats::mapped_bytes`]).
    mapped: u64,
    /// Monotonic access tick for LRU ordering.
    last_used: u64,
    inserted: Instant,
}

#[derive(Default)]
struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
    resident_bytes: u64,
    mapped_bytes: u64,
}

/// Byte-budget LRU cache of decoded artifacts (see module docs).
pub struct MemStore {
    inner: Mutex<Inner>,
    /// Per-key in-flight build locks (same shape as the disk store's):
    /// entries are swept once no builder holds them.
    build_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    budget_bytes: u64,
    ttl: Option<Duration>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    expirations: AtomicU64,
}

impl std::fmt::Debug for MemStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MemStore")
            .field("budget_bytes", &self.budget_bytes)
            .field("ttl", &self.ttl)
            .finish_non_exhaustive()
    }
}

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // The `()`/map payloads carry no invariants a panicking builder could
    // tear, so a poisoned lock is safe to re-enter.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl MemStore {
    /// Cache with a byte budget (0 = unlimited) and no TTL.
    pub fn new(budget_bytes: u64) -> MemStore {
        MemStore {
            inner: Mutex::new(Inner::default()),
            build_locks: Mutex::new(HashMap::new()),
            budget_bytes,
            ttl: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            expirations: AtomicU64::new(0),
        }
    }

    /// Same cache with entries expiring `ttl` after insertion.
    pub fn with_ttl(mut self, ttl: Duration) -> MemStore {
        self.ttl = Some(ttl);
        self
    }

    /// Probe for `key`; counts and records nothing (test/introspection
    /// helper — the serving path goes through [`MemStore::get_or_insert`]).
    pub fn peek<T: Send + Sync + 'static>(&self, key: &str) -> Option<Arc<T>> {
        let mut inner = relock(&self.inner);
        self.lookup::<T>(&mut inner, key)
    }

    /// Return the pinned value for `key`, building (and inserting) it on
    /// a miss. `build` returns the value plus its decoded size in bytes.
    /// Concurrent misses on one key build once.
    pub fn get_or_insert<T, F>(&self, key: &str, build: F) -> Arc<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> (T, u64),
    {
        self.get_or_insert_full(key, || {
            let (v, bytes) = build();
            (v, bytes, 0)
        })
    }

    /// [`MemStore::get_or_insert`] for builders that also know how much of
    /// the value is mmap-backed: `build` returns
    /// `(value, total_bytes, mapped_bytes)`. The mapped figure feeds
    /// [`MemStats::mapped_bytes`] — how much of the resident set is one
    /// shared physical copy rather than per-process heap.
    pub fn get_or_insert_full<T, F>(&self, key: &str, build: F) -> Arc<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> (T, u64, u64),
    {
        match self.try_get_or_insert_full(key, || Ok(build())) {
            Ok(v) => v,
            Err(e) => unreachable!("infallible build failed: {e}"),
        }
    }

    /// Fallible variant of [`MemStore::get_or_insert`] for builders that
    /// can fail (dataset loads). Nothing is cached on error.
    pub fn try_get_or_insert<T, F>(&self, key: &str, build: F) -> anyhow::Result<Arc<T>>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> anyhow::Result<(T, u64)>,
    {
        self.try_get_or_insert_full(key, || {
            let (v, bytes) = build()?;
            Ok((v, bytes, 0))
        })
    }

    /// Fallible variant of [`MemStore::get_or_insert_full`].
    pub fn try_get_or_insert_full<T, F>(&self, key: &str, build: F) -> anyhow::Result<Arc<T>>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> anyhow::Result<(T, u64, u64)>,
    {
        let t0 = recorder::timestamp();
        if let Some(v) = self.lookup::<T>(&mut relock(&self.inner), key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.record(t0, key, true);
            return Ok(v);
        }
        let key_lock = self.build_lock(key);
        let _building = relock(&key_lock);
        // Second probe under the key lock: a concurrent builder may have
        // filled the entry while we waited.
        if let Some(v) = self.lookup::<T>(&mut relock(&self.inner), key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.record(t0, key, true);
            return Ok(v);
        }
        // Build OUTSIDE the cache lock (only the key lock is held):
        // distinct keys decode/build concurrently.
        let (value, bytes, mapped) = build()?;
        let value: Arc<T> = Arc::new(value);
        // A triggered `mem.insert` failpoint degrades to "don't cache" —
        // the caller still gets its freshly built value, so the infallible
        // `get_or_insert_full` wrapper stays infallible. A panic action
        // propagates (contained by the serve worker's catch_unwind).
        if let Some(a) = crate::fault::check(crate::fault::Site::MemInsert) {
            if matches!(a, crate::fault::Action::Panic) {
                panic!("injected panic at failpoint mem.insert");
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            self.record(t0, key, false);
            return Ok(value);
        }
        let mut inner = relock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.insert(
            key.to_string(),
            Entry {
                value: value.clone(),
                bytes,
                mapped,
                last_used: tick,
                inserted: Instant::now(),
            },
        ) {
            inner.resident_bytes -= old.bytes;
            inner.mapped_bytes -= old.mapped;
        }
        inner.resident_bytes += bytes;
        inner.mapped_bytes += mapped;
        self.evict_to_budget(&mut inner, key);
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.record(t0, key, false);
        Ok(value)
    }

    /// Drop every entry whose key starts with `prefix` (e.g. one graph's
    /// fingerprint, or `dataset:` on regeneration). Returns the count.
    pub fn invalidate_prefix(&self, prefix: &str) -> usize {
        let mut inner = relock(&self.inner);
        let doomed: Vec<String> =
            inner.map.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
        for k in &doomed {
            if let Some(e) = inner.map.remove(k) {
                inner.resident_bytes -= e.bytes;
                inner.mapped_bytes -= e.mapped;
            }
        }
        doomed.len()
    }

    pub fn stats(&self) -> MemStats {
        let inner = relock(&self.inner);
        MemStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            expirations: self.expirations.load(Ordering::Relaxed),
            entries: inner.map.len() as u64,
            resident_bytes: inner.resident_bytes,
            mapped_bytes: inner.mapped_bytes,
            budget_bytes: self.budget_bytes,
        }
    }

    /// TTL- and type-checked probe; bumps LRU position on hit. Expired or
    /// type-mismatched entries are removed (the latter happens only if a
    /// caller reuses a key at a different type — treated as staleness).
    fn lookup<T: Send + Sync + 'static>(&self, inner: &mut Inner, key: &str) -> Option<Arc<T>> {
        let expired = match inner.map.get(key) {
            Some(e) => self.ttl.is_some_and(|ttl| e.inserted.elapsed() > ttl),
            None => return None,
        };
        if expired {
            let e = inner.map.remove(key).unwrap();
            inner.resident_bytes -= e.bytes;
            inner.mapped_bytes -= e.mapped;
            self.expirations.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        inner.tick += 1;
        let tick = inner.tick;
        let e = inner.map.get_mut(key).unwrap();
        match e.value.clone().downcast::<T>() {
            Ok(v) => {
                e.last_used = tick;
                Some(v)
            }
            Err(_) => {
                let e = inner.map.remove(key).unwrap();
                inner.resident_bytes -= e.bytes;
                inner.mapped_bytes -= e.mapped;
                None
            }
        }
    }

    /// Evict LRU entries until the cache fits the budget, never evicting
    /// `keep` (the entry just inserted).
    fn evict_to_budget(&self, inner: &mut Inner, keep: &str) {
        if self.budget_bytes == 0 {
            return;
        }
        // A triggered `mem.evict` failpoint skips this eviction pass —
        // a transient budget overshoot, repaired by the next insert.
        if let Some(a) = crate::fault::check(crate::fault::Site::MemEvict) {
            if matches!(a, crate::fault::Action::Panic) {
                panic!("injected panic at failpoint mem.evict");
            }
            return;
        }
        while inner.resident_bytes > self.budget_bytes {
            let victim = inner
                .map
                .iter()
                .filter(|(k, _)| k.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.map.remove(&k).unwrap();
                    inner.resident_bytes -= e.bytes;
                    inner.mapped_bytes -= e.mapped;
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break, // only `keep` remains; let it stay resident
            }
        }
    }

    fn build_lock(&self, key: &str) -> Arc<Mutex<()>> {
        let mut locks = relock(&self.build_locks);
        locks.retain(|_, l| Arc::strong_count(l) > 1);
        locks.entry(key.to_string()).or_default().clone()
    }

    fn record(&self, t0: u64, key: &str, hit: bool) {
        recorder::record_artifact(t0, Path::new(&format!("mem:{key}")), hit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_returns_same_pinned_value() {
        let m = MemStore::new(0);
        let a = m.get_or_insert("k", || (vec![1u32, 2, 3], 12));
        let b = m.get_or_insert("k", || panic!("must not rebuild on hit"));
        assert!(Arc::ptr_eq(&a, &b));
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.resident_bytes), (1, 1, 1, 12));
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let m = MemStore::new(100);
        m.get_or_insert("a", || (vec![0u8; 40], 40));
        m.get_or_insert("b", || (vec![0u8; 40], 40));
        // Touch `a` so `b` is the LRU entry when `c` overflows the budget.
        m.get_or_insert("a", || -> (Vec<u8>, u64) { panic!("hit expected") });
        m.get_or_insert("c", || (vec![0u8; 40], 40));
        let s = m.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_bytes, 80);
        assert!(m.peek::<Vec<u8>>("b").is_none(), "LRU entry must be evicted");
        assert!(m.peek::<Vec<u8>>("a").is_some());
        assert!(m.peek::<Vec<u8>>("c").is_some());
    }

    #[test]
    fn oversized_entry_stays_resident() {
        let m = MemStore::new(10);
        let v = m.get_or_insert("big", || (vec![0u8; 64], 64));
        assert_eq!(v.len(), 64);
        // The fresh insert is never its own victim: warm hits still work.
        assert!(m.peek::<Vec<u8>>("big").is_some());
        assert_eq!(m.stats().evictions, 0);
        // ...but it is first in line once anything newer arrives.
        m.get_or_insert("next", || (vec![0u8; 4], 4));
        assert!(m.peek::<Vec<u8>>("big").is_none());
    }

    #[test]
    fn ttl_expiry_counts_and_rebuilds() {
        let m = MemStore::new(0).with_ttl(Duration::from_millis(0));
        m.get_or_insert("k", || (7u64, 8));
        std::thread::sleep(Duration::from_millis(2));
        let v = m.get_or_insert("k", || (9u64, 8));
        assert_eq!(*v, 9, "expired entry must be rebuilt");
        let s = m.stats();
        assert_eq!(s.expirations, 1);
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn invalidate_prefix_drops_matching_keys() {
        let m = MemStore::new(0);
        m.get_or_insert("fp1-csr", || (1u32, 4));
        m.get_or_insert("fp1-perm", || (2u32, 4));
        m.get_or_insert("fp2-csr", || (3u32, 4));
        assert_eq!(m.invalidate_prefix("fp1-"), 2);
        let s = m.stats();
        assert_eq!((s.entries, s.resident_bytes), (1, 4));
        assert!(m.peek::<u32>("fp2-csr").is_some());
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let m = Arc::new(MemStore::new(0));
        let builds = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            let builds = builds.clone();
            handles.push(std::thread::spawn(move || {
                m.get_or_insert("shared", || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(5));
                    (vec![42u32; 16], 64)
                })
            }));
        }
        let vals: Vec<Arc<Vec<u32>>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(builds.load(Ordering::SeqCst), 1, "losers must block, then hit");
        for v in &vals[1..] {
            assert!(Arc::ptr_eq(&vals[0], v));
        }
    }

    #[test]
    fn mapped_bytes_tracked_through_insert_and_removal() {
        let m = MemStore::new(0);
        m.get_or_insert_full("seg", || (vec![0u8; 64], 64, 48));
        m.get_or_insert_full("perm", || (vec![0u8; 16], 16, 16));
        m.get_or_insert("decoded", || (vec![0u8; 8], 8));
        let s = m.stats();
        assert_eq!((s.resident_bytes, s.mapped_bytes), (88, 64));
        // Re-insert under the same key replaces the old accounting.
        m.invalidate_prefix("seg");
        m.get_or_insert_full("seg", || (vec![0u8; 64], 64, 0));
        let s = m.stats();
        assert_eq!((s.resident_bytes, s.mapped_bytes), (88, 16));
        m.invalidate_prefix("");
        let s = m.stats();
        assert_eq!((s.resident_bytes, s.mapped_bytes), (0, 0));
    }

    #[test]
    fn failed_build_caches_nothing() {
        let m = MemStore::new(0);
        let r: anyhow::Result<Arc<u32>> =
            m.try_get_or_insert("k", || anyhow::bail!("load failed"));
        assert!(r.is_err());
        assert_eq!(m.stats().entries, 0);
        let v = m.get_or_insert("k", || (5u32, 4));
        assert_eq!(*v, 5);
    }
}
