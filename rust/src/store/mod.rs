//! Persistent preprocessing-artifact store.
//!
//! The paper justifies its preprocessing passes — frequency-based
//! clustering (§3) and CSR segmenting (§4) — by noting their cost "can be
//! amortized across many runs" (Table 9). This subsystem makes that
//! amortization real: the outputs of preprocessing (permutations,
//! relabeled CSRs, and [`crate::segment::SegmentedCsr`] partitions) are
//! persisted to disk, keyed by
//!
//! > (graph fingerprint, ordering/label, seg_size, merge_block, codec version)
//!
//! so a service restart — or the next of "many runs" — pays a sequential
//! read instead of a rebuild (GPOP builds its partitions once offline for
//! the same reason).
//!
//! Three layers:
//! - [`fingerprint`] — cheap, sampled, order-insensitive content hashes of
//!   a [`crate::graph::Csr`] plus dataset identity.
//! - [`codec`] — the versioned little-endian on-disk format with header
//!   magic and checksums; corruption is always an `Err`, never a panic or
//!   a wrong decode.
//! - [`artifact_store`] — `get_or_build` over one-file-per-artifact
//!   storage with mtime-LRU eviction, stats, and `clear`.
//!
//! Wiring: [`crate::coordinator::job::run_job`] opens the store when
//! `SystemConfig::store_enabled` is set and the app's variant declares
//! cacheable preprocessing ([`crate::apps::GraphApp::uses_store`]), then
//! threads a [`StoreCtx`] through [`crate::apps::GraphApp::prepare`]
//! into the apps' unified `Prepared::prepare(&StoreCtx)` constructors
//! (PageRank, CF, CC's symmetrized structures, and the PR/BC/BFS/SSSP
//! reordering permutation) — a disabled context *is* the no-store path;
//! `cagra batch` shares ONE store instance across a whole job list, with
//! per-job eviction-exemption scopes ([`ArtifactStore::begin_scope`]);
//! dataset loading reuses the [`codec`] layer to persist finished CSRs
//! (`graph/datasets.rs`), so warm loads map (or decode) instead of
//! rebuilding; `cagra cache stats|clear` exposes the store on the CLI.

pub mod artifact_store;
pub mod codec;
pub mod fingerprint;
pub mod mem;
pub mod mmap;
pub mod slice;

pub use artifact_store::{
    ArtifactInfo, ArtifactStore, ExemptionScope, ScopeId, StoreKey, StoreStats,
};
pub use codec::{Artifact, CODEC_VERSION};
pub use fingerprint::{fingerprint_csr, fingerprint_dataset};
pub use mem::{MemStats, MemStore};
pub use mmap::{mmap_supported, MappedRegion};
pub use slice::{ArcSlice, Pod};

/// The attached storage stack of an enabled [`StoreCtx`]: disk store,
/// eviction-exemption scope, and optionally the in-memory layer.
#[derive(Debug, Clone, Copy)]
struct Backend<'a> {
    store: &'a ArtifactStore,
    scope: ScopeId,
    mem: Option<&'a MemStore>,
}

/// The one storage surface every preparation site builds against —
/// enabled (a borrowed store + the dataset fingerprint that keys
/// artifacts + the job's exemption scope) or *disabled*, in which case
/// `get_or_build*` simply runs the builder. Apps therefore have a single
/// `prepare` code path; "no store" is not a second constructor but a
/// [`StoreCtx::disabled`] value. `Copy` so it threads through
/// constructors as a plain borrowed argument.
///
/// `with_mem` stacks the in-memory layer ([`MemStore`]) above the disk
/// store: [`StoreCtx::get_or_build_arc`] probes memory first, so a
/// resident process (`cagra serve`) pays zero decode on a warm request.
#[derive(Debug, Clone, Copy)]
pub struct StoreCtx<'a> {
    backend: Option<Backend<'a>>,
    /// Fingerprint of the job's dataset (0 when disabled — never used to
    /// form a key in that case, since the builders run unconditionally).
    pub fingerprint: u64,
}

impl<'a> StoreCtx<'a> {
    /// The no-store path: every `get_or_build*` runs its builder.
    pub fn disabled() -> StoreCtx<'static> {
        StoreCtx {
            backend: None,
            fingerprint: 0,
        }
    }

    /// Context under the instance-lifetime scope (stores that live
    /// exactly one job: tests, benches, one-shot tools).
    pub fn new(store: &'a ArtifactStore, fingerprint: u64) -> StoreCtx<'a> {
        StoreCtx::scoped(store, fingerprint, ScopeId::INSTANCE)
    }

    /// Context bound to a job's exemption scope
    /// ([`ArtifactStore::begin_scope`]) — how `run_job` threads per-job
    /// eviction scoping through shared, long-lived stores.
    pub fn scoped(store: &'a ArtifactStore, fingerprint: u64, scope: ScopeId) -> StoreCtx<'a> {
        StoreCtx {
            backend: Some(Backend {
                store,
                scope,
                mem: None,
            }),
            fingerprint,
        }
    }

    /// Stack the in-memory layer above the disk store for this context
    /// (no-op on a disabled context).
    pub fn with_mem(mut self, mem: &'a MemStore) -> StoreCtx<'a> {
        if let Some(b) = &mut self.backend {
            b.mem = Some(mem);
        }
        self
    }

    /// Whether a store is attached.
    pub fn is_enabled(&self) -> bool {
        self.backend.is_some()
    }

    /// The attached disk store, if any.
    pub fn store(&self) -> Option<&'a ArtifactStore> {
        self.backend.map(|b| b.store)
    }

    /// The attached in-memory layer, if any.
    pub fn mem(&self) -> Option<&'a MemStore> {
        self.backend.and_then(|b| b.mem)
    }

    /// [`ArtifactStore::get_or_build_scoped`] with a by-value key, so call
    /// sites that just built the key from `self.fingerprint` stay
    /// one-liners. Disabled contexts run `build` directly.
    pub fn get_or_build<T: Artifact>(&self, key: StoreKey, build: impl FnOnce() -> T) -> T {
        match &self.backend {
            Some(b) => b.store.get_or_build_scoped(&key, b.scope, build),
            None => build(),
        }
    }

    /// Like [`StoreCtx::get_or_build`], but the loaded value is pinned
    /// behind an [`std::sync::Arc`]. With a [`MemStore`] attached, the
    /// memory layer is probed first (keyed by the disk filename, which
    /// already embeds fingerprint, label, and codec version); a hit skips
    /// disk entirely. Disabled contexts return `Arc::new(build())`.
    pub fn get_or_build_arc<T>(&self, key: StoreKey, build: impl FnOnce() -> T) -> std::sync::Arc<T>
    where
        T: Artifact + Send + Sync + 'static,
    {
        let Some(b) = &self.backend else {
            return std::sync::Arc::new(build());
        };
        match b.mem {
            Some(m) => m.get_or_insert_full(&key.filename::<T>(), || {
                let v = b.store.get_or_build_scoped(&key, b.scope, build);
                let (bytes, mapped) = (v.mem_bytes(), v.mapped_bytes());
                (v, bytes, mapped)
            }),
            None => std::sync::Arc::new(b.store.get_or_build_scoped(&key, b.scope, build)),
        }
    }
}
