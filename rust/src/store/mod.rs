//! Persistent preprocessing-artifact store.
//!
//! The paper justifies its preprocessing passes — frequency-based
//! clustering (§3) and CSR segmenting (§4) — by noting their cost "can be
//! amortized across many runs" (Table 9). This subsystem makes that
//! amortization real: the outputs of preprocessing (permutations,
//! relabeled CSRs, and [`crate::segment::SegmentedCsr`] partitions) are
//! persisted to disk, keyed by
//!
//! > (graph fingerprint, ordering/label, seg_size, merge_block, codec version)
//!
//! so a service restart — or the next of "many runs" — pays a sequential
//! read instead of a rebuild (GPOP builds its partitions once offline for
//! the same reason).
//!
//! Three layers:
//! - [`fingerprint`] — cheap, sampled, order-insensitive content hashes of
//!   a [`crate::graph::Csr`] plus dataset identity.
//! - [`codec`] — the versioned little-endian on-disk format with header
//!   magic and checksums; corruption is always an `Err`, never a panic or
//!   a wrong decode.
//! - [`artifact_store`] — `get_or_build` over one-file-per-artifact
//!   storage with mtime-LRU eviction, stats, and `clear`.
//!
//! Wiring: [`crate::coordinator::job::run_job`] opens the store when
//! `SystemConfig::store_enabled` is set and the app's variant declares
//! cacheable preprocessing ([`crate::apps::GraphApp::uses_store`]), then
//! threads a [`StoreCtx`] through [`crate::apps::GraphApp::prepare`]
//! into the apps' `Prepared::new_cached` constructors (PageRank, CF, CC's
//! symmetrized structures, and the PR/BC/BFS/SSSP reordering
//! permutation); `cagra batch` shares ONE store instance across a whole
//! job list, with per-job eviction-exemption scopes
//! ([`ArtifactStore::begin_scope`]); dataset loading reuses the [`codec`]
//! layer to persist finished CSRs (`graph/datasets.rs`), so warm loads
//! decode instead of rebuilding; `cagra cache stats|clear` exposes the
//! store on the CLI.

pub mod artifact_store;
pub mod codec;
pub mod fingerprint;
pub mod mem;

pub use artifact_store::{ArtifactStore, ExemptionScope, ScopeId, StoreKey, StoreStats};
pub use codec::{Artifact, CODEC_VERSION};
pub use fingerprint::{fingerprint_csr, fingerprint_dataset};
pub use mem::{MemStats, MemStore};

/// A borrowed store plus the fingerprint of the job's dataset — what the
/// preprocessing sites need to form keys — and the job's
/// eviction-exemption scope (writes made through this context cannot be
/// evicted until the job's [`ExemptionScope`] is dropped). `Copy` so it
/// threads through constructors as a plain optional argument.
///
/// `mem` optionally stacks the in-memory layer ([`MemStore`]) above the
/// disk store: [`StoreCtx::get_or_build_arc`] probes memory first, so a
/// resident process (`cagra serve`) pays zero decode on a warm request.
#[derive(Debug, Clone, Copy)]
pub struct StoreCtx<'a> {
    pub store: &'a ArtifactStore,
    pub fingerprint: u64,
    pub scope: ScopeId,
    pub mem: Option<&'a MemStore>,
}

impl<'a> StoreCtx<'a> {
    /// Context under the instance-lifetime scope (stores that live
    /// exactly one job: tests, benches, one-shot tools).
    pub fn new(store: &'a ArtifactStore, fingerprint: u64) -> StoreCtx<'a> {
        StoreCtx::scoped(store, fingerprint, ScopeId::INSTANCE)
    }

    /// Context bound to a job's exemption scope
    /// ([`ArtifactStore::begin_scope`]) — how `run_job` threads per-job
    /// eviction scoping through shared, long-lived stores.
    pub fn scoped(store: &'a ArtifactStore, fingerprint: u64, scope: ScopeId) -> StoreCtx<'a> {
        StoreCtx {
            store,
            fingerprint,
            scope,
            mem: None,
        }
    }

    /// Stack the in-memory layer above the disk store for this context.
    pub fn with_mem(mut self, mem: &'a MemStore) -> StoreCtx<'a> {
        self.mem = Some(mem);
        self
    }

    /// [`ArtifactStore::get_or_build_scoped`] with a by-value key, so call
    /// sites that just built the key from `self.fingerprint` stay
    /// one-liners.
    pub fn get_or_build<T: Artifact>(&self, key: StoreKey, build: impl FnOnce() -> T) -> T {
        self.store.get_or_build_scoped(&key, self.scope, build)
    }

    /// Like [`StoreCtx::get_or_build`], but the decoded value is pinned
    /// behind an [`std::sync::Arc`]. With a [`MemStore`] attached, the
    /// memory layer is probed first (keyed by the disk filename, which
    /// already embeds fingerprint, label, and codec version); a hit skips
    /// disk and decode entirely. Without one this is `Arc::new(disk)`.
    pub fn get_or_build_arc<T>(&self, key: StoreKey, build: impl FnOnce() -> T) -> std::sync::Arc<T>
    where
        T: Artifact + Send + Sync + 'static,
    {
        match self.mem {
            Some(m) => m.get_or_insert(&key.filename::<T>(), || {
                let v = self.store.get_or_build_scoped(&key, self.scope, build);
                let bytes = v.mem_bytes();
                (v, bytes)
            }),
            None => std::sync::Arc::new(self.store.get_or_build_scoped(&key, self.scope, build)),
        }
    }
}
