//! Read-only file mappings via raw `mmap`/`munmap` syscalls — the
//! zero-copy substrate for warm artifact loads (DESIGN.md §6).
//!
//! Dependency-free in the style of `obs/pmu.rs`'s `perf_event_open`
//! reader: the syscalls go through the C runtime's variadic `syscall`
//! entry point with arch-gated syscall numbers, so no `libc` crate is
//! needed. Platforms without the real implementation (non-Linux,
//! big-endian, or the `mmap` feature off) get a stub whose `map` always
//! fails cleanly — callers fall back to read-and-decode.
//!
//! Safety model: mappings are `PROT_READ` + `MAP_PRIVATE`, so the pages
//! are immutable for the mapping's lifetime. The store only ever
//! *replaces* artifact files via write-to-temp + atomic rename (a new
//! inode) and never truncates or rewrites in place, so a live mapping's
//! inode stays intact even after the path is evicted or replaced —
//! no SIGBUS window. A [`MappedRegion`] is therefore a plain immutable
//! byte slab that is `Send + Sync` and unmapped on the last drop.

use anyhow::Result;

#[cfg(all(feature = "mmap", target_os = "linux", target_endian = "little"))]
mod imp {
    use anyhow::{bail, Context, Result};
    use std::ffi::c_void;
    use std::os::raw::{c_int, c_long};
    use std::os::unix::io::AsRawFd;
    use std::path::Path;
    use std::ptr::NonNull;

    // Raw syscall numbers for the mmap pair, per-arch like pmu.rs.
    #[cfg(target_arch = "x86_64")]
    const SYS_MMAP: c_long = 9;
    #[cfg(target_arch = "x86_64")]
    const SYS_MUNMAP: c_long = 11;
    #[cfg(target_arch = "aarch64")]
    const SYS_MMAP: c_long = 222;
    #[cfg(target_arch = "aarch64")]
    const SYS_MUNMAP: c_long = 215;

    const PROT_READ: c_long = 0x1;
    const MAP_PRIVATE: c_long = 0x02;

    extern "C" {
        // Variadic syscall entry from the C runtime (no libc crate).
        fn syscall(num: c_long, ...) -> c_long;
    }

    /// A whole-file read-only private mapping, unmapped on drop.
    pub struct MappedRegion {
        ptr: NonNull<u8>,
        len: usize,
    }

    // SAFETY: the region owns its mapping outright (no thread-affine
    // state; munmap is valid from any thread), so moving it across
    // threads is sound. PROT_READ + MAP_PRIVATE pages never change under
    // us (see module docs for the no-truncate store contract).
    unsafe impl Send for MappedRegion {}
    // SAFETY: all shared access is read-only over immutable PROT_READ
    // pages — `&MappedRegion` exposes no mutation, so concurrent readers
    // cannot race.
    unsafe impl Sync for MappedRegion {}

    impl MappedRegion {
        /// Map `path` read-only in full. Fails (never panics) on empty
        /// files, unmappable filesystems, or kernel refusal.
        pub fn map(path: &Path) -> Result<MappedRegion> {
            crate::fault::failpoint(crate::fault::Site::StoreMap)?;
            let file = std::fs::File::open(path)
                .with_context(|| format!("opening {} for mapping", path.display()))?;
            let len = file
                .metadata()
                .with_context(|| format!("stat {}", path.display()))?
                .len();
            if len == 0 {
                bail!("{}: empty file cannot be mapped", path.display());
            }
            let len: usize = len
                .try_into()
                .map_err(|_| anyhow::anyhow!("{}: file too large to map", path.display()))?;
            let fd: c_int = file.as_raw_fd();
            // SAFETY: a fresh anonymous address (addr = null), a length we
            // just measured, and an fd we own for the duration of the call.
            let addr = unsafe {
                syscall(
                    SYS_MMAP,
                    std::ptr::null_mut::<c_void>(),
                    len as c_long,
                    PROT_READ,
                    MAP_PRIVATE,
                    fd as c_long,
                    0 as c_long,
                )
            };
            // The C runtime's syscall wrapper reports failure as -1 (a
            // raw-syscall path would return -errno; cover both).
            if (-4095..=-1).contains(&addr) {
                bail!("mmap({}) failed", path.display());
            }
            let ptr = NonNull::new(addr as *mut u8)
                .ok_or_else(|| anyhow::anyhow!("mmap returned null"))?;
            Ok(MappedRegion { ptr, len })
        }

        pub fn len(&self) -> usize {
            self.len
        }

        pub fn is_empty(&self) -> bool {
            self.len == 0
        }

        pub fn as_ptr(&self) -> *const u8 {
            self.ptr.as_ptr()
        }

        /// The mapped file as an immutable byte slice.
        pub fn bytes(&self) -> &[u8] {
            // SAFETY: ptr/len describe a live PROT_READ mapping (held
            // alive by &self), and the pages are immutable for the
            // borrow's lifetime.
            unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
        }
    }

    impl Drop for MappedRegion {
        fn drop(&mut self) {
            // SAFETY: exactly the region mmap returned; errors on unmap
            // are unrecoverable and ignored (address space leak at worst).
            unsafe {
                syscall(SYS_MUNMAP, self.ptr.as_ptr() as c_long, self.len as c_long);
            }
        }
    }

    /// Real implementation present on this platform.
    pub const SUPPORTED: bool = true;
}

#[cfg(not(all(feature = "mmap", target_os = "linux", target_endian = "little")))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub: mapping is unavailable; every `map` fails cleanly and the
    /// store falls back to read-and-decode.
    pub struct MappedRegion {
        never: std::convert::Infallible,
    }

    impl MappedRegion {
        pub fn map(path: &Path) -> Result<MappedRegion> {
            bail!(
                "mmap unavailable on this platform ({}): falling back to decode",
                path.display()
            );
        }

        pub fn len(&self) -> usize {
            match self.never {}
        }

        pub fn is_empty(&self) -> bool {
            match self.never {}
        }

        pub fn as_ptr(&self) -> *const u8 {
            match self.never {}
        }

        pub fn bytes(&self) -> &[u8] {
            match self.never {}
        }
    }

    pub const SUPPORTED: bool = false;
}

pub use imp::{MappedRegion, SUPPORTED};

/// Whether this build can ever serve mapped artifacts.
pub fn mmap_supported() -> bool {
    SUPPORTED
}

impl std::fmt::Debug for MappedRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedRegion").field("len", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_whole_file_or_fails_cleanly() {
        let dir = std::env::temp_dir().join(format!("cagra-mmap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let data: Vec<u8> = (0..8192u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        match MappedRegion::map(&path) {
            Ok(region) => {
                assert!(mmap_supported());
                assert_eq!(region.len(), data.len());
                assert_eq!(region.bytes(), &data[..]);
                // Shared across threads: the region is Send + Sync.
                let shared = std::sync::Arc::new(region);
                let r2 = shared.clone();
                let sum: u64 = std::thread::spawn(move || {
                    r2.bytes().iter().map(|&b| b as u64).sum()
                })
                .join()
                .unwrap();
                assert_eq!(sum, data.iter().map(|&b| b as u64).sum::<u64>());
            }
            Err(_) => assert!(!mmap_supported(), "supported platform must map a plain file"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_file_errs() {
        if !mmap_supported() {
            return;
        }
        let dir = std::env::temp_dir().join(format!("cagra-mmap-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        assert!(MappedRegion::map(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errs() {
        let path = std::path::Path::new("/nonexistent/cagra-definitely-missing.art");
        assert!(MappedRegion::map(path).is_err());
    }
}
