//! Content fingerprints for graphs — the first component of every
//! [`super::StoreKey`].
//!
//! A fingerprint must be (a) cheap relative to the preprocessing it keys
//! (reordering / segmenting cost a small multiple of a PageRank iteration,
//! Table 9, so hashing must be a small fraction of one), and (b) stable
//! across reloads of the same dataset. (b) is subtler than it looks:
//! [`crate::graph::Csr::from_edges`] scatters edges with atomic per-vertex
//! cursors, so the order of neighbors *within* a bucket differs from run
//! to run. The fingerprint therefore hashes each vertex's neighbor
//! **multiset** commutatively (wrapping *sum* of per-edge mixes — not
//! XOR, which would cancel duplicate edges in pairs and alias distinct
//! multigraphs) — any interleaving of the same edges produces the same
//! fingerprint, while changing a single edge of a sampled vertex changes
//! it.
//!
//! Cost is bounded by sampling: up to [`MAX_SAMPLES`] vertices (chosen by
//! stable vertex *id*, not array position) contribute their offsets and
//! neighbor lists; lengths and the full degree-prefix shape are always
//! mixed in, so any change that shifts `offsets` is caught even for
//! unsampled vertices. Hashing is position-salted and XOR-combined, so it
//! parallelizes with [`parallel_reduce`] deterministically under any
//! thread count.

use crate::graph::Csr;
use crate::parallel::parallel_reduce;

/// Upper bound on sampled vertices (and sampled offsets) per array.
pub const MAX_SAMPLES: usize = 1 << 16;

/// SplitMix64 finalizer — the avalanche step every hash here runs through.
#[inline]
pub(crate) fn mix64(z: u64) -> u64 {
    let z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    let z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a over raw bytes with a final avalanche (labels, dataset names,
/// codec checksums).
pub fn hash_bytes(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed ^ 0xCBF29CE484222325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100000001B3);
    }
    mix64(h)
}

/// `i`-th sampled index of `0..len` when keeping at most `samples`.
#[inline]
fn sample_pos(i: usize, len: usize, samples: usize) -> usize {
    if len <= samples {
        i
    } else {
        ((i as u128 * len as u128) / samples as u128) as usize
    }
}

/// Position-salted sampled hash of the offsets array. Offsets are built by
/// a deterministic counting pass, so positional hashing is stable.
fn hash_offsets(offsets: &[u64]) -> u64 {
    let len = offsets.len();
    if len == 0 {
        return mix64(0x0FF5E75);
    }
    let samples = len.min(MAX_SAMPLES);
    let h = parallel_reduce(
        samples,
        || 0u64,
        |acc, i| {
            let pos = sample_pos(i, len, samples);
            acc.wrapping_add(mix64(
                0x0FF5E75
                    ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ offsets[pos].wrapping_mul(0xC2B2AE3D27D4EB4F),
            ))
        },
        |a, b| a.wrapping_add(b),
    );
    mix64(h ^ (len as u64).wrapping_mul(0xA24BAED4963EE407))
}

/// Sampled commutative hash of adjacency: for each sampled vertex `u`,
/// sum-fold `mix(u, v)` over its neighbors `v` (order-insensitive but
/// multiplicity-sensitive: duplicate edges add twice instead of
/// cancelling), salted with `u` and its degree.
fn hash_adjacency(g: &Csr) -> u64 {
    let n = g.num_vertices();
    if n == 0 {
        return mix64(0xAD7ACE);
    }
    let samples = n.min(MAX_SAMPLES);
    let h = parallel_reduce(
        samples,
        || 0u64,
        |acc, i| {
            let u = sample_pos(i, n, samples);
            let mut local = (u as u64)
                .wrapping_mul(0x9E3779B97F4A7C15)
                ^ (g.degree(u as u32) as u64).wrapping_mul(0xC2B2AE3D27D4EB4F);
            for &v in g.neighbors(u as u32) {
                // Commutative across neighbors: bucket scatter order is
                // nondeterministic (atomic cursors in from_edges).
                local = local.wrapping_add(mix64(0xAD7ACE ^ ((u as u64) << 32) ^ v as u64));
            }
            acc.wrapping_add(mix64(local))
        },
        |a, b| a.wrapping_add(b),
    );
    mix64(h ^ (n as u64).rotate_left(31))
}

/// Fingerprint of a CSR's structure: lengths, degree shape (`offsets`),
/// and sampled neighbor multisets.
pub fn fingerprint_csr(g: &Csr) -> u64 {
    let shape = mix64(
        (g.num_vertices() as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ g.num_edges() as u64,
    );
    mix64(shape ^ hash_offsets(&g.offsets).rotate_left(17) ^ hash_adjacency(g).rotate_left(43))
}

/// Fingerprint keying the artifact store: dataset identity (name + scale)
/// mixed with the structural fingerprint of the loaded graph. Including
/// both means a regenerated stand-in with different generator parameters
/// can never alias a stale artifact, while the name/scale pair keeps
/// distinct datasets apart even under a (vanishingly unlikely) structural
/// hash collision.
pub fn fingerprint_dataset(name: &str, scale: f64, g: &Csr) -> u64 {
    let id = hash_bytes(0xDA7A5E7, name.as_bytes());
    mix64(id ^ scale.to_bits().rotate_left(21) ^ fingerprint_csr(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    fn graph(seed: u64) -> Csr {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), seed);
        Csr::from_edges(n, &e)
    }

    #[test]
    fn deterministic_across_calls() {
        let g = graph(1);
        assert_eq!(fingerprint_csr(&g), fingerprint_csr(&g));
        assert_eq!(
            fingerprint_dataset("x", 0.5, &g),
            fingerprint_dataset("x", 0.5, &g)
        );
    }

    #[test]
    fn insensitive_to_neighbor_order() {
        // Same edge multiset, different bucket order → same fingerprint.
        let edges = vec![(0u32, 1u32), (0, 2), (0, 3), (2, 1), (3, 0)];
        let mut reversed = edges.clone();
        reversed.reverse();
        let a = Csr::from_edges(4, &edges);
        let b = Csr::from_edges(4, &reversed);
        assert_eq!(fingerprint_csr(&a), fingerprint_csr(&b));
    }

    #[test]
    fn sensitive_to_structure_name_and_scale() {
        let g = graph(1);
        let h = graph(2);
        assert_ne!(fingerprint_csr(&g), fingerprint_csr(&h));
        assert_ne!(
            fingerprint_dataset("a", 1.0, &g),
            fingerprint_dataset("b", 1.0, &g)
        );
        assert_ne!(
            fingerprint_dataset("a", 1.0, &g),
            fingerprint_dataset("a", 0.5, &g)
        );
    }

    #[test]
    fn single_edge_change_flips_fingerprint() {
        let a = Csr::from_edges(6, &[(0, 1), (1, 2), (2, 3), (4, 5)]);
        let b = Csr::from_edges(6, &[(0, 1), (1, 2), (2, 4), (4, 5)]);
        assert_ne!(fingerprint_csr(&a), fingerprint_csr(&b));
    }

    #[test]
    fn duplicate_edges_do_not_cancel() {
        // Same degrees, same offsets; differ only in an even-multiplicity
        // neighbor swap. An XOR fold would alias these (pairs cancel);
        // the sum fold must not.
        let a = Csr::from_edges(4, &[(0, 1), (0, 1), (0, 3)]);
        let b = Csr::from_edges(4, &[(0, 2), (0, 2), (0, 3)]);
        assert_eq!(a.out_degrees(), b.out_degrees());
        assert_ne!(fingerprint_csr(&a), fingerprint_csr(&b));
    }

    #[test]
    fn prop_relabel_changes_fingerprint() {
        // Distinct permutations should (overwhelmingly) produce distinct
        // fingerprints — that is what keys reordered artifacts apart.
        check("relabel changes fingerprint", 15, |gen| {
            let (n, edges) = gen.edges(8..80, 4);
            let g = Csr::from_edges(n, &edges);
            let perm = gen.permutation(n);
            let identity: Vec<u32> = (0..n as u32).collect();
            if perm != identity {
                let h = g.relabel(&perm);
                if h.sorted() != g.sorted() {
                    assert_ne!(fingerprint_csr(&g), fingerprint_csr(&h));
                }
            }
        });
    }

    #[test]
    fn hash_bytes_discriminates() {
        assert_ne!(hash_bytes(0, b"abc"), hash_bytes(0, b"abd"));
        assert_ne!(hash_bytes(0, b"abc"), hash_bytes(1, b"abc"));
        assert_eq!(hash_bytes(7, b""), hash_bytes(7, b""));
    }
}
