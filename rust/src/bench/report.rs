//! Machine-readable bench results (`BENCH_<suite>.json`).
//!
//! Every bench suite emits — alongside its paper-style ASCII table — a
//! versioned JSON report that CI archives and `cagra bench diff` compares
//! against a committed baseline. The format is hand-rolled over
//! [`crate::util::json`] (offline mirror — no serde) and versioned so a
//! newer writer can never be silently misread by an older parser.
//!
//! File layout (`FORMAT_NAME` / `FORMAT_VERSION`):
//!
//! ```json
//! {
//!   "format": "cagra-bench",
//!   "version": 1,
//!   "note": "optional free-form provenance",
//!   "suites": [
//!     {
//!       "suite": "table2_pagerank",
//!       "git_sha": "f41d867…",
//!       "scale": 0.25,
//!       "threads": 4,
//!       "cases": [
//!         {"name": "twitter-sim/optimized", "unit": "s", "reps": 5,
//!          "median": 0.141, "mean": 0.143, "stddev": 0.002,
//!          "min": 0.139, "max": 0.147, "work": 47283456,
//!          "rate": 335343659.57}
//!       ]
//!     }
//!   ]
//! }
//! ```
//!
//! `rate` (work units per second at the median) is derived on encode and
//! ignored on parse, so encode→parse→encode is byte-stable.

use crate::bench::Measurement;
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Format discriminator in every report file.
pub const FORMAT_NAME: &str = "cagra-bench";
/// Schema version this build writes and the newest it can read.
pub const FORMAT_VERSION: u64 = 1;

/// Unit tag for wall-clock timings (the default for `Bencher` cases).
pub const UNIT_SECS: &str = "s";

/// One measured (or simulated) case inside a suite.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseResult {
    /// Unique within the suite; scoped as `<scope>/<label>` by the runner.
    pub name: String,
    /// Metric unit ("s" for timings; simulation suites use e.g.
    /// "GCycles", "q", "pp"). `bench diff` only compares like units and
    /// always treats a larger median as worse.
    pub unit: String,
    pub reps: usize,
    pub median: f64,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// Optional work units (e.g. edges) for rate reporting.
    pub work: Option<u64>,
}

impl CaseResult {
    /// Convert a harness measurement (always seconds).
    pub fn from_measurement(m: &Measurement) -> CaseResult {
        CaseResult {
            name: m.name.clone(),
            unit: UNIT_SECS.to_string(),
            reps: m.summary.n,
            median: m.summary.median,
            mean: m.summary.mean,
            stddev: m.summary.stddev,
            min: m.summary.min,
            max: m.summary.max,
            work: m.work,
        }
    }

    /// A single deterministic sample (simulated/analytic metrics).
    pub fn single(name: &str, unit: &str, value: f64) -> CaseResult {
        CaseResult {
            name: name.to_string(),
            unit: unit.to_string(),
            reps: 1,
            median: value,
            mean: value,
            stddev: 0.0,
            min: value,
            max: value,
            work: None,
        }
    }

    /// Work units per second at the median, if work was recorded.
    pub fn rate(&self) -> Option<f64> {
        match self.work {
            Some(w) if self.median > 0.0 => Some(w as f64 / self.median),
            _ => None,
        }
    }

    fn to_value(&self) -> Value {
        let mut fields = vec![
            ("name".to_string(), Value::Str(self.name.clone())),
            ("unit".to_string(), Value::Str(self.unit.clone())),
            ("reps".to_string(), Value::Num(self.reps as f64)),
            ("median".to_string(), Value::Num(self.median)),
            ("mean".to_string(), Value::Num(self.mean)),
            ("stddev".to_string(), Value::Num(self.stddev)),
            ("min".to_string(), Value::Num(self.min)),
            ("max".to_string(), Value::Num(self.max)),
        ];
        if let Some(w) = self.work {
            fields.push(("work".to_string(), Value::Num(w as f64)));
        }
        if let Some(r) = self.rate() {
            fields.push(("rate".to_string(), Value::Num(r)));
        }
        Value::Obj(fields)
    }

    fn from_value(v: &Value) -> Result<CaseResult> {
        let name = require_str(v, "name")?;
        let case = CaseResult {
            name: name.clone(),
            unit: require_str(v, "unit")?,
            reps: require_u64(v, &name, "reps")? as usize,
            median: require_num(v, &name, "median")?,
            mean: require_num(v, &name, "mean")?,
            stddev: require_num(v, &name, "stddev")?,
            min: require_num(v, &name, "min")?,
            max: require_num(v, &name, "max")?,
            work: match v.get("work") {
                None | Some(Value::Null) => None,
                Some(w) => Some(
                    w.as_u64()
                        .with_context(|| format!("case {name:?}: work must be a u64"))?,
                ),
            },
        };
        if case.reps == 0 {
            bail!("case {name:?}: reps must be >= 1");
        }
        if case.median < 0.0 || case.stddev < 0.0 {
            bail!("case {name:?}: negative median/stddev");
        }
        Ok(case)
    }
}

/// One suite's results: identity + environment + cases.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Suite name from the registry ([`crate::bench::suite::SUITES`]).
    pub suite: String,
    /// Commit the binary was built from (best effort; "unknown" offline).
    pub git_sha: String,
    /// `CAGRA_BENCH_SCALE` the suite ran at.
    pub scale: f64,
    /// Worker threads in the global pool.
    pub threads: usize,
    pub cases: Vec<CaseResult>,
}

impl BenchReport {
    pub fn case(&self, name: &str) -> Option<&CaseResult> {
        self.cases.iter().find(|c| c.name == name)
    }

    fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("suite".to_string(), Value::Str(self.suite.clone())),
            ("git_sha".to_string(), Value::Str(self.git_sha.clone())),
            ("scale".to_string(), Value::Num(self.scale)),
            ("threads".to_string(), Value::Num(self.threads as f64)),
            (
                "cases".to_string(),
                Value::Arr(self.cases.iter().map(CaseResult::to_value).collect()),
            ),
        ])
    }

    fn from_value(v: &Value) -> Result<BenchReport> {
        let suite = require_str(v, "suite")?;
        let cases = v
            .get("cases")
            .and_then(Value::as_arr)
            .with_context(|| format!("suite {suite:?}: missing cases array"))?;
        Ok(BenchReport {
            suite: suite.clone(),
            git_sha: require_str(v, "git_sha")?,
            scale: require_num(v, &suite, "scale")?,
            threads: require_u64(v, &suite, "threads")? as usize,
            cases: cases
                .iter()
                .map(CaseResult::from_value)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("suite {suite:?}"))?,
        })
    }
}

/// A report file: one or more suites (a single emitted `BENCH_*.json`
/// holds one; a merged baseline holds many).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BenchFile {
    /// Free-form provenance ("" = omitted from the encoding).
    pub note: String,
    pub suites: Vec<BenchReport>,
}

impl BenchFile {
    pub fn single(report: BenchReport) -> BenchFile {
        BenchFile {
            note: String::new(),
            suites: vec![report],
        }
    }

    pub fn suite(&self, name: &str) -> Option<&BenchReport> {
        self.suites.iter().find(|s| s.suite == name)
    }

    pub fn case_count(&self) -> usize {
        self.suites.iter().map(|s| s.cases.len()).sum()
    }

    /// Encode to the versioned JSON format. Errors on non-finite stats
    /// (which would otherwise lossily encode as `null`).
    pub fn to_json(&self) -> Result<String> {
        for s in &self.suites {
            if !s.scale.is_finite() {
                bail!("suite {:?}: non-finite scale", s.suite);
            }
            for c in &s.cases {
                for (field, v) in [
                    ("median", c.median),
                    ("mean", c.mean),
                    ("stddev", c.stddev),
                    ("min", c.min),
                    ("max", c.max),
                ] {
                    if !v.is_finite() {
                        bail!("suite {:?} case {:?}: non-finite {field}", s.suite, c.name);
                    }
                }
            }
        }
        let mut fields = vec![
            ("format".to_string(), Value::Str(FORMAT_NAME.to_string())),
            ("version".to_string(), Value::Num(FORMAT_VERSION as f64)),
        ];
        if !self.note.is_empty() {
            fields.push(("note".to_string(), Value::Str(self.note.clone())));
        }
        fields.push((
            "suites".to_string(),
            Value::Arr(self.suites.iter().map(BenchReport::to_value).collect()),
        ));
        let mut out = Value::Obj(fields).render();
        out.push('\n');
        Ok(out)
    }

    /// Strict parse: wrong format tag, unsupported version, missing
    /// fields, or malformed JSON all error.
    pub fn parse(input: &str) -> Result<BenchFile> {
        let v = json::parse(input).context("bench report is not valid JSON")?;
        let format = v
            .get("format")
            .and_then(Value::as_str)
            .context("missing format tag")?;
        if format != FORMAT_NAME {
            bail!("not a bench report (format {format:?}, expected {FORMAT_NAME:?})");
        }
        let version = v
            .get("version")
            .and_then(Value::as_u64)
            .context("missing format version")?;
        if version > FORMAT_VERSION {
            bail!("bench report version {version} is newer than this build (max {FORMAT_VERSION})");
        }
        let suites = v
            .get("suites")
            .and_then(Value::as_arr)
            .context("missing suites array")?;
        let file = BenchFile {
            note: v
                .get("note")
                .and_then(Value::as_str)
                .unwrap_or("")
                .to_string(),
            suites: suites
                .iter()
                .map(BenchReport::from_value)
                .collect::<Result<Vec<_>>>()?,
        };
        let mut seen = std::collections::BTreeSet::new();
        for s in &file.suites {
            if !seen.insert(s.suite.as_str()) {
                bail!("duplicate suite {:?} in bench report", s.suite);
            }
        }
        Ok(file)
    }

    /// Combine files into one (for baselines). Duplicate suites error.
    pub fn merge(files: Vec<BenchFile>) -> Result<BenchFile> {
        let mut out = BenchFile::default();
        for f in files {
            for s in f.suites {
                if out.suite(&s.suite).is_some() {
                    bail!("suite {:?} appears in more than one input", s.suite);
                }
                out.suites.push(s);
            }
        }
        Ok(out)
    }

    /// Load one report file.
    pub fn load(path: &Path) -> Result<BenchFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Load a report file, or merge every `BENCH_*.json` in a directory.
    pub fn load_path(path: &Path) -> Result<BenchFile> {
        if !path.is_dir() {
            return Self::load(path);
        }
        let mut names: Vec<PathBuf> = std::fs::read_dir(path)
            .with_context(|| format!("listing {}", path.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect();
        if names.is_empty() {
            bail!("no BENCH_*.json files in {}", path.display());
        }
        names.sort();
        let files = names
            .iter()
            .map(|p| Self::load(p))
            .collect::<Result<Vec<_>>>()?;
        Self::merge(files)
    }
}

fn require_str(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .with_context(|| format!("missing string field {key:?}"))
}

fn require_num(v: &Value, ctx: &str, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .with_context(|| format!("{ctx}: missing numeric field {key:?}"))
}

fn require_u64(v: &Value, ctx: &str, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_u64)
        .with_context(|| format!("{ctx}: missing integer field {key:?}"))
}

/// Output directory for emitted reports (`CAGRA_BENCH_OUT`, default cwd).
pub fn out_dir() -> PathBuf {
    std::env::var("CAGRA_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."))
}

/// File name convention every suite emits under.
pub fn report_filename(suite: &str) -> String {
    format!("BENCH_{suite}.json")
}

/// Write `BENCH_<suite>.json` into [`out_dir`], creating it if needed.
pub fn write_report(report: &BenchReport) -> Result<PathBuf> {
    let dir = out_dir();
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(report_filename(&report.suite));
    let text = BenchFile::single(report.clone()).to_json()?;
    std::fs::write(&path, text).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Commit the running binary's tree corresponds to, best effort:
/// `CAGRA_GIT_SHA` / `GITHUB_SHA` env, else `.git/HEAD` found by walking
/// up from the current directory, else "unknown". No subprocesses.
pub fn git_sha() -> String {
    for var in ["CAGRA_GIT_SHA", "GITHUB_SHA"] {
        if let Ok(v) = std::env::var(var) {
            if !v.trim().is_empty() {
                return v.trim().to_string();
            }
        }
    }
    resolve_git_head().unwrap_or_else(|| "unknown".to_string())
}

fn resolve_git_head() -> Option<String> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let git = dir.join(".git");
        if let Ok(head) = std::fs::read_to_string(git.join("HEAD")) {
            let head = head.trim();
            let Some(refname) = head.strip_prefix("ref: ") else {
                // Detached HEAD: the file holds the sha directly.
                return Some(head.to_string());
            };
            if let Ok(sha) = std::fs::read_to_string(git.join(refname)) {
                return Some(sha.trim().to_string());
            }
            if let Ok(packed) = std::fs::read_to_string(git.join("packed-refs")) {
                for line in packed.lines() {
                    if line.starts_with('#') {
                        continue;
                    }
                    if let Some(sha) = line.strip_suffix(refname) {
                        if sha.ends_with(' ') {
                            return Some(sha.trim().to_string());
                        }
                    }
                }
            }
            return None;
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> BenchFile {
        BenchFile {
            note: String::new(),
            suites: vec![BenchReport {
                suite: "table2_pagerank".into(),
                git_sha: "deadbeef".into(),
                scale: 0.25,
                threads: 4,
                cases: vec![
                    CaseResult {
                        name: "twitter-sim/optimized".into(),
                        unit: UNIT_SECS.into(),
                        reps: 5,
                        median: 0.141,
                        mean: 0.1432,
                        stddev: 0.0021,
                        min: 0.139,
                        max: 0.147,
                        work: Some(47_283_456),
                    },
                    CaseResult::single("twitter-sim/q", "q", 2.31),
                ],
            }],
        }
    }

    #[test]
    fn encode_parse_encode_is_byte_stable() {
        let f = sample_file();
        let once = f.to_json().unwrap();
        let back = BenchFile::parse(&once).unwrap();
        assert_eq!(back, f);
        assert_eq!(back.to_json().unwrap(), once);
    }

    #[test]
    fn version_and_format_are_enforced() {
        let good = sample_file().to_json().unwrap();
        let newer = good.replace("\"version\": 1", "\"version\": 99");
        assert!(BenchFile::parse(&newer).is_err(), "future version accepted");
        let alien = good.replace("cagra-bench", "other-tool");
        assert!(BenchFile::parse(&alien).is_err(), "foreign format accepted");
    }

    #[test]
    fn missing_fields_error() {
        for field in ["\"median\"", "\"unit\"", "\"suite\"", "\"git_sha\""] {
            let broken = sample_file()
                .to_json()
                .unwrap()
                .replace(field, "\"renamed\"");
            assert!(BenchFile::parse(&broken).is_err(), "missing {field} accepted");
        }
    }

    #[test]
    fn fractional_counts_are_rejected() {
        let good = sample_file().to_json().unwrap();
        for (from, to) in [("\"reps\": 5", "\"reps\": 5.5"), ("\"threads\": 4", "\"threads\": 4.5")]
        {
            let bad = good.replacen(from, to, 1);
            assert!(BenchFile::parse(&bad).is_err(), "accepted fractional {from}");
        }
    }

    #[test]
    fn non_finite_stats_refuse_to_encode() {
        let mut f = sample_file();
        f.suites[0].cases[0].median = f64::NAN;
        assert!(f.to_json().is_err());
    }

    #[test]
    fn merge_rejects_duplicate_suites() {
        let a = sample_file();
        let b = sample_file();
        assert!(BenchFile::merge(vec![a.clone(), b]).is_err());
        let merged = BenchFile::merge(vec![a]).unwrap();
        assert_eq!(merged.case_count(), 2);
    }

    #[test]
    fn rate_derived_from_work() {
        let c = &sample_file().suites[0].cases[0];
        let r = c.rate().unwrap();
        assert!((r - 47_283_456.0 / 0.141).abs() < 1e-6);
        assert!(CaseResult::single("x", "q", 1.0).rate().is_none());
    }

    #[test]
    fn git_sha_prefers_env() {
        // Can't mutate process env safely in parallel tests; just check
        // the fallback produces *something* stable.
        let a = git_sha();
        let b = git_sha();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }
}
