//! Bench-suite registry and the shared suite runner.
//!
//! Every target under `rust/benches/` registers here and runs through
//! [`run`] (via `benches/common::run_suite`) instead of an ad-hoc `main`:
//! the runner prints the standard header, collects every case the body
//! records into one [`BenchReport`], and emits `BENCH_<suite>.json` into
//! `CAGRA_BENCH_OUT` (default: current directory) alongside the ASCII
//! tables. A suite that records no cases panics — CI's bench-smoke job
//! turns silent bench bit-rot into a red build.
//!
//! [`SUITES`] is the single source of truth the CLI (`cagra bench ls`)
//! renders; a bench target whose name is not registered panics at
//! startup, so the registry cannot drift from the actual targets.

use super::report::{self, BenchReport, CaseResult};
use super::{header, Bencher, Measurement};
use anyhow::{bail, Result};

/// Static description of one bench suite (one `rust/benches/*.rs` target).
#[derive(Debug, Clone, Copy)]
pub struct SuiteInfo {
    /// Target name (`cargo bench --bench <name>`; `BENCH_<name>.json`).
    pub name: &'static str,
    /// Header line printed before the tables.
    pub title: &'static str,
    /// What the suite reproduces.
    pub paper_ref: &'static str,
    /// Case labels the suite records (full case names are
    /// `<scope>/<label>`; unscoped suites record the label alone).
    pub cases: &'static [&'static str],
    /// What the scope component ranges over.
    pub scopes: &'static str,
}

/// Every bench target, in paper order.
pub const SUITES: &[SuiteInfo] = &[
    SuiteInfo {
        name: "fig1_overview",
        title: "Figure 1: ours vs frameworks, RMAT27",
        paper_ref: "paper Figure 1",
        cases: &[
            "pr-opt",
            "pr-graphmat",
            "pr-ligra",
            "pr-gridgraph",
            "cf-opt",
            "cf-graphmat",
            "bc-opt",
            "bc-ligra",
        ],
        scopes: "unscoped (rmat27-sim + netflix-sim)",
    },
    SuiteInfo {
        name: "fig2_breakdown",
        title: "Figure 2: optimization breakdown, PageRank RMAT27",
        paper_ref: "paper Figure 2",
        cases: &["<variant>", "<variant>-stalls"],
        scopes: "unscoped (rmat27-sim, every registry PageRank variant)",
    },
    SuiteInfo {
        name: "fig3_stalls",
        title: "Figure 3: % cycles stalled on memory (simulated)",
        paper_ref: "paper Figure 3",
        cases: &["<dataset>"],
        scopes: "apps (pagerank, cf, bc, bfs)",
    },
    SuiteInfo {
        name: "fig6_merge_cost",
        title: "Figure 6: segment compute vs merge cost",
        paper_ref: "paper Figure 6",
        cases: &["segment-compute", "merge", "other", "total-iter"],
        scopes: "datasets (twitter-sim, rmat27-sim)",
    },
    SuiteInfo {
        name: "fig7_expansion",
        title: "Figure 7: expansion factor vs segment count",
        paper_ref: "paper Figure 7",
        cases: &["k=<segments>"],
        scopes: "dataset/ordering",
    },
    SuiteInfo {
        name: "fig8_speedups",
        title: "Figure 8: per-optimization speedups",
        paper_ref: "paper Figure 8",
        cases: &[
            "base",
            "reorder",
            "segment",
            "both",
            "cf-base",
            "cf-seg",
            "bc-<variant>",
            "bfs-<variant>",
        ],
        scopes: "datasets",
    },
    SuiteInfo {
        name: "fig9_per_edge",
        title: "Figure 9: per-edge time and stalls",
        paper_ref: "paper Figure 9",
        cases: &["<pagerank variant>", "cf-base", "cf-seg"],
        scopes: "datasets",
    },
    SuiteInfo {
        name: "fig10_hilbert",
        title: "Figure 10: Hilbert parallelizations vs segmenting",
        paper_ref: "paper Figure 10",
        cases: &["t=<threads>"],
        scopes: "modes (hserial, hatomic, hmerge, segmenting)",
    },
    SuiteInfo {
        name: "fig11_scalability",
        title: "Figure 11: PageRank thread scalability",
        paper_ref: "paper Figure 11",
        cases: &["t=<threads>"],
        scopes: "unscoped (twitter-sim)",
    },
    SuiteInfo {
        name: "model_validation",
        title: "Section 5: analytical model vs simulator",
        paper_ref: "paper §5 (within-5% claim)",
        cases: &["<cache KiB>", "worst-random-pp", "prop2-beaten"],
        scopes: "graph/ordering",
    },
    SuiteInfo {
        name: "table2_pagerank",
        title: "Table 2: PageRank per-iteration runtime",
        paper_ref: "paper Table 2",
        cases: &["optimized", "baseline", "graphmat", "ligra", "gridgraph"],
        scopes: "graph datasets",
    },
    SuiteInfo {
        name: "table3_cf",
        title: "Table 3: Collaborative Filtering per-iteration runtime",
        paper_ref: "paper Table 3",
        cases: &["optimized", "baseline"],
        scopes: "CF datasets",
    },
    SuiteInfo {
        name: "table4_bc",
        title: "Table 4: Betweenness Centrality runtime",
        paper_ref: "paper Table 4",
        cases: &["optimized", "ligra"],
        scopes: "graph datasets",
    },
    SuiteInfo {
        name: "table5_bfs",
        title: "Table 5: BFS runtime",
        paper_ref: "paper Table 5",
        cases: &["optimized", "ligra"],
        scopes: "graph datasets",
    },
    SuiteInfo {
        name: "table6_inmem",
        title: "Table 6: 20-iteration in-memory PageRank, LiveJournal",
        paper_ref: "paper Table 6",
        cases: &["graphmat", "gridgraph", "xstream"],
        scopes: "unscoped (livejournal-sim)",
    },
    SuiteInfo {
        name: "table7_bc_stalls",
        title: "Table 7: simulated stall cycles, Betweenness Centrality",
        paper_ref: "paper Table 7",
        cases: &["baseline", "reordering", "bitvector", "reordering+bitvector"],
        scopes: "graph datasets",
    },
    SuiteInfo {
        name: "table8_bfs_stalls",
        title: "Table 8: simulated stall cycles, BFS",
        paper_ref: "paper Table 8",
        cases: &["baseline", "reordering", "bitvector", "reordering+bitvector"],
        scopes: "graph datasets",
    },
    SuiteInfo {
        name: "table9_preprocessing",
        title: "Table 9: preprocessing runtime",
        paper_ref: "paper Table 9",
        cases: &["reorder", "segment", "csr", "load-warm", "seg-cold", "seg-warm", "pr-iter"],
        scopes: "datasets (livejournal, twitter, rmat27)",
    },
    SuiteInfo {
        name: "table10_traffic",
        title: "Table 10: sequential-DRAM-traffic model",
        paper_ref: "paper Table 10",
        cases: &["q", "ours", "gridgraph", "xstream"],
        scopes: "datasets (twitter-sim, rmat27-sim)",
    },
    SuiteInfo {
        name: "ablation_params",
        title: "Ablations: coarsen / merge block / segment fill",
        paper_ref: "DESIGN.md design choices",
        cases: &["<value>"],
        scopes: "knobs (coarsen, merge-block, segment-fill)",
    },
    SuiteInfo {
        name: "frontier_churn",
        title: "Frontier churn: deep narrow-frontier traversals (engine scratch reuse)",
        paper_ref: "engine zero-allocation steady state (no paper analogue)",
        cases: &["bfs-deep", "bfs-deep-bitvector", "sssp-deep", "bfs-wide-levels"],
        scopes: "unscoped (synthetic deep-chain / lattice graphs)",
    },
    SuiteInfo {
        name: "serve_throughput",
        title: "Serving: worker-pool throughput, cold vs resident artifact layer",
        paper_ref: "ROADMAP serving north star (no paper analogue)",
        cases: &["jobs-per-sec", "p50-ms", "p99-ms"],
        scopes: "cold (fresh pool per round) / resident (warm shared layer)",
    },
];

/// Look up a suite by target name.
pub fn find(name: &str) -> Option<&'static SuiteInfo> {
    SUITES.iter().find(|s| s.name == name)
}

/// Per-run suite context: a [`Bencher`] plus case collection under a
/// current scope, accumulated into the suite's [`BenchReport`].
pub struct Suite {
    pub info: &'static SuiteInfo,
    pub bencher: Bencher,
    scope: String,
    cases: Vec<CaseResult>,
}

impl Suite {
    pub fn new(info: &'static SuiteInfo) -> Suite {
        Suite {
            info,
            bencher: Bencher::new(),
            scope: String::new(),
            cases: Vec::new(),
        }
    }

    /// Set the scope prefixed onto subsequent case labels (typically the
    /// dataset). Empty scope = labels used verbatim.
    pub fn set_scope(&mut self, scope: &str) {
        self.scope = scope.to_string();
    }

    /// Cap measurement repetitions (suites trim reps on heavy sections;
    /// the env-driven default still lowers it further for smoke runs).
    pub fn cap_reps(&mut self, max: usize) {
        self.bencher.reps = self.bencher.reps.min(max.max(1));
    }

    pub fn reps(&self) -> usize {
        self.bencher.reps
    }

    fn qualify(&self, label: &str) -> String {
        if self.scope.is_empty() {
            label.to_string()
        } else {
            format!("{}/{label}", self.scope)
        }
    }

    /// Time `f` under the current scope (warmup + reps, median etc.).
    pub fn bench(&mut self, label: &str, f: impl FnMut()) -> Measurement {
        let mut f = f;
        self.bench_work(label, None, &mut f)
    }

    /// Like [`Suite::bench`] with a work-unit count for rate reporting.
    pub fn bench_work(
        &mut self,
        label: &str,
        work: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> Measurement {
        let name = self.qualify(label);
        let m = self.bencher.bench_work(&name, work, f);
        self.cases.push(CaseResult::from_measurement(&m));
        m
    }

    /// Record an externally-obtained deterministic metric (simulated
    /// stalls, expansion factors, subprocess timings) as a single-rep
    /// case under the current scope.
    pub fn record(&mut self, label: &str, unit: &str, value: f64) {
        let name = self.qualify(label);
        self.cases.push(CaseResult::single(&name, unit, value));
    }

    /// The accumulated report. Errors on an empty suite or duplicate case
    /// names (almost always a missing [`Suite::set_scope`] call).
    pub fn report(&self) -> Result<BenchReport> {
        if self.cases.is_empty() {
            bail!("suite {:?} recorded no cases", self.info.name);
        }
        let mut seen = std::collections::BTreeSet::new();
        for c in &self.cases {
            if !seen.insert(c.name.as_str()) {
                bail!(
                    "suite {:?} recorded case {:?} twice (missing set_scope?)",
                    self.info.name,
                    c.name
                );
            }
        }
        Ok(BenchReport {
            suite: self.info.name.to_string(),
            git_sha: report::git_sha(),
            scale: super::scale(),
            threads: crate::parallel::num_threads(),
            cases: self.cases.clone(),
        })
    }
}

/// Run a registered suite: header, body, then report emission. Panics
/// (nonzero bench exit) on unregistered names, empty reports, duplicate
/// cases, or emission failure — all bugs CI must surface.
pub fn run(name: &str, body: impl FnOnce(&mut Suite)) {
    let info = find(name).unwrap_or_else(|| {
        panic!("bench suite {name:?} is not registered in bench::suite::SUITES")
    });
    header(info.title, info.paper_ref);
    let mut suite = Suite::new(info);
    body(&mut suite);
    let report = suite
        .report()
        .unwrap_or_else(|e| panic!("bench suite {name}: {e:#}"));
    match report::write_report(&report) {
        Ok(path) => println!(
            "\nmachine-readable results: {} ({} cases)",
            path.display(),
            report.cases.len()
        ),
        Err(e) => panic!("bench suite {name}: emitting report: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_findable() {
        let mut seen = std::collections::BTreeSet::new();
        for s in SUITES {
            assert!(seen.insert(s.name), "duplicate suite {:?}", s.name);
            assert!(find(s.name).is_some());
            assert!(!s.title.is_empty() && !s.paper_ref.is_empty());
            assert!(!s.cases.is_empty());
        }
        assert_eq!(SUITES.len(), 22, "one entry per benches/*.rs target");
        assert!(find("no_such_suite").is_none());
    }

    #[test]
    fn suite_scopes_and_collects_cases() {
        let info = find("table2_pagerank").unwrap();
        let mut s = Suite::new(info);
        s.bencher.reps = 1;
        s.bencher.warmup = 0;
        s.set_scope("ds-a");
        s.bench("optimized", || {});
        s.record("q", "q", 2.5);
        s.set_scope("ds-b");
        s.bench("optimized", || {});
        let r = s.report().unwrap();
        assert_eq!(r.suite, "table2_pagerank");
        assert_eq!(r.cases.len(), 3);
        assert_eq!(r.cases[0].name, "ds-a/optimized");
        assert_eq!(r.cases[1].name, "ds-a/q");
        assert_eq!(r.cases[2].name, "ds-b/optimized");
        assert!(r.threads >= 1);
    }

    #[test]
    fn empty_or_duplicate_reports_error() {
        let info = find("table3_cf").unwrap();
        let s = Suite::new(info);
        assert!(s.report().is_err(), "empty suite must not emit");
        let mut s = Suite::new(info);
        s.bencher.reps = 1;
        s.bencher.warmup = 0;
        s.bench("optimized", || {});
        s.bench("optimized", || {});
        assert!(s.report().is_err(), "duplicate case names must error");
    }

    #[test]
    fn cap_reps_only_lowers() {
        let info = find("table3_cf").unwrap();
        let mut s = Suite::new(info);
        s.bencher.reps = 5;
        s.cap_reps(3);
        assert_eq!(s.reps(), 3);
        s.cap_reps(10);
        assert_eq!(s.reps(), 3);
        s.cap_reps(0);
        assert_eq!(s.reps(), 1);
    }
}
