//! Aligned ASCII tables for bench output, shaped like the paper's tables
//! ("Optimized Version | Our Baseline | GraphMat | ...").

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds the way the paper does ("0.29s", "14.6s").
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0001 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 0.1 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 10.0 {
        format!("{s:.3}s")
    } else {
        format!("{s:.1}s")
    }
}

/// Format a slowdown factor relative to a baseline ("(4.30×)").
pub fn fmt_factor(x: f64) -> String {
    format!("({x:.2}x)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Dataset", "Optimized", "Baseline"]);
        t.row_str(&["LiveJournal", "0.017s", "0.031s"]);
        t.row_str(&["Twitter", "0.29s", "0.97s"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(r.contains("LiveJournal"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.29), "0.290s");
        assert_eq!(fmt_secs(14.63), "14.6s");
        assert_eq!(fmt_secs(0.00005), "50.0µs");
        assert_eq!(fmt_factor(4.304), "(4.30x)");
    }
}
