//! In-repo benchmark harness (no `criterion` in the offline mirror).
//!
//! Each `rust/benches/*.rs` target (`harness = false`) registers in
//! [`suite::SUITES`] and runs through the shared [`suite`] runner, which
//! uses [`Bencher`] to time named cases with warmup + repeated
//! measurement, prints paper-style tables via [`Table`], and emits a
//! machine-readable `BENCH_<suite>.json` ([`report`]) that `cagra bench
//! diff` ([`diff`]) compares against a baseline. Benches honor
//! environment knobs:
//!
//! - `CAGRA_BENCH_SCALE` — dataset scale factor (default 1.0; smoke runs
//!   use e.g. 0.25; CI bench-smoke uses 0.05).
//! - `CAGRA_BENCH_REPS` — measurement repetitions (default 5).
//! - `CAGRA_BENCH_WARMUP` — warmup repetitions (default 1).
//! - `CAGRA_BENCH_OUT` — directory for `BENCH_*.json` (default: cwd).
//! - `CAGRA_GIT_SHA` — overrides the commit stamped into reports.

pub mod diff;
pub mod report;
pub mod suite;
pub mod table;

pub use table::Table;

use crate::util::stats::Summary;
use std::time::Instant;

/// One measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    /// Optional work units (e.g. edges) for rate reporting.
    pub work: Option<u64>,
}

impl Measurement {
    /// Median seconds.
    pub fn secs(&self) -> f64 {
        self.summary.median
    }

    /// Work units per second at the median, if work was set.
    pub fn rate(&self) -> Option<f64> {
        self.work.map(|w| w as f64 / self.summary.median)
    }
}

/// Benchmark runner with warmup and repetitions.
pub struct Bencher {
    pub reps: usize,
    pub warmup: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher {
            reps: env_usize("CAGRA_BENCH_REPS", 5),
            warmup: env_usize("CAGRA_BENCH_WARMUP", 1),
            results: Vec::new(),
        }
    }

    /// Time `f` (which runs one full iteration of the workload) and record
    /// it under `name`. Returns the measurement.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Measurement {
        self.bench_work(name, None, &mut f)
    }

    /// Like [`bench`], with a work-unit count for rate reporting.
    pub fn bench_work(
        &mut self,
        name: &str,
        work: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps.max(1) {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            summary: Summary::of(&samples),
            work,
        };
        crate::log_debug!(
            "bench {name}: median {:.6}s (±{:.6})",
            m.summary.median,
            m.summary.stddev
        );
        self.results.push(m.clone());
        m
    }

    /// Look up a recorded measurement.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }
}

/// Dataset scale factor for benches (`CAGRA_BENCH_SCALE`).
pub fn scale() -> f64 {
    std::env::var("CAGRA_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&v: &f64| v > 0.0)
        .unwrap_or(1.0)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Print the standard bench header used by every table/figure target.
pub fn header(experiment: &str, paper_ref: &str) {
    println!("==================================================================");
    println!("{experiment}");
    println!("reproduces: {paper_ref}");
    println!(
        "threads={} scale={} reps={}",
        crate::parallel::num_threads(),
        scale(),
        env_usize("CAGRA_BENCH_REPS", 5),
    );
    println!("==================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bencher {
            reps: 3,
            warmup: 1,
            results: Vec::new(),
        };
        let mut count = 0;
        let m = b.bench("t", || {
            count += 1;
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(count, 4); // 1 warmup + 3 reps
        assert_eq!(m.summary.n, 3);
        assert!(m.secs() >= 0.0005);
        assert!(b.get("t").is_some());
    }

    #[test]
    fn rate_uses_work() {
        let mut b = Bencher {
            reps: 1,
            warmup: 0,
            results: Vec::new(),
        };
        let m = b.bench_work("w", Some(1000), &mut || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let r = m.rate().unwrap();
        assert!(r > 0.0 && r < 1_000_000.0);
    }
}
