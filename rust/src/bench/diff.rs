//! Noise-aware comparison of two bench report files (`cagra bench diff`).
//!
//! A case regresses when its new median exceeds the baseline median by
//! more than the relative tolerance *plus* a noise margin derived from
//! the recorded standard deviations:
//!
//! ```text
//! regression  ⟺  new > old·(1 + tolerance) + sigma·√(old_sd² + new_sd²)
//! improvement ⟺  new < old·(1 − tolerance) − sigma·√(old_sd² + new_sd²)
//! ```
//!
//! so single-rep smoke runs (stddev 0) fall back to the pure tolerance
//! band, while noisy measurements widen their own band instead of
//! producing false alarms. Units must match (all comparisons treat a
//! larger median as worse, which holds for every unit the suites emit:
//! seconds, stall cycles, expansion factors, miss-rate error).
//!
//! Environments must match too: a suite measured at a different
//! `CAGRA_BENCH_SCALE` is a different workload, so **all** its cases are
//! flagged not-comparable instead of producing spurious 20x
//! "regressions"; a different thread count invalidates only the timing
//! (`"s"`) cases — simulated/analytic metrics are thread-independent.
//! Not-comparable cases always fail the diff (they mean the baseline
//! needs refreshing), independent of `--allow-missing`.
//!
//! Cases present in the baseline but missing from the new run are
//! treated as regressions by default — that is exactly the bench bit-rot
//! this subsystem exists to catch. New cases are informational.

use crate::bench::report::BenchFile;
use crate::bench::Table;
use crate::util::stats::quadrature;

/// Comparison knobs (`--tolerance`, `--sigma`, `--allow-missing`).
#[derive(Debug, Clone, Copy)]
pub struct DiffOptions {
    /// Relative slack on the baseline median (0.10 = +10%).
    pub tolerance: f64,
    /// Multiplier on the combined stddev added to the band.
    pub sigma: f64,
    /// Whether a baseline case absent from the new file fails the diff.
    pub fail_on_missing: bool,
}

impl Default for DiffOptions {
    fn default() -> Self {
        DiffOptions {
            tolerance: 0.10,
            sigma: 2.0,
            fail_on_missing: true,
        }
    }
}

/// Per-case outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within the tolerance + noise band.
    Within,
    /// Better than the band — report, never fail.
    Improved,
    /// Worse than the band.
    Regressed,
    /// In the baseline, absent from the new file (bench bit-rot).
    Missing,
    /// In the new file only (informational).
    New,
    /// Unit changed, or the two runs' environments (scale; threads for
    /// timing cases) differ — comparing the medians would be
    /// meaningless. Always fails the diff.
    Incomparable,
}

impl Verdict {
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Within => "ok",
            Verdict::Improved => "improved",
            Verdict::Regressed => "REGRESSED",
            Verdict::Missing => "MISSING",
            Verdict::New => "new",
            Verdict::Incomparable => "NOT COMPARABLE",
        }
    }
}

/// One compared case.
#[derive(Debug, Clone)]
pub struct CaseDelta {
    pub suite: String,
    pub name: String,
    pub unit: String,
    pub old_median: Option<f64>,
    pub new_median: Option<f64>,
    pub verdict: Verdict,
}

impl CaseDelta {
    /// new/old ratio when both sides exist and old is nonzero.
    pub fn ratio(&self) -> Option<f64> {
        match (self.old_median, self.new_median) {
            (Some(o), Some(n)) if o > 0.0 => Some(n / o),
            _ => None,
        }
    }
}

/// The full comparison result.
#[derive(Debug, Clone)]
pub struct Diff {
    pub opts: DiffOptions,
    pub deltas: Vec<CaseDelta>,
    /// Per-suite environment mismatches (scale/threads) explaining any
    /// NOT COMPARABLE verdicts; rendered above the summary.
    pub notes: Vec<String>,
}

impl Diff {
    /// Compare every baseline case against the new file, then append the
    /// new file's unmatched cases as [`Verdict::New`].
    pub fn compare(baseline: &BenchFile, new: &BenchFile, opts: DiffOptions) -> Diff {
        let mut deltas = Vec::new();
        let mut notes = Vec::new();
        for bs in &baseline.suites {
            let ns = new.suite(&bs.suite);
            let scale_mismatch = ns.is_some_and(|s| s.scale != bs.scale);
            let thread_mismatch = ns.is_some_and(|s| s.threads != bs.threads);
            if let Some(ns) = ns {
                if scale_mismatch {
                    notes.push(format!(
                        "suite {}: scale {} (baseline) vs {} (new) — no case is comparable",
                        bs.suite, bs.scale, ns.scale
                    ));
                } else if thread_mismatch {
                    notes.push(format!(
                        "suite {}: threads {} (baseline) vs {} (new) — timing cases not comparable",
                        bs.suite, bs.threads, ns.threads
                    ));
                }
            }
            for bc in &bs.cases {
                let nc = ns.and_then(|s| s.case(&bc.name));
                let delta = match nc {
                    None => CaseDelta {
                        suite: bs.suite.clone(),
                        name: bc.name.clone(),
                        unit: bc.unit.clone(),
                        old_median: Some(bc.median),
                        new_median: None,
                        verdict: Verdict::Missing,
                    },
                    Some(nc) => {
                        let env_mismatch = scale_mismatch
                            || (thread_mismatch && bc.unit == crate::bench::report::UNIT_SECS);
                        let verdict = if nc.unit != bc.unit || env_mismatch {
                            Verdict::Incomparable
                        } else {
                            let noise = opts.sigma * quadrature(bc.stddev, nc.stddev);
                            let upper = bc.median * (1.0 + opts.tolerance) + noise;
                            let lower = bc.median * (1.0 - opts.tolerance) - noise;
                            if nc.median > upper {
                                Verdict::Regressed
                            } else if nc.median < lower {
                                Verdict::Improved
                            } else {
                                Verdict::Within
                            }
                        };
                        CaseDelta {
                            suite: bs.suite.clone(),
                            name: bc.name.clone(),
                            unit: bc.unit.clone(),
                            old_median: Some(bc.median),
                            new_median: Some(nc.median),
                            verdict,
                        }
                    }
                };
                deltas.push(delta);
            }
        }
        for ns in &new.suites {
            let bs = baseline.suite(&ns.suite);
            for nc in &ns.cases {
                if bs.and_then(|s| s.case(&nc.name)).is_none() {
                    deltas.push(CaseDelta {
                        suite: ns.suite.clone(),
                        name: nc.name.clone(),
                        unit: nc.unit.clone(),
                        old_median: None,
                        new_median: Some(nc.median),
                        verdict: Verdict::New,
                    });
                }
            }
        }
        Diff {
            opts,
            deltas,
            notes,
        }
    }

    /// Cases that fail the gate under the configured options.
    /// Not-comparable cases always fail — they mean the baseline itself
    /// is stale, which `--allow-missing` must not waive.
    pub fn failures(&self) -> Vec<&CaseDelta> {
        self.deltas
            .iter()
            .filter(|d| match d.verdict {
                Verdict::Regressed | Verdict::Incomparable => true,
                Verdict::Missing => self.opts.fail_on_missing,
                _ => false,
            })
            .collect()
    }

    pub fn is_regression(&self) -> bool {
        !self.failures().is_empty()
    }

    /// Per-case delta table plus a one-line summary.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["Suite", "Case", "Baseline", "New", "Delta", "Verdict"]);
        for d in &self.deltas {
            let delta = match d.ratio() {
                Some(r) => format!("{:+.1}%", (r - 1.0) * 100.0),
                None => "-".to_string(),
            };
            t.row(&[
                d.suite.clone(),
                d.name.clone(),
                fmt_metric(d.old_median, &d.unit),
                fmt_metric(d.new_median, &d.unit),
                delta,
                d.verdict.label().to_string(),
            ]);
        }
        let count = |v: Verdict| self.deltas.iter().filter(|d| d.verdict == v).count();
        let mut out = t.render();
        for note in &self.notes {
            out.push_str(&format!("\nnote: {note}"));
        }
        if !self.notes.is_empty() {
            out.push('\n');
        }
        out.push_str(&format!(
            "\n{} case(s): {} ok, {} improved, {} regressed, {} missing, {} new, \
             {} not-comparable (tolerance {:.0}%, sigma {:.1})\n",
            self.deltas.len(),
            count(Verdict::Within),
            count(Verdict::Improved),
            count(Verdict::Regressed),
            count(Verdict::Missing),
            count(Verdict::New),
            count(Verdict::Incomparable),
            self.opts.tolerance * 100.0,
            self.opts.sigma,
        ));
        out
    }
}

fn fmt_metric(v: Option<f64>, unit: &str) -> String {
    match v {
        None => "-".to_string(),
        Some(v) if unit == crate::bench::report::UNIT_SECS => crate::bench::table::fmt_secs(v),
        Some(v) => format!("{v:.4} {unit}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::report::{BenchReport, CaseResult, UNIT_SECS};

    fn file_with(cases: Vec<CaseResult>) -> BenchFile {
        BenchFile::single(BenchReport {
            suite: "s".into(),
            git_sha: "x".into(),
            scale: 1.0,
            threads: 1,
            cases,
        })
    }

    fn timed(name: &str, median: f64, stddev: f64) -> CaseResult {
        CaseResult {
            name: name.into(),
            unit: UNIT_SECS.into(),
            reps: 5,
            median,
            mean: median,
            stddev,
            min: median - stddev,
            max: median + stddev,
            work: None,
        }
    }

    #[test]
    fn injected_slowdown_regresses() {
        let base = file_with(vec![timed("a", 0.100, 0.001)]);
        let new = file_with(vec![timed("a", 0.200, 0.001)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert!(d.is_regression());
        assert_eq!(d.deltas[0].verdict, Verdict::Regressed);
        assert!((d.deltas[0].ratio().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn within_tolerance_jitter_passes() {
        let base = file_with(vec![timed("a", 0.100, 0.001)]);
        let new = file_with(vec![timed("a", 0.105, 0.001)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert!(!d.is_regression());
        assert_eq!(d.deltas[0].verdict, Verdict::Within);
    }

    #[test]
    fn noisy_measurements_widen_the_band() {
        // +15% exceeds the 10% tolerance, but both sides carry stddev
        // 0.01 — 2σ of the combined noise covers it.
        let base = file_with(vec![timed("a", 0.100, 0.01)]);
        let new = file_with(vec![timed("a", 0.115, 0.01)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert_eq!(d.deltas[0].verdict, Verdict::Within);
        // The same +15% with tight stddev regresses.
        let base = file_with(vec![timed("a", 0.100, 0.0)]);
        let new = file_with(vec![timed("a", 0.115, 0.0)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert_eq!(d.deltas[0].verdict, Verdict::Regressed);
    }

    #[test]
    fn improvement_never_fails() {
        let base = file_with(vec![timed("a", 0.100, 0.0)]);
        let new = file_with(vec![timed("a", 0.050, 0.0)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert_eq!(d.deltas[0].verdict, Verdict::Improved);
        assert!(!d.is_regression());
    }

    #[test]
    fn missing_case_is_bit_rot() {
        let base = file_with(vec![timed("a", 0.1, 0.0), timed("b", 0.1, 0.0)]);
        let new = file_with(vec![timed("a", 0.1, 0.0)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert!(d.is_regression());
        assert_eq!(d.failures()[0].verdict, Verdict::Missing);
        let lenient = DiffOptions {
            fail_on_missing: false,
            ..Default::default()
        };
        assert!(!Diff::compare(&base, &new, lenient).is_regression());
    }

    #[test]
    fn empty_baseline_only_reports_new_cases() {
        let base = BenchFile::default();
        let new = file_with(vec![timed("a", 0.1, 0.0)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert!(!d.is_regression());
        assert_eq!(d.deltas.len(), 1);
        assert_eq!(d.deltas[0].verdict, Verdict::New);
    }

    #[test]
    fn unit_change_is_flagged_even_with_allow_missing() {
        let base = file_with(vec![timed("a", 0.1, 0.0)]);
        let new = file_with(vec![CaseResult::single("a", "GCycles", 0.1)]);
        let opts = DiffOptions {
            fail_on_missing: false,
            ..Default::default()
        };
        let d = Diff::compare(&base, &new, opts);
        assert_eq!(d.deltas[0].verdict, Verdict::Incomparable);
        assert!(d.is_regression(), "--allow-missing must not waive unit changes");
    }

    #[test]
    fn scale_mismatch_makes_every_case_incomparable() {
        let base = file_with(vec![timed("a", 0.1, 0.0), CaseResult::single("q", "q", 2.0)]);
        let mut new = file_with(vec![timed("a", 0.1, 0.0), CaseResult::single("q", "q", 2.0)]);
        new.suites[0].scale = 0.05;
        let d = Diff::compare(&base, &new, DiffOptions::default());
        assert!(d.deltas.iter().all(|c| c.verdict == Verdict::Incomparable));
        assert!(d.is_regression());
        assert_eq!(d.notes.len(), 1);
        assert!(d.render().contains("scale 1 (baseline) vs 0.05 (new)"), "{}", d.render());
    }

    #[test]
    fn thread_mismatch_only_invalidates_timing_cases() {
        let base = file_with(vec![timed("a", 0.1, 0.0), CaseResult::single("q", "q", 2.0)]);
        let mut new = file_with(vec![timed("a", 0.1, 0.0), CaseResult::single("q", "q", 2.0)]);
        new.suites[0].threads = 8;
        let d = Diff::compare(&base, &new, DiffOptions::default());
        let verdict = |name: &str| d.deltas.iter().find(|c| c.name == name).unwrap().verdict;
        assert_eq!(verdict("a"), Verdict::Incomparable, "timing case");
        assert_eq!(verdict("q"), Verdict::Within, "simulated metric is thread-independent");
        assert!(d.is_regression());
    }

    #[test]
    fn render_mentions_every_case() {
        let base = file_with(vec![timed("a", 0.1, 0.0), timed("b", 0.1, 0.0)]);
        let new = file_with(vec![timed("a", 0.3, 0.0), timed("c", 0.1, 0.0)]);
        let d = Diff::compare(&base, &new, DiffOptions::default());
        let r = d.render();
        for needle in ["REGRESSED", "MISSING", "new", "+200.0%"] {
            assert!(r.contains(needle), "render missing {needle:?}:\n{r}");
        }
    }
}
