//! `cagra audit` — the in-tree invariant checker for the unsafe /
//! concurrent core (DESIGN.md §7).
//!
//! The repo's speed story rests on invariants that ordinary tests cannot
//! see: every raw-pointer write justified, `Pod` confined to primitives,
//! the hot path allocation-free, every bench registered, every relaxed
//! store argued, every lock poison-tolerant. This module machine-enforces
//! them as seven named lints
//! over `src/`, `benches/`, and `tests/` — dependency-free (a hand-rolled
//! scanner in [`scanner`], same ethos as `util/json.rs`), so the checker
//! itself can run everywhere CI runs, including offline mirrors.
//!
//! Entry points: [`audit_tree`] (the whole crate, as CI runs it) and
//! [`audit_paths`] (explicit files/dirs, as `cagra audit src/engine`
//! runs it). Both return a [`Report`] whose diagnostics carry
//! `file:line` positions ready for terminal output.

pub mod lints;
pub mod scanner;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One finding: a named lint firing at a source position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Display path, relative to the crate root (e.g.
    /// `src/parallel/pool.rs`).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Lint name (one of [`lints::ALL_LINTS`]).
    pub lint: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.lint, self.message
        )
    }
}

/// The outcome of an audit run.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, in file order then line order.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Number of source lines carrying the `unsafe` keyword (the audited
    /// surface — reported so the clean-run output still says what was
    /// checked).
    pub unsafe_sites: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Run the per-file lints over one source text. `file` is the display
/// path used in diagnostics.
pub fn audit_source(file: &str, src: &str) -> Vec<Diagnostic> {
    let lines = scanner::scan(src);
    let mut out = Vec::new();
    lints::safety_comment(file, &lines, &mut out);
    lints::pod_allowlist(file, &lines, &mut out);
    lints::nan_sort(file, &lines, &mut out);
    lints::hot_path_alloc(file, &lines, &mut out);
    lints::relaxed_store(file, &lines, &mut out);
    lints::lock_unwrap(file, &lines, &mut out);
    out
}

/// Count the audited unsafe surface in one source text.
fn count_unsafe_sites(src: &str) -> usize {
    let kw = "unsafe";
    scanner::scan(src)
        .iter()
        .filter(|l| scanner::has_word(&l.code, kw))
        .count()
}

/// Recursively collect `.rs` files under `dir`, sorted for stable
/// output. Non-`.rs` files (fixtures, data) are skipped by design —
/// audit fixtures live under `tests/audit_fixtures/` as `.txt` precisely
/// so the tree walk never trips over its own test inputs.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Resolve the crate directory from a user-supplied root: accepts either
/// the crate dir itself (contains `src/`) or the repo root (contains
/// `rust/src/`), so `cagra audit` works from both checkout layouts.
pub fn resolve_crate_dir(root: &Path) -> Option<PathBuf> {
    if root.join("src").is_dir() {
        return Some(root.to_path_buf());
    }
    let nested = root.join("rust");
    if nested.join("src").is_dir() {
        return Some(nested);
    }
    None
}

/// Audit the whole crate at `root` (crate dir or repo root): every `.rs`
/// file under `src/`, `benches/`, `tests/`, plus the tree-level
/// bench-registry check.
pub fn audit_tree(root: &Path) -> io::Result<Report> {
    let crate_dir = resolve_crate_dir(root).ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::NotFound,
            format!("no `src/` under {} (or its `rust/` subdir)", root.display()),
        )
    })?;

    let mut files = Vec::new();
    for sub in ["src", "benches", "tests"] {
        let dir = crate_dir.join(sub);
        if dir.is_dir() {
            walk_rs(&dir, &mut files)?;
        }
    }

    let mut report = audit_files(&crate_dir, &files)?;

    // Tree-level lint 5: bench registration. Raw text on purpose — the
    // registry names are string literals, which the scanner blanks.
    let bench_dir = crate_dir.join("benches");
    if bench_dir.is_dir() {
        let mut stems: Vec<String> = Vec::new();
        for f in &files {
            if f.starts_with(&bench_dir) {
                // Only bench *targets* need registration: with
                // `harness = false` every target defines `fn main`.
                // Helper modules (`benches/common.rs`, included via
                // `mod`) don't, and are exempt.
                let src = fs::read_to_string(f).unwrap_or_default();
                if !src.contains("fn main") {
                    continue;
                }
                if let Some(stem) = f.file_stem().and_then(|s| s.to_str()) {
                    stems.push(stem.to_string());
                }
            }
        }
        let suite_src = fs::read_to_string(crate_dir.join("src/bench/suite.rs"))
            .unwrap_or_default();
        let cargo_toml =
            fs::read_to_string(crate_dir.join("Cargo.toml")).unwrap_or_default();
        lints::bench_registry(&stems, &suite_src, &cargo_toml, &mut report.diagnostics);
    }

    sort_diagnostics(&mut report.diagnostics);
    Ok(report)
}

/// Audit an explicit set of paths (files or directories). Display paths
/// in diagnostics are relative to `base` when possible.
pub fn audit_paths(base: &Path, paths: &[PathBuf]) -> io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        if p.is_dir() {
            walk_rs(p, &mut files)?;
        } else if p.is_file() {
            files.push(p.clone());
        } else {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("no such file or directory: {}", p.display()),
            ));
        }
    }
    let mut report = audit_files(base, &files)?;
    sort_diagnostics(&mut report.diagnostics);
    Ok(report)
}

/// Scan each file and run the per-file lints.
fn audit_files(base: &Path, files: &[PathBuf]) -> io::Result<Report> {
    let mut report = Report::default();
    for path in files {
        let src = fs::read_to_string(path)?;
        let display = path
            .strip_prefix(base)
            .unwrap_or(path)
            .to_string_lossy()
            .into_owned();
        report.unsafe_sites += count_unsafe_sites(&src);
        report.diagnostics.extend(audit_source(&display, &src));
        report.files_scanned += 1;
    }
    Ok(report)
}

fn sort_diagnostics(ds: &mut [Diagnostic]) {
    ds.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint).cmp(&(b.file.as_str(), b.line, b.lint))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn audit_source_runs_all_per_file_lints() {
        let k = format!("un{}", "safe");
        let src = format!(
            "fn f() {{ {k} {{ g(); }} }}\n\
             v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
             flag.store(true, Ordering::Relaxed);\n"
        );
        let ds = audit_source("multi.rs", &src);
        let lints_hit: Vec<&str> = ds.iter().map(|d| d.lint).collect();
        assert!(lints_hit.contains(&lints::SAFETY_COMMENT), "{ds:?}");
        assert!(lints_hit.contains(&lints::NAN_SORT), "{ds:?}");
        assert!(lints_hit.contains(&lints::RELAXED_STORE), "{ds:?}");
    }

    #[test]
    fn diagnostics_display_as_file_line() {
        let d = Diagnostic {
            file: "src/x.rs".to_string(),
            line: 7,
            lint: lints::NAN_SORT,
            message: "msg".to_string(),
        };
        assert_eq!(d.to_string(), "src/x.rs:7: [nan-sort] msg");
    }

    #[test]
    fn unsafe_site_count_ignores_strings_and_idents() {
        let k = format!("un{}", "safe");
        let src = format!(
            "// SAFETY: counted once\n{k} {{ g(); }}\n\
             let s = \"{k}\";\nfn {k}_helper() {{}}\n"
        );
        assert_eq!(count_unsafe_sites(&src), 1);
    }

    #[test]
    fn crate_dir_resolution() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        assert_eq!(
            resolve_crate_dir(manifest).as_deref(),
            Some(manifest),
            "crate dir resolves to itself"
        );
        if let Some(repo_root) = manifest.parent() {
            if manifest.file_name().and_then(|n| n.to_str()) == Some("rust") {
                assert_eq!(
                    resolve_crate_dir(repo_root).as_deref(),
                    Some(manifest),
                    "repo root resolves to rust/"
                );
            }
        }
    }
}
