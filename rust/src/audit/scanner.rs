//! Line-oriented Rust source scanner for the audit pass.
//!
//! The lints in this module family need exactly one thing a plain
//! line-by-line `grep` cannot give them: per line, *which characters are
//! code and which are comment or string-literal contents*. A hand-rolled
//! character state machine (same ethos as `util/json.rs` — no `syn`, no
//! proc-macro machinery, no crates) is enough, because every invariant we
//! enforce is lexical: "this token appears in code", "this marker appears
//! in a comment".
//!
//! [`scan`] splits a source file into [`Line`]s. For each line it
//! produces:
//! - `code`: the raw text with comments and string/char-literal contents
//!   blanked to spaces (so byte offsets still line up with the source),
//! - `comment`: the concatenated text of any comments on that line.
//!
//! Handled: line comments (`//`, `///`, `//!`), nested block comments,
//! normal and byte strings with escapes, raw strings `r#".."#` at any
//! hash depth, and the char-literal vs lifetime ambiguity of `'`.

/// One scanned source line.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// The unmodified source line (no trailing newline).
    pub raw: String,
    /// Code text: comments and literal contents replaced by spaces.
    pub code: String,
    /// Comment text on this line (contents after `//` / inside `/* */`),
    /// without the comment markers themselves.
    pub comment: String,
}

/// Lexer state carried across lines.
enum State {
    Code,
    /// Inside `/* ... */`; the depth supports Rust's nested block
    /// comments.
    Block(u32),
    /// Inside a normal (or byte) string literal.
    Str,
    /// Inside a raw string literal closed by `"` followed by this many
    /// `#` characters.
    RawStr(u32),
}

/// Scan `src` into per-line code/comment views.
pub fn scan(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0usize;

    // Push a char to the raw view and (blanked or not) to the code view.
    macro_rules! put {
        ($c:expr, code) => {{
            cur.raw.push($c);
            cur.code.push($c);
        }};
        ($c:expr, blank) => {{
            cur.raw.push($c);
            cur.code.push(' ');
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    // Line comment: everything to end-of-line is comment
                    // text. Skip the marker (and any further `/` or `!`
                    // doc-comment sigils) before capturing.
                    cur.raw.push_str("//");
                    cur.code.push_str("  ");
                    i += 2;
                    while matches!(chars.get(i), Some('/') | Some('!')) {
                        cur.raw.push(chars[i]);
                        cur.code.push(' ');
                        i += 1;
                    }
                    while i < chars.len() && chars[i] != '\n' {
                        cur.raw.push(chars[i]);
                        cur.code.push(' ');
                        cur.comment.push(chars[i]);
                        i += 1;
                    }
                } else if c == '/' && next == Some('*') {
                    put!('/', blank);
                    put!('*', blank);
                    i += 2;
                    state = State::Block(1);
                } else if c == '"' {
                    put!('"', code);
                    i += 1;
                    state = State::Str;
                } else if c == 'r' || c == 'b' {
                    // Possible raw/byte string prefix: r"..", r#"..."#,
                    // b"..", br#"..."#. Only treat as a prefix when the
                    // previous char is not part of an identifier (so
                    // `attr`, `ptr` etc. never misfire).
                    let prev_ident = i > 0 && is_ident_char(chars[i - 1]);
                    let (hashes, quote_at) = raw_string_lookahead(&chars, i);
                    if !prev_ident && quote_at > 0 {
                        // Emit the prefix (r/b/#s) and opening quote as
                        // code, then enter the appropriate string state.
                        for &p in &chars[i..=quote_at] {
                            put!(p, code);
                        }
                        i = quote_at + 1;
                        state = if chars[quote_at - 1] == '#'
                            || chars[quote_at - 1] == 'r'
                        {
                            State::RawStr(hashes)
                        } else {
                            State::Str // b"..": escapes apply
                        };
                    } else {
                        put!(c, code);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal or lifetime. Heuristics that cover
                    // real Rust: `'\...'` is a char; `'x'` (closing quote
                    // two ahead) is a char; anything else (`'a`, `'static`)
                    // is a lifetime and the `'` is plain code.
                    if next == Some('\\') {
                        put!('\'', code);
                        i += 1;
                        // Blank the escape until the closing quote.
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            if chars[i] == '\\' && i + 1 < chars.len() {
                                put!(chars[i], blank);
                                i += 1;
                            }
                            put!(chars[i], blank);
                            i += 1;
                        }
                        if chars.get(i) == Some(&'\'') {
                            put!('\'', code);
                            i += 1;
                        }
                    } else if next.is_some() && chars.get(i + 2) == Some(&'\'') {
                        put!('\'', code);
                        put!(next.unwrap(), blank);
                        put!('\'', code);
                        i += 3;
                    } else {
                        put!('\'', code);
                        i += 1;
                    }
                } else {
                    put!(c, code);
                    i += 1;
                }
            }
            State::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    put!('*', blank);
                    put!('/', blank);
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::Block(depth - 1)
                    };
                } else if c == '/' && next == Some('*') {
                    put!('/', blank);
                    put!('*', blank);
                    i += 2;
                    state = State::Block(depth + 1);
                } else {
                    cur.raw.push(c);
                    cur.code.push(' ');
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    put!(c, blank);
                    i += 1;
                    if let Some(&esc) = chars.get(i) {
                        if esc != '\n' {
                            put!(esc, blank);
                            i += 1;
                        }
                    }
                } else if c == '"' {
                    put!('"', code);
                    i += 1;
                    state = State::Code;
                } else {
                    put!(c, blank);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    put!('"', code);
                    i += 1;
                    for _ in 0..hashes {
                        put!('#', code);
                        i += 1;
                    }
                    state = State::Code;
                } else {
                    put!(c, blank);
                    i += 1;
                }
            }
        }
    }
    if !cur.raw.is_empty() {
        lines.push(cur);
    }
    lines
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// If `chars[at..]` starts a raw/byte string prefix (`r`, `b`, `br`,
/// `rb` plus optional `#`s then `"`), return `(hash_count,
/// index_of_opening_quote)`; otherwise `(0, 0)`.
fn raw_string_lookahead(chars: &[char], at: usize) -> (u32, usize) {
    let mut j = at;
    let mut saw_r = false;
    // Up to two prefix letters: b, r (in either order, each at most once).
    for _ in 0..2 {
        match chars.get(j) {
            Some('r') if !saw_r => {
                saw_r = true;
                j += 1;
            }
            Some('b') if j == at => {
                j += 1;
            }
            _ => break,
        }
    }
    if j == at {
        return (0, 0);
    }
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    // `b".."` (no r, no hashes) is a plain byte string — handled by the
    // caller as State::Str; raw forms require the `r`.
    if chars.get(j) == Some(&'"') && (saw_r || hashes == 0) {
        (hashes, j)
    } else {
        (0, 0)
    }
}

/// Does the `"` at `chars[at]` terminate a raw string with `hashes` `#`s?
fn closes_raw(chars: &[char], at: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if chars.get(at + 1 + k) != Some(&'#') {
            return false;
        }
    }
    true
}

/// True when `word` occurs in `code` as a standalone token (not as a
/// substring of a longer identifier). Used by the lints so that e.g. an
/// identifier containing a keyword never misfires.
pub fn has_word(code: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let before_ok = start == 0
            || !is_ident_char(code[..start].chars().next_back().unwrap());
        let after_ok =
            end >= code.len() || !is_ident_char(code[end..].chars().next().unwrap());
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comment_split() {
        let l = scan("let x = 1; // set x\n");
        assert_eq!(l.len(), 1);
        assert_eq!(l[0].code.trim_end(), "let x = 1;");
        assert_eq!(l[0].comment.trim(), "set x");
    }

    #[test]
    fn doc_comment_is_comment() {
        let l = scan("/// # Safety\n//! inner\nfn f() {}\n");
        assert_eq!(l[0].comment.trim(), "# Safety");
        assert_eq!(l[0].code.trim(), "");
        assert_eq!(l[1].comment.trim(), "inner");
        assert_eq!(l[2].code.trim(), "fn f() {}");
    }

    #[test]
    fn string_contents_blanked() {
        let l = scan("let s = \"// not a comment\"; f();\n");
        assert!(l[0].comment.is_empty());
        assert!(!l[0].code.contains("not a comment"));
        assert!(l[0].code.contains("f();"));
        // Offsets preserved: code and raw have equal length.
        assert_eq!(l[0].code.len(), l[0].raw.len());
    }

    #[test]
    fn escaped_quote_in_string() {
        let l = scan("let s = \"a\\\"b\"; g();\n");
        assert!(l[0].code.contains("g();"));
    }

    #[test]
    fn raw_string_with_hashes() {
        let l = scan("let s = r#\"has \"quotes\" and // slashes\"#; h();\n");
        assert!(l[0].comment.is_empty());
        assert!(!l[0].code.contains("slashes"));
        assert!(l[0].code.contains("h();"));
    }

    #[test]
    fn multiline_raw_string() {
        let l = scan("let s = r#\"line one\nline two\"#;\nnext();\n");
        assert_eq!(l.len(), 3);
        assert!(!l[1].code.contains("line two"));
        assert!(l[2].code.contains("next();"));
    }

    #[test]
    fn nested_block_comment() {
        let l = scan("a(); /* outer /* inner */ still */ b();\n");
        assert!(l[0].code.contains("a();"));
        assert!(l[0].code.contains("b();"));
        assert!(!l[0].code.contains("inner"));
        assert!(l[0].comment.contains("inner"));
    }

    #[test]
    fn multiline_block_comment() {
        let l = scan("x();/* one\ntwo\nthree */ y();\n");
        assert_eq!(l.len(), 3);
        assert!(l[1].comment.contains("two"));
        assert_eq!(l[1].code.trim(), "");
        assert!(l[2].code.contains("y();"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let l = scan("let c = 'x'; fn f<'a>(v: &'a str) { g('\\n'); }\n");
        let code = &l[0].code;
        assert!(code.contains("fn f<'a>"), "lifetime kept: {code}");
        assert!(!code.contains('x'), "char literal blanked: {code}");
        assert!(code.contains("g("));
    }

    #[test]
    fn identifier_not_raw_prefix() {
        // `ptr`, `attr` end in r/b but must not start a raw string.
        let l = scan("let attr = ptr; let b = \"s\";\n");
        assert!(l[0].code.contains("let attr = ptr;"));
    }

    #[test]
    fn byte_string_blanked() {
        let l = scan("let b = b\"bytes // here\"; k();\n");
        assert!(l[0].comment.is_empty());
        assert!(l[0].code.contains("k();"));
        assert!(!l[0].code.contains("here"));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe fn f()", "unsafe"));
        assert!(!has_word("fn unsafe_slice()", "unsafe"));
        assert!(!has_word("fn an_unsafe()", "unsafe"));
        assert!(has_word("(unsafe)", "unsafe"));
        assert!(!has_word("", "unsafe"));
    }
}
