//! The seven repo-specific lints (DESIGN.md §7).
//!
//! Each lint is a standalone function over one scanned file so it can be
//! unit-tested against minimal good/bad snippets. All of them work on
//! the [`Line`] views from [`super::scanner`]: token checks look only at
//! `code` (comments and string contents blanked), marker checks look
//! only at `comment` — so a string literal can never satisfy or trip a
//! lint.
//!
//! Waivers: any finding can be silenced with a justification comment
//! `// audit: allow(<lint-name>) — reason`, either on the offending line
//! or in the comment block directly above it. Waivers are for the rare
//! case where the invariant holds for a reason the scanner cannot see;
//! the reason text is mandatory in spirit (review rejects bare waivers)
//! even though the scanner only checks the marker.

use super::scanner::{has_word, Line};
use super::Diagnostic;

/// Lint names, as accepted by `audit: allow(...)` and printed in
/// diagnostics.
pub const SAFETY_COMMENT: &str = "safety-comment";
pub const POD_ALLOWLIST: &str = "pod-allowlist";
pub const NAN_SORT: &str = "nan-sort";
pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
pub const BENCH_REGISTRY: &str = "bench-registry";
pub const RELAXED_STORE: &str = "relaxed-store";
pub const LOCK_UNWRAP: &str = "lock-unwrap";

/// All lint names (for `--help`-style listings and waiver validation).
pub const ALL_LINTS: &[&str] = &[
    SAFETY_COMMENT,
    POD_ALLOWLIST,
    NAN_SORT,
    HOT_PATH_ALLOC,
    BENCH_REGISTRY,
    RELAXED_STORE,
    LOCK_UNWRAP,
];

/// `Pod` may only be implemented for these primitives: fixed-size,
/// padding-free, every bit pattern valid, and — because mapped artifacts
/// are read in place — an on-disk little-endian layout that matches the
/// in-memory one on the platforms where mmap is enabled. `usize`/`isize`
/// are deliberately absent (their width differs across targets, so a
/// mapped artifact would not be portable), as are `bool`/`char` (invalid
/// bit patterns) and all aggregates (padding).
pub const POD_ALLOWED: &[&str] = &[
    "u8", "u16", "u32", "u64", "i8", "i16", "i32", "i64", "f32", "f64",
];

/// Concatenate the comments "adjacent" to line `i`: the line's own
/// comment plus the contiguous run of comment-only lines directly above
/// it. Attribute lines (`#[...]` / `#![...]`) between the comment block
/// and the code are skipped, matching how rustc/clippy accept a comment
/// above attributes. A blank line breaks adjacency.
fn adjacent_comments(lines: &[Line], i: usize) -> String {
    let mut text = lines[i].comment.clone();
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        if code.is_empty() && !l.raw.trim().is_empty() {
            // Comment-only line (line comment, doc comment, or the
            // interior of a block comment).
            text.push('\n');
            text.push_str(&l.comment);
        } else if code.starts_with("#[") || code.starts_with("#![") {
            // Attribute between comment and item: keep walking (and keep
            // any trailing comment it carries).
            text.push('\n');
            text.push_str(&l.comment);
        } else {
            break;
        }
    }
    text
}

/// Is line `i` waived for `lint` by an `audit: allow(<lint>)` marker?
fn waived(lines: &[Line], i: usize, lint: &str) -> bool {
    let marker = format!("audit: allow({lint})");
    adjacent_comments(lines, i).contains(&marker)
}

/// Lint 1 — `safety-comment`: every line introducing an `unsafe` block,
/// fn, impl, or trait must carry an adjacent `// SAFETY:` comment (or a
/// `/// # Safety` doc section directly above, the std convention for
/// unsafe fns/traits whose contract is caller-facing).
pub fn safety_comment(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    let kw = "unsafe";
    for (i, l) in lines.iter().enumerate() {
        if !has_word(&l.code, kw) {
            continue;
        }
        if waived(lines, i, SAFETY_COMMENT) {
            continue;
        }
        let ctx = adjacent_comments(lines, i);
        if ctx.contains("SAFETY:") || ctx.contains("# Safety") {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line: i + 1,
            lint: SAFETY_COMMENT,
            message: format!(
                "`{kw}` without an adjacent `// SAFETY:` comment \
                 (or `/// # Safety` doc section)"
            ),
        });
    }
}

/// Lint 2 — `pod-allowlist`: `unsafe impl Pod for T` only for the
/// approved primitives in [`POD_ALLOWED`]. Anything else (aggregates,
/// `usize`, `bool`, …) breaks the any-bit-pattern / stable-layout
/// contract that zero-copy mapped artifacts rely on.
pub fn pod_allowlist(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    let kw = "unsafe";
    for (i, l) in lines.iter().enumerate() {
        let code = &l.code;
        if !(has_word(code, kw) && has_word(code, "impl") && has_word(code, "Pod")) {
            continue;
        }
        // `impl Pod for T` — find the type name after the `for` token
        // (joining with the next line for a wrapped impl header).
        let joined = match lines.get(i + 1) {
            Some(n) => format!("{code} {}", n.code),
            None => code.clone(),
        };
        let ty = token_after_for(&joined);
        let ty = match ty {
            Some(t) => t,
            None => continue, // not an `impl .. for ..` form
        };
        if POD_ALLOWED.contains(&ty.as_str()) {
            continue;
        }
        if waived(lines, i, POD_ALLOWLIST) {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line: i + 1,
            lint: POD_ALLOWLIST,
            message: format!(
                "`impl Pod for {ty}` — Pod is restricted to the primitive \
                 allowlist {POD_ALLOWED:?} (fixed layout, any bit pattern valid)"
            ),
        });
    }
}

/// The identifier token following the standalone `for` keyword.
fn token_after_for(code: &str) -> Option<String> {
    let mut from = 0;
    while let Some(pos) = code[from..].find("for") {
        let start = from + pos;
        let end = start + 3;
        let before_ok = start == 0
            || !code[..start]
                .chars()
                .next_back()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false);
        let after = &code[end..];
        if before_ok && after.starts_with(char::is_whitespace) {
            let tok: String = after
                .trim_start()
                .chars()
                .take_while(|&c| c.is_alphanumeric() || c == '_')
                .collect();
            if !tok.is_empty() {
                return Some(tok);
            }
        }
        from = end;
    }
    None
}

/// Lint 3 — `nan-sort`: a comparator that unwraps `partial_cmp` panics
/// on NaN. PR 6 converted four of these to `total_cmp` by hand; this
/// lint makes recurrence impossible. (Both tokens on one code line is
/// exactly the `sort_by(|a, b| a.partial_cmp(b).unwrap())` shape.)
pub fn nan_sort(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    for (i, l) in lines.iter().enumerate() {
        if !(l.code.contains("partial_cmp") && l.code.contains("unwrap")) {
            continue;
        }
        if waived(lines, i, NAN_SORT) {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line: i + 1,
            lint: NAN_SORT,
            message: "NaN-unsafe comparator: `partial_cmp(..).unwrap()` \
                      panics on NaN — use `total_cmp` (or an explicit \
                      NaN policy)"
                .to_string(),
        });
    }
}

/// Allocation / timing idioms banned inside `// audit: hot-path`
/// regions. Note `reserve`/`resize`/`push` are *allowed*: the engine's
/// high-water-mark growth discipline (scratch pools) amortizes those to
/// zero, which the counting-allocator test verifies dynamically. What
/// this lint bans are the idioms that allocate fresh storage every call.
pub const HOT_PATH_BANNED: &[&str] = &[
    "Vec::new(",
    "vec!",
    ".to_vec(",
    ".collect(",
    "format!",
    "Box::new(",
    "String::new(",
    ".to_string(",
    ".to_owned(",
    "Instant::now(",
];

/// Lint 4 — `hot-path-alloc`: no per-call allocation (or `Instant::now`
/// timing) inside regions bracketed by `// audit: hot-path` …
/// `// audit: hot-path-end` comments. The zero-alloc invariant enforced
/// at the source level, complementing the counting-allocator test which
/// only sees the code paths a given input exercises.
pub fn hot_path_alloc(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    let mut region_start: Option<usize> = None;
    for (i, l) in lines.iter().enumerate() {
        // Check the end marker first: "hot-path" is a prefix of
        // "hot-path-end".
        if l.comment.contains("audit: hot-path-end") {
            region_start = None;
            continue;
        }
        if l.comment.contains("audit: hot-path") {
            region_start = Some(i);
            continue;
        }
        if region_start.is_none() {
            continue;
        }
        for needle in HOT_PATH_BANNED {
            if !l.code.contains(needle) {
                continue;
            }
            if waived(lines, i, HOT_PATH_ALLOC) {
                continue;
            }
            out.push(Diagnostic {
                file: file.to_string(),
                line: i + 1,
                lint: HOT_PATH_ALLOC,
                message: format!(
                    "`{}` inside an `// audit: hot-path` region — the hot \
                     path must not allocate per call (pool/reuse instead)",
                    needle.trim_end_matches('(')
                ),
            });
        }
    }
    if let Some(start) = region_start {
        out.push(Diagnostic {
            file: file.to_string(),
            line: start + 1,
            lint: HOT_PATH_ALLOC,
            message: "unclosed `// audit: hot-path` region (missing \
                      `// audit: hot-path-end`)"
                .to_string(),
        });
    }
}

/// Lint 6 — `relaxed-store`: a `.store(.., Relaxed)` on shared state is
/// correct only when the flag carries no data dependency (idempotent
/// one-way flags, counters read after a join, …). Each one must say why
/// via an adjacent `// audit: relaxed-ok — reason` comment.
pub fn relaxed_store(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    for (i, l) in lines.iter().enumerate() {
        if !(l.code.contains(".store(") && has_word(&l.code, "Relaxed")) {
            continue;
        }
        if waived(lines, i, RELAXED_STORE) {
            continue;
        }
        if adjacent_comments(lines, i).contains("audit: relaxed-ok") {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line: i + 1,
            lint: RELAXED_STORE,
            message: "`Ordering::Relaxed` store without an \
                      `// audit: relaxed-ok` justification"
                .to_string(),
        });
    }
}

/// Lint 7 — `lock-unwrap`: `.lock().unwrap()` panics exactly when a
/// panic *already* happened somewhere else (the mutex is poisoned),
/// turning one contained fault into a cascade across every thread that
/// touches the lock. Production code must use the poison-tolerant idiom
/// the worker pool hand-rolls — `.unwrap_or_else(|p| p.into_inner())` —
/// or justify itself with an adjacent `// audit: lock-ok — reason`
/// comment. Everything from a `#[cfg(test)]` attribute down is exempt
/// (test modules sit at file bottoms by convention, and a test *wants*
/// poison to propagate as a failure).
///
/// Both tokens must sit on one code line — the repo writes the chain
/// unwrapped, same single-line assumption as `nan-sort`.
pub fn lock_unwrap(file: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    let mut in_tests = false;
    for (i, l) in lines.iter().enumerate() {
        if l.code.contains("#[cfg(test)]") {
            in_tests = true;
        }
        if in_tests {
            continue;
        }
        if !(l.code.contains(".lock()") && l.code.contains(".unwrap()")) {
            continue;
        }
        if waived(lines, i, LOCK_UNWRAP) {
            continue;
        }
        if adjacent_comments(lines, i).contains("audit: lock-ok") {
            continue;
        }
        out.push(Diagnostic {
            file: file.to_string(),
            line: i + 1,
            lint: LOCK_UNWRAP,
            message: "`.lock().unwrap()` cascades on a poisoned mutex — \
                      use `.unwrap_or_else(|p| p.into_inner())` (poison-\
                      tolerant) or justify with `// audit: lock-ok`"
                .to_string(),
        });
    }
}

/// Lint 5 — `bench-registry`: every `benches/*.rs` stem must appear both
/// in `bench/suite.rs` (`name: "<stem>"`) and in `Cargo.toml`
/// (`name = "<stem>"`, with `harness = false`). Operates on raw text —
/// the registry strings live in string literals, which the scanner
/// blanks — so it runs at tree level, not through the per-file scanner.
pub fn bench_registry(
    bench_stems: &[String],
    suite_src: &str,
    cargo_toml: &str,
    out: &mut Vec<Diagnostic>,
) {
    for stem in bench_stems {
        let in_suite = suite_src.contains(&format!("name: \"{stem}\""));
        let in_cargo = cargo_toml.contains(&format!("name = \"{stem}\""));
        if in_suite && in_cargo {
            continue;
        }
        let mut missing = Vec::new();
        if !in_suite {
            missing.push("bench/suite.rs SUITES");
        }
        if !in_cargo {
            missing.push("Cargo.toml [[bench]]");
        }
        out.push(Diagnostic {
            file: format!("benches/{stem}.rs"),
            line: 1,
            lint: BENCH_REGISTRY,
            message: format!(
                "bench suite `{stem}` not registered in {} — unregistered \
                 benches silently drop out of CI's run-every-suite job",
                missing.join(" and ")
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::scanner::scan;

    fn run(
        lint: fn(&str, &[Line], &mut Vec<Diagnostic>),
        src: &str,
    ) -> Vec<Diagnostic> {
        let lines = scan(src);
        let mut out = Vec::new();
        lint("test.rs", &lines, &mut out);
        out
    }

    // The keyword under test, built so this file's own code never
    // contains it as a bare token.
    fn kw_unsafe() -> String {
        format!("un{}", "safe")
    }

    #[test]
    fn safety_comment_fires_and_clears() {
        let k = kw_unsafe();
        let bad = format!("fn f() {{ {k} {{ g(); }} }}\n");
        let d = run(safety_comment, &bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].lint, SAFETY_COMMENT);
        assert_eq!(d[0].line, 1);

        let good = format!("// SAFETY: g is fine\nfn f() {{ {k} {{ g(); }} }}\n");
        assert!(run(safety_comment, &good).is_empty());

        let same_line = format!("fn f() {{ {k} {{ g(); }} }} // SAFETY: g is fine\n");
        assert!(run(safety_comment, &same_line).is_empty());

        // `/// # Safety` doc section above an unsafe fn counts, including
        // through an intervening attribute and further doc text.
        let doc = format!(
            "/// Does things.\n///\n/// # Safety\n/// Caller checks i.\n\
             #[inline]\npub {k} fn w(i: usize) {{}}\n"
        );
        assert!(run(safety_comment, &doc).is_empty());
    }

    #[test]
    fn safety_comment_not_fooled_by_strings_or_idents() {
        let k = kw_unsafe();
        // Keyword inside a string literal or an identifier: no finding.
        let src = format!("let s = \"{k} code\";\nfn {k}_slice_writes() {{}}\n");
        assert!(run(safety_comment, &src).is_empty());
        // A SAFETY: *string* must not satisfy the lint either.
        let sneaky = format!("let s = \"SAFETY: nope\"; {k} {{ g(); }}\n");
        assert_eq!(run(safety_comment, &sneaky).len(), 1);
    }

    #[test]
    fn safety_comment_waiver() {
        let k = kw_unsafe();
        let src = format!(
            "// audit: allow(safety-comment) — fixture exercising waivers\n\
             fn f() {{ {k} {{ g(); }} }}\n"
        );
        assert!(run(safety_comment, &src).is_empty());
    }

    #[test]
    fn pod_allowlist_fires_and_clears() {
        let k = kw_unsafe();
        let bad = format!("// SAFETY: wrong\n{k} impl Pod for MyStruct {{}}\n");
        let d = run(pod_allowlist, &bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].lint, POD_ALLOWLIST);
        assert!(d[0].message.contains("MyStruct"));

        // usize is NOT allowed: width varies across targets.
        let usz = format!("{k} impl Pod for usize {{}}\n");
        assert_eq!(run(pod_allowlist, &usz).len(), 1);

        let good = format!("{k} impl Pod for u32 {{}}\n");
        assert!(run(pod_allowlist, &good).is_empty());

        // Wrapped impl header: type on the next line.
        let wrapped = format!("{k} impl Pod\n    for u64 {{}}\n");
        assert!(run(pod_allowlist, &wrapped).is_empty());
    }

    #[test]
    fn nan_sort_fires_and_clears() {
        let bad = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        let d = run(nan_sort, bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].lint, NAN_SORT);

        let good = "v.sort_by(|a, b| a.total_cmp(b));\n";
        assert!(run(nan_sort, good).is_empty());

        // Mentioning the idiom in a comment or string is fine.
        let comment = "// partial_cmp(..).unwrap() is banned\nf();\n";
        assert!(run(nan_sort, comment).is_empty());
    }

    #[test]
    fn hot_path_alloc_fires_and_clears() {
        let bad = "// audit: hot-path\nlet v: Vec<u32> = xs.iter().collect();\n\
                   // audit: hot-path-end\n";
        let d = run(hot_path_alloc, bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].lint, HOT_PATH_ALLOC);
        assert_eq!(d[0].line, 2);

        // Outside the region the same code is fine.
        let outside = "let v: Vec<u32> = xs.iter().collect();\n\
                       // audit: hot-path\nf(x);\n// audit: hot-path-end\n";
        assert!(run(hot_path_alloc, outside).is_empty());

        // High-water growth is allowed inside.
        let growth = "// audit: hot-path\nbuf.resize(n, 0); buf.push(x); \
                      buf.reserve(n);\n// audit: hot-path-end\n";
        assert!(run(hot_path_alloc, growth).is_empty());

        // Unclosed region is itself a finding.
        let unclosed = "// audit: hot-path\nf(x);\n";
        let d = run(hot_path_alloc, unclosed);
        assert_eq!(d.len(), 1);
        assert!(d[0].message.contains("unclosed"));
    }

    #[test]
    fn relaxed_store_fires_and_clears() {
        let bad = "flag.store(true, Ordering::Relaxed);\n";
        let d = run(relaxed_store, bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].lint, RELAXED_STORE);

        let good = "// audit: relaxed-ok — idempotent one-way flag\n\
                    flag.store(true, Ordering::Relaxed);\n";
        assert!(run(relaxed_store, good).is_empty());

        // Loads and non-Relaxed stores are out of scope.
        let load = "let v = flag.load(Ordering::Relaxed);\n\
                    flag.store(true, Ordering::Release);\n";
        assert!(run(relaxed_store, load).is_empty());
    }

    #[test]
    fn lock_unwrap_fires_and_clears() {
        let bad = "let st = self.state.lock().unwrap();\n";
        let d = run(lock_unwrap, bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].lint, LOCK_UNWRAP);
        assert_eq!(d[0].line, 1);

        let good = "let st = self.state.lock().unwrap_or_else(|p| p.into_inner());\n";
        assert!(run(lock_unwrap, good).is_empty());

        // Justified single-site exception.
        let justified = "// audit: lock-ok — held only by this thread\n\
                         let st = self.state.lock().unwrap();\n";
        assert!(run(lock_unwrap, justified).is_empty());

        // The standard waiver marker works too.
        let waived = "// audit: allow(lock-unwrap) — fixture\n\
                      let st = self.state.lock().unwrap();\n";
        assert!(run(lock_unwrap, waived).is_empty());

        // Everything below #[cfg(test)] is exempt.
        let test_mod = "fn prod() {}\n#[cfg(test)]\nmod tests {\n\
                        fn t() { q.lock().unwrap(); }\n}\n";
        assert!(run(lock_unwrap, test_mod).is_empty());

        // ...but production code above the test module still fires.
        let above = "fn prod() { q.lock().unwrap(); }\n#[cfg(test)]\nmod tests {}\n";
        assert_eq!(run(lock_unwrap, above).len(), 1);

        // Unrelated unwraps (no lock on the line) are out of scope.
        let unrelated = "let v = opt.unwrap();\nlet g = m.lock();\n";
        assert!(run(lock_unwrap, unrelated).is_empty());
    }

    #[test]
    fn bench_registry_fires_and_clears() {
        let stems = vec!["fig1_overview".to_string(), "orphan".to_string()];
        let suite = "Suite { name: \"fig1_overview\", .. }";
        let cargo = "[[bench]]\nname = \"fig1_overview\"\nharness = false\n";
        let mut out = Vec::new();
        bench_registry(&stems, suite, cargo, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].lint, BENCH_REGISTRY);
        assert!(out[0].file.contains("orphan"));
        assert!(out[0].message.contains("suite.rs"));
        assert!(out[0].message.contains("Cargo.toml"));
    }
}
