//! Closed-loop load generator for `cagra serve` (`cagra loadgen`): N
//! client threads each hold one TCP connection and issue M requests
//! back-to-back (a new request the moment the previous response lands —
//! the closed-loop model, so offered load tracks service capacity).
//!
//! Every response is strictly validated (parses, `ok:true`, echoed id
//! matches, finite summary); the report aggregates throughput and
//! latency percentiles — the jobs/sec and p50/p99 numbers the
//! `serve_throughput` bench records for cold vs resident stores.

use crate::util::json::{parse, Value};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Load-generation parameters (the `cagra loadgen` flag surface).
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    /// Daemon address (`host:port`).
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    /// The `op:"run"` request body sent by every client; `id` is
    /// injected per request (`c<client>-r<request>`).
    pub request: Value,
    /// Send `{"op":"shutdown"}` after the measurement (one extra
    /// connection), so a scripted run tears the daemon down.
    pub shutdown_after: bool,
}

/// Aggregated closed-loop results.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadgenReport {
    pub clients: usize,
    pub completed: usize,
    pub elapsed_s: f64,
    pub jobs_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        format!(
            "loadgen: {} request(s) over {} client(s) in {:.3}s\n\
             \x20 throughput: {:.2} jobs/s\n\
             \x20 latency:    p50 {:.2}ms  p99 {:.2}ms\n",
            self.completed, self.clients, self.elapsed_s, self.jobs_per_sec, self.p50_ms, self.p99_ms
        )
    }
}

/// Run the closed loop. Any protocol violation or error response fails
/// the whole run — a load test that silently drops errors measures a
/// different server than the one you have.
pub fn run(opts: &LoadgenOpts) -> Result<LoadgenReport> {
    if opts.clients == 0 || opts.requests == 0 {
        bail!("loadgen needs at least one client and one request");
    }
    let started = Instant::now();
    let latencies = std::thread::scope(|scope| -> Result<Vec<f64>> {
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| scope.spawn(move || client_loop(c, opts)))
            .collect();
        let mut all = Vec::with_capacity(opts.clients * opts.requests);
        for h in handles {
            all.extend(h.join().expect("client thread panicked")?);
        }
        Ok(all)
    })?;
    let elapsed_s = started.elapsed().as_secs_f64();
    if opts.shutdown_after {
        shutdown(&opts.addr)?;
    }
    let mut sorted = latencies.clone();
    sorted.sort_by(f64::total_cmp);
    Ok(LoadgenReport {
        clients: opts.clients,
        completed: latencies.len(),
        elapsed_s,
        jobs_per_sec: latencies.len() as f64 / elapsed_s.max(1e-9),
        p50_ms: percentile(&sorted, 50.0) * 1e3,
        p99_ms: percentile(&sorted, 99.0) * 1e3,
    })
}

/// Nearest-rank percentile of an ascending slice (seconds in, seconds
/// out). Empty input yields 0.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn client_loop(client: usize, opts: &LoadgenOpts) -> Result<Vec<f64>> {
    let stream = TcpStream::connect(&opts.addr)
        .with_context(|| format!("client {client}: connecting {}", opts.addr))?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    let mut latencies = Vec::with_capacity(opts.requests);
    for i in 0..opts.requests {
        let id = format!("c{client}-r{i}");
        let line = with_id(&opts.request, &id).render_compact();
        let t0 = Instant::now();
        writer
            .write_all(format!("{line}\n").as_bytes())
            .and_then(|()| writer.flush())
            .with_context(|| format!("client {client}: sending request {i}"))?;
        let mut reply = String::new();
        let n = reader
            .read_line(&mut reply)
            .with_context(|| format!("client {client}: reading response {i}"))?;
        if n == 0 {
            bail!("client {client}: server closed the connection at request {i}");
        }
        latencies.push(t0.elapsed().as_secs_f64());
        validate(&reply, &id).with_context(|| format!("client {client} request {i}"))?;
    }
    Ok(latencies)
}

/// Copy the request template with `id` set (replacing any existing id).
fn with_id(template: &Value, id: &str) -> Value {
    let mut fields = match template {
        Value::Obj(f) => f.clone(),
        other => vec![("op".to_string(), other.clone())],
    };
    fields.retain(|(k, _)| k != "id");
    fields.push(("id".to_string(), Value::Str(id.to_string())));
    Value::Obj(fields)
}

/// Strict response validation: parses, `ok:true`, id echoed, summary
/// finite.
fn validate(reply: &str, id: &str) -> Result<()> {
    let v = parse(reply.trim()).context("response is not valid JSON")?;
    if v.get("ok") != Some(&Value::Bool(true)) {
        bail!(
            "error response: {} — {}",
            v.get("error").and_then(Value::as_str).unwrap_or("?"),
            v.get("message").and_then(Value::as_str).unwrap_or("?")
        );
    }
    match v.get("id").and_then(Value::as_str) {
        Some(got) if got == id => {}
        other => bail!("response id {other:?} does not echo request id {id:?}"),
    }
    match v.get("summary").and_then(Value::as_f64) {
        Some(s) if s.is_finite() => Ok(()),
        other => bail!("response summary {other:?} is missing or non-finite"),
    }
}

/// Send one shutdown request and wait for the ack.
pub fn shutdown(addr: &str) -> Result<()> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"op\":\"shutdown\"}\n")
        .and_then(|()| writer.flush())
        .context("sending shutdown")?;
    let mut reply = String::new();
    reader.read_line(&mut reply).context("reading shutdown ack")?;
    let v = parse(reply.trim()).context("shutdown ack is not valid JSON")?;
    if v.get("ok") != Some(&Value::Bool(true)) {
        bail!("shutdown rejected: {reply}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn with_id_replaces_existing() {
        let t = Value::Obj(vec![
            ("op".to_string(), Value::Str("run".to_string())),
            ("id".to_string(), Value::Num(1.0)),
        ]);
        let v = with_id(&t, "c0-r0");
        assert_eq!(v.get("id").and_then(Value::as_str), Some("c0-r0"));
        let Value::Obj(fields) = &v else { panic!() };
        assert_eq!(fields.iter().filter(|(k, _)| k == "id").count(), 1);
    }

    #[test]
    fn validation_is_strict() {
        assert!(validate(r#"{"ok":true,"id":"a","summary":1.5}"#, "a").is_ok());
        for (reply, id) in [
            ("not json", "a"),
            (r#"{"ok":false,"id":"a","error":"failed","message":"x"}"#, "a"),
            (r#"{"ok":true,"id":"b","summary":1.5}"#, "a"),
            (r#"{"ok":true,"id":"a"}"#, "a"),
            (r#"{"ok":true,"id":"a","summary":null}"#, "a"),
        ] {
            assert!(validate(reply, id).is_err(), "accepted {reply:?}");
        }
    }
}
