//! Closed-loop load generator for `cagra serve` (`cagra loadgen`): N
//! client threads each hold one TCP connection and issue M requests
//! back-to-back (a new request the moment the previous response lands —
//! the closed-loop model, so offered load tracks service capacity).
//!
//! Every response is strictly validated (parses, `ok:true`, echoed id
//! matches, finite summary); the report aggregates throughput and
//! latency percentiles — the jobs/sec and p50/p99 numbers the
//! `serve_throughput` bench records for cold vs resident stores.
//!
//! Transient refusals (`overloaded`, `deadline`) are resubmitted with
//! seeded, jittered exponential backoff up to `retry_max` times — the
//! well-behaved-client model for an admission-controlled server — and
//! counted in the report. With `allow_failures` (chaos runs), `failed`
//! replies are counted instead of aborting the whole measurement.

use crate::util::json::{parse, Value};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Load-generation parameters (the `cagra loadgen` flag surface).
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    /// Daemon address (`host:port`).
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    /// The `op:"run"` request body sent by every client; `id` is
    /// injected per request (`c<client>-r<request>`).
    pub request: Value,
    /// Send `{"op":"shutdown"}` after the measurement (one extra
    /// connection), so a scripted run tears the daemon down.
    pub shutdown_after: bool,
    /// Resubmissions allowed per request after an `overloaded` or
    /// `deadline` refusal (0 = fail on the first refusal).
    pub retry_max: usize,
    /// Base backoff before the first resubmission; doubles per attempt
    /// with jitter, capped at 1s.
    pub retry_base_ms: u64,
    /// Seed for the backoff jitter (per-client streams are derived from
    /// it, so a rerun backs off identically).
    pub seed: u64,
    /// Tolerate `failed` error replies: count them instead of aborting.
    /// For chaos runs, where injected faults *should* fail some jobs —
    /// a clean-path measurement keeps the strict default.
    pub allow_failures: bool,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            addr: "127.0.0.1:7421".to_string(),
            clients: 4,
            requests: 16,
            request: Value::Null,
            shutdown_after: false,
            retry_max: 3,
            retry_base_ms: 10,
            seed: 0x10AD,
            allow_failures: false,
        }
    }
}

/// Aggregated closed-loop results.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadgenReport {
    pub clients: usize,
    pub completed: usize,
    /// `overloaded`/`deadline` refusals that were resubmitted.
    pub retries: u64,
    /// `failed` replies tolerated under `allow_failures`.
    pub failed: u64,
    pub elapsed_s: f64,
    pub jobs_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        format!(
            "loadgen: {} request(s) over {} client(s) in {:.3}s\n\
             \x20 throughput: {:.2} jobs/s\n\
             \x20 latency:    p50 {:.2}ms  p99 {:.2}ms\n\
             \x20 resilience: {} retried refusal(s), {} tolerated failure(s)\n",
            self.completed,
            self.clients,
            self.elapsed_s,
            self.jobs_per_sec,
            self.p50_ms,
            self.p99_ms,
            self.retries,
            self.failed
        )
    }
}

struct ClientResult {
    latencies: Vec<f64>,
    retries: u64,
    failed: u64,
}

/// Run the closed loop. Any protocol violation — and, unless
/// `allow_failures` is set, any non-retryable error response — fails
/// the whole run: a load test that silently drops errors measures a
/// different server than the one you have.
pub fn run(opts: &LoadgenOpts) -> Result<LoadgenReport> {
    if opts.clients == 0 || opts.requests == 0 {
        bail!("loadgen needs at least one client and one request");
    }
    let started = Instant::now();
    let results = std::thread::scope(|scope| -> Result<Vec<ClientResult>> {
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| scope.spawn(move || client_loop(c, opts)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect()
    })?;
    let elapsed_s = started.elapsed().as_secs_f64();
    if opts.shutdown_after {
        shutdown(&opts.addr)?;
    }
    let latencies: Vec<f64> = results.iter().flat_map(|r| r.latencies.iter().copied()).collect();
    let mut sorted = latencies.clone();
    sorted.sort_by(f64::total_cmp);
    Ok(LoadgenReport {
        clients: opts.clients,
        completed: latencies.len(),
        retries: results.iter().map(|r| r.retries).sum(),
        failed: results.iter().map(|r| r.failed).sum(),
        elapsed_s,
        jobs_per_sec: latencies.len() as f64 / elapsed_s.max(1e-9),
        p50_ms: percentile(&sorted, 50.0) * 1e3,
        p99_ms: percentile(&sorted, 99.0) * 1e3,
    })
}

/// Nearest-rank percentile of an ascending slice (seconds in, seconds
/// out). Empty input yields 0.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn client_loop(client: usize, opts: &LoadgenOpts) -> Result<ClientResult> {
    let stream = TcpStream::connect(&opts.addr)
        .with_context(|| format!("client {client}: connecting {}", opts.addr))?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    // Per-client jitter stream: distinct per client, reproducible per
    // (seed, client) so a rerun of a chaos test backs off identically.
    let mut rng = Rng::new(opts.seed ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut res = ClientResult {
        latencies: Vec::with_capacity(opts.requests),
        retries: 0,
        failed: 0,
    };
    for i in 0..opts.requests {
        let id = format!("c{client}-r{i}");
        let line = with_id(&opts.request, &id).render_compact();
        let t0 = Instant::now();
        let mut attempt = 0usize;
        loop {
            writer
                .write_all(format!("{line}\n").as_bytes())
                .and_then(|()| writer.flush())
                .with_context(|| format!("client {client}: sending request {i}"))?;
            let mut reply = String::new();
            let n = reader
                .read_line(&mut reply)
                .with_context(|| format!("client {client}: reading response {i}"))?;
            if n == 0 {
                bail!("client {client}: server closed the connection at request {i}");
            }
            match classify(&reply, &id).with_context(|| format!("client {client} request {i}"))? {
                Reply::Ok => {
                    // Client-perceived latency: includes any backoff.
                    res.latencies.push(t0.elapsed().as_secs_f64());
                    break;
                }
                Reply::Retryable(kind) => {
                    if attempt >= opts.retry_max {
                        bail!(
                            "client {client} request {i}: still {kind} after {attempt} resubmission(s)"
                        );
                    }
                    attempt += 1;
                    res.retries += 1;
                    std::thread::sleep(Duration::from_millis(backoff_ms(
                        opts.retry_base_ms,
                        attempt,
                        &mut rng,
                    )));
                }
                Reply::Failed(msg) => {
                    if !opts.allow_failures {
                        bail!("client {client} request {i}: {msg}");
                    }
                    res.failed += 1;
                    break;
                }
            }
        }
    }
    Ok(res)
}

/// Jittered exponential backoff: `base * 2^(attempt-1)`, scaled by a
/// uniform factor in [0.5, 1.0] and capped at 1s (equal-jitter keeps a
/// floor so colliding clients still spread out).
fn backoff_ms(base_ms: u64, attempt: usize, rng: &mut Rng) -> u64 {
    let exp = base_ms.max(1).saturating_mul(1u64 << (attempt - 1).min(10)) as f64;
    let jittered = exp * (0.5 + 0.5 * rng.next_f64());
    jittered.clamp(1.0, 1000.0) as u64
}

/// Copy the request template with `id` set (replacing any existing id).
fn with_id(template: &Value, id: &str) -> Value {
    let mut fields = match template {
        Value::Obj(f) => f.clone(),
        other => vec![("op".to_string(), other.clone())],
    };
    fields.retain(|(k, _)| k != "id");
    fields.push(("id".to_string(), Value::Str(id.to_string())));
    Value::Obj(fields)
}

/// What one response line means for the closed loop.
#[derive(Debug, PartialEq)]
enum Reply {
    /// `ok:true`, id echoed, finite summary.
    Ok,
    /// A refusal worth resubmitting (`overloaded` / `deadline`).
    Retryable(&'static str),
    /// Any other error reply (fatal unless `allow_failures`).
    Failed(String),
}

/// Strict response triage: a protocol violation (unparseable line, bad
/// id echo, missing summary) is always an `Err` — never retried, never
/// tolerated — while well-formed error replies become [`Reply`] data.
fn classify(reply: &str, id: &str) -> Result<Reply> {
    let v = parse(reply.trim()).context("response is not valid JSON")?;
    if v.get("ok") != Some(&Value::Bool(true)) {
        let kind = v.get("error").and_then(Value::as_str).unwrap_or("?");
        let msg = v.get("message").and_then(Value::as_str).unwrap_or("?");
        return Ok(match kind {
            "overloaded" => Reply::Retryable("overloaded"),
            "deadline" => Reply::Retryable("deadline"),
            _ => Reply::Failed(format!("error response: {kind} — {msg}")),
        });
    }
    match v.get("id").and_then(Value::as_str) {
        Some(got) if got == id => {}
        other => bail!("response id {other:?} does not echo request id {id:?}"),
    }
    match v.get("summary").and_then(Value::as_f64) {
        Some(s) if s.is_finite() => Ok(Reply::Ok),
        other => bail!("response summary {other:?} is missing or non-finite"),
    }
}

/// Send one shutdown request and wait for the ack.
pub fn shutdown(addr: &str) -> Result<()> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"op\":\"shutdown\"}\n")
        .and_then(|()| writer.flush())
        .context("sending shutdown")?;
    let mut reply = String::new();
    reader.read_line(&mut reply).context("reading shutdown ack")?;
    let v = parse(reply.trim()).context("shutdown ack is not valid JSON")?;
    if v.get("ok") != Some(&Value::Bool(true)) {
        bail!("shutdown rejected: {reply}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn with_id_replaces_existing() {
        let t = Value::Obj(vec![
            ("op".to_string(), Value::Str("run".to_string())),
            ("id".to_string(), Value::Num(1.0)),
        ]);
        let v = with_id(&t, "c0-r0");
        assert_eq!(v.get("id").and_then(Value::as_str), Some("c0-r0"));
        let Value::Obj(fields) = &v else { panic!() };
        assert_eq!(fields.iter().filter(|(k, _)| k == "id").count(), 1);
    }

    #[test]
    fn classification_is_strict() {
        assert_eq!(
            classify(r#"{"ok":true,"id":"a","summary":1.5}"#, "a").unwrap(),
            Reply::Ok
        );
        // Protocol violations are errors, never data.
        for (reply, id) in [
            ("not json", "a"),
            (r#"{"ok":true,"id":"b","summary":1.5}"#, "a"),
            (r#"{"ok":true,"id":"a"}"#, "a"),
            (r#"{"ok":true,"id":"a","summary":null}"#, "a"),
        ] {
            assert!(classify(reply, id).is_err(), "accepted {reply:?}");
        }
        // Refusals retry; real failures don't.
        assert_eq!(
            classify(r#"{"ok":false,"id":"a","error":"overloaded","message":"q"}"#, "a").unwrap(),
            Reply::Retryable("overloaded")
        );
        assert_eq!(
            classify(r#"{"ok":false,"id":"a","error":"deadline","message":"d"}"#, "a").unwrap(),
            Reply::Retryable("deadline")
        );
        assert!(matches!(
            classify(r#"{"ok":false,"id":"a","error":"failed","message":"x"}"#, "a").unwrap(),
            Reply::Failed(_)
        ));
    }

    #[test]
    fn backoff_is_seeded_bounded_and_grows() {
        let seq = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            (1..=8).map(|a| backoff_ms(10, a, &mut rng)).collect()
        };
        assert_eq!(seq(7), seq(7), "same seed, same backoffs");
        let s = seq(7);
        for (i, &ms) in s.iter().enumerate() {
            assert!((1..=1000).contains(&ms), "attempt {}: {ms}ms", i + 1);
            // Equal-jitter floor: attempt k waits at least base*2^(k-1)/2.
            let floor = (10u64 << i.min(10)) / 2;
            assert!(ms >= floor.min(1000), "attempt {}: {ms}ms < floor {floor}", i + 1);
        }
        assert!(s[7] > s[0], "backoff must grow across attempts");
    }
}
