//! `cagra serve` — the resident graph-analytics daemon.
//!
//! The batch driver already shares one disk [`crate::store::ArtifactStore`]
//! across jobs; this subsystem makes the process itself long-lived so the
//! *decoded* artifacts stay resident too (ROADMAP serving north star):
//!
//! - [`worker`]: a pool of N job-execution threads over one shared
//!   [`crate::coordinator::JobEnv`] — disk store + in-memory artifact
//!   layer ([`crate::store::MemStore`]) — with bounded admission, per-
//!   request deadlines, and graceful drain. A warm resident request does
//!   zero CSR decode and the engines' steady state allocates nothing.
//! - [`protocol`]: newline-delimited JSON requests/responses (the
//!   `cagra batch` JobSpec surface plus `id` and `deadline_ms`).
//! - [`daemon`]: the TCP/stdio transport (`cagra serve`).
//! - [`loadgen`]: the closed-loop measurement client (`cagra loadgen`),
//!   also driven by the `serve_throughput` bench suite.
//!
//! Fault containment (DESIGN.md §8): job panics are caught and become
//! `failed` replies, dead worker threads are respawned by a supervisor,
//! connections are bounded (`max_conns`) and idle-timed-out, and the
//! disk store quarantines + rebuilds corrupt artifacts. All of it is
//! exercised deterministically through [`crate::fault`] failpoints.

pub mod daemon;
pub mod loadgen;
pub mod protocol;
pub mod worker;

pub use daemon::{serve, ServeOpts};
pub use loadgen::{LoadgenOpts, LoadgenReport};
pub use protocol::{parse_request, ErrorKind, Request, StatsSnapshot};
pub use worker::{Outcome, SubmitError, WorkerPool};
