//! The resident daemon: accepts newline-delimited JSON requests over TCP
//! (one handler thread per connection, responses in request order per
//! connection) or stdio (`--stdio`: one request per stdin line, replies
//! on stdout — the embedding/pipe mode), and executes them on a shared
//! [`WorkerPool`].
//!
//! Shutdown is graceful end-to-end: an `{"op":"shutdown"}` request (or
//! stdin EOF in stdio mode) is acknowledged, the listener stops
//! accepting, open connections finish their in-flight request streams,
//! and the pool drains every admitted job before the process returns.

use super::protocol::{self, ErrorKind, Request, StatsSnapshot};
use super::worker::{Outcome, SubmitError, WorkerPool};
use crate::coordinator::SystemConfig;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, ErrorKind as IoErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Daemon configuration (the `cagra serve` flag surface).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// TCP bind address; port 0 picks a free port (see `port_file`).
    pub addr: String,
    pub workers: usize,
    /// Admission-queue bound (jobs waiting beyond the busy workers).
    pub queue_cap: usize,
    /// In-memory artifact-layer budget in bytes (0 = unbounded).
    pub mem_budget: u64,
    /// Write the actual bound address (`host:port\n`) here once
    /// listening — how CI and scripts discover a port-0 daemon.
    pub port_file: Option<String>,
    /// Serve stdin→stdout instead of TCP.
    pub stdio: bool,
    /// Admission bound on concurrent connections; excess connections get
    /// one `overloaded` error line and are closed without a handler
    /// thread (so a connection flood cannot exhaust threads).
    pub max_conns: usize,
    /// Close a connection that sends no request for this long
    /// (milliseconds; 0 disables). Idle closes are clean, not errors.
    pub idle_timeout_ms: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            addr: "127.0.0.1:7421".to_string(),
            workers: 4,
            queue_cap: 64,
            mem_budget: 0,
            port_file: None,
            stdio: false,
            max_conns: 1024,
            idle_timeout_ms: 60_000,
        }
    }
}

/// Run the daemon until a shutdown request (or stdio EOF). Blocks.
pub fn serve(cfg: SystemConfig, opts: &ServeOpts) -> Result<()> {
    let pool = Arc::new(WorkerPool::start(
        cfg,
        opts.workers,
        opts.queue_cap,
        opts.mem_budget,
    )?);
    if opts.stdio {
        serve_stdio(&pool)
    } else {
        serve_tcp(&pool, opts)
    }
}

fn serve_stdio(pool: &Arc<WorkerPool>) -> Result<()> {
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line.context("reading stdin")?;
        if line.trim().is_empty() {
            continue;
        }
        let (reply, is_shutdown) = handle_line(&line, pool);
        stdout
            .write_all(format!("{reply}\n").as_bytes())
            .and_then(|()| stdout.flush())
            .context("writing stdout")?;
        if is_shutdown {
            break;
        }
    }
    // EOF without an explicit shutdown still drains admitted work.
    pool.shutdown();
    Ok(())
}

fn serve_tcp(pool: &Arc<WorkerPool>, opts: &ServeOpts) -> Result<()> {
    let listener =
        TcpListener::bind(&opts.addr).with_context(|| format!("binding {}", opts.addr))?;
    let local = listener.local_addr().context("reading bound address")?;
    if let Some(path) = &opts.port_file {
        std::fs::write(path, format!("{local}\n"))
            .with_context(|| format!("writing port file {path}"))?;
    }
    println!(
        "cagra serve: listening on {local} ({} workers, queue cap {}, mem budget {})",
        pool.worker_count(),
        opts.queue_cap,
        if opts.mem_budget == 0 {
            "unbounded".to_string()
        } else {
            crate::util::fmt_bytes(opts.mem_budget as usize)
        }
    );
    let shutting_down = Arc::new(AtomicBool::new(false));
    let active_conns = Arc::new(AtomicUsize::new(0));
    let conn_handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));
    for stream in listener.incoming() {
        if shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(e) => {
                crate::log_warn!("accept failed: {e}");
                continue;
            }
        };
        // Admission bound: refuse with one parseable error line instead
        // of spawning a handler the flood would never release.
        if active_conns.load(Ordering::SeqCst) >= opts.max_conns.max(1) {
            let line = protocol::render_error(
                None,
                ErrorKind::Overloaded,
                "connection limit reached; retry later",
            );
            let _ = stream.write_all(format!("{line}\n").as_bytes());
            continue;
        }
        let pool = pool.clone();
        let flag = shutting_down.clone();
        let active = active_conns.clone();
        let idle = opts.idle_timeout_ms;
        active.fetch_add(1, Ordering::SeqCst);
        let handle = std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &pool, &flag, local, idle) {
                crate::log_warn!("connection error: {e:#}");
            }
            active.fetch_sub(1, Ordering::SeqCst);
        });
        // One lock for both bookkeeping steps: push this handler, reap
        // finished ones so a long-lived daemon doesn't accumulate them.
        {
            let mut h = conn_handles.lock().unwrap_or_else(|p| p.into_inner());
            h.retain(|h| !h.is_finished());
            h.push(handle);
        }
    }
    let handles: Vec<_> = {
        let mut h = conn_handles.lock().unwrap_or_else(|p| p.into_inner());
        h.drain(..).collect()
    };
    for h in handles {
        let _ = h.join();
    }
    pool.shutdown();
    // One grep-able drain line: CI's chaos smoke asserts on these fields.
    let store = pool.store_stats().unwrap_or_default();
    println!(
        "cagra serve: drained; jobs={} workers_alive={} panics_contained={} \
         quarantined={} rebuilds={} resident_hits={}",
        pool.jobs_done(),
        pool.workers_alive(),
        pool.panics_contained(),
        store.quarantined,
        store.rebuilds,
        pool.mem_stats().hits
    );
    Ok(())
}

/// A peer that vanished (EOF is handled separately) — a normal fact of
/// network life, closed without noise.
fn is_disconnect(kind: IoErrorKind) -> bool {
    matches!(
        kind,
        IoErrorKind::ConnectionReset
            | IoErrorKind::ConnectionAborted
            | IoErrorKind::BrokenPipe
            | IoErrorKind::UnexpectedEof
    )
}

/// A read that hit the socket timeout — the connection idled out.
/// (Linux reports `WouldBlock`, other platforms `TimedOut`.)
fn is_idle_timeout(kind: IoErrorKind) -> bool {
    matches!(kind, IoErrorKind::WouldBlock | IoErrorKind::TimedOut)
}

fn handle_conn(
    stream: TcpStream,
    pool: &Arc<WorkerPool>,
    shutting_down: &AtomicBool,
    local: std::net::SocketAddr,
    idle_timeout_ms: u64,
) -> Result<()> {
    if idle_timeout_ms > 0 {
        // The timeout clock only runs while waiting for the *next*
        // request — replies are written by this same thread, so a slow
        // job can never idle out its own connection.
        stream
            .set_read_timeout(Some(Duration::from_millis(idle_timeout_ms)))
            .context("setting read timeout")?;
    }
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: the client is done — clean close.
            Ok(_) => {}
            Err(e) if is_idle_timeout(e.kind()) => {
                crate::log_debug!("closing idle connection ({idle_timeout_ms}ms without a request)");
                break;
            }
            Err(e) if is_disconnect(e.kind()) => break,
            Err(e) => return Err(e).context("reading request line"),
        }
        if line.trim().is_empty() {
            continue;
        }
        // Injected connection fault: drop the connection mid-stream, as
        // if the peer's network vanished (err) or the handler had a bug
        // (panic — only this thread dies; the daemon keeps accepting).
        if let Err(e) = crate::fault::failpoint(crate::fault::Site::ConnIo) {
            crate::log_debug!("dropping connection: {e:#}");
            break;
        }
        let (reply, is_shutdown) = handle_line(&line, pool);
        match writer
            .write_all(format!("{reply}\n").as_bytes())
            .and_then(|()| writer.flush())
        {
            Ok(()) => {}
            Err(e) if is_disconnect(e.kind()) => break, // reply raced a hangup
            Err(e) => return Err(e).context("writing response"),
        }
        if is_shutdown {
            shutting_down.store(true, Ordering::SeqCst);
            // The accept loop is blocked in `incoming()`; poke it with a
            // throwaway connection so it observes the flag and exits.
            let _ = TcpStream::connect_timeout(&local, Duration::from_secs(1));
            break;
        }
    }
    Ok(())
}

/// Handle one request line against the pool. Returns the response line
/// (no trailing newline) and whether this was a shutdown request.
pub fn handle_line(line: &str, pool: &WorkerPool) -> (String, bool) {
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            return (
                protocol::render_error(None, ErrorKind::BadRequest, &format!("{e:#}")),
                false,
            )
        }
    };
    match req {
        Request::Ping { id } => (protocol::render_pong(id.as_ref()), false),
        Request::Stats { id } => (
            protocol::render_stats(
                id.as_ref(),
                &StatsSnapshot {
                    mem: pool.mem_stats(),
                    workers: pool.worker_count(),
                    workers_alive: pool.workers_alive(),
                    panics_contained: pool.panics_contained(),
                    queue_depth: pool.queue_depth(),
                    jobs_done: pool.jobs_done(),
                    store: pool.store_stats(),
                },
            ),
            false,
        ),
        Request::Shutdown { id } => (protocol::render_shutdown_ack(id.as_ref()), true),
        Request::Run(run) => {
            let deadline = run.deadline_ms.map(Duration::from_millis);
            let id = run.id.clone();
            match pool.run_sync(run.spec, deadline) {
                Ok(Outcome::Done {
                    result: Ok(r),
                    queue_s,
                    run_s,
                }) => (
                    protocol::render_run_result(id.as_ref(), &r, queue_s, run_s),
                    false,
                ),
                Ok(Outcome::Done {
                    result: Err(e), ..
                }) => (
                    protocol::render_error(id.as_ref(), ErrorKind::Failed, &format!("{e:#}")),
                    false,
                ),
                Ok(Outcome::DeadlineExpired { queue_s }) => (
                    protocol::render_error(
                        id.as_ref(),
                        ErrorKind::Deadline,
                        &format!("deadline elapsed after {:.1}ms in queue", queue_s * 1e3),
                    ),
                    false,
                ),
                Err(SubmitError::Overloaded) => (
                    protocol::render_error(
                        id.as_ref(),
                        ErrorKind::Overloaded,
                        "admission queue full",
                    ),
                    false,
                ),
                Err(SubmitError::ShuttingDown) => (
                    protocol::render_error(
                        id.as_ref(),
                        ErrorKind::ShuttingDown,
                        "server is draining",
                    ),
                    false,
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{parse, Value};

    #[test]
    fn handle_line_covers_control_plane() {
        // Pool construction (re)arms failpoints from the config, so hold
        // the crate-wide guard to avoid disarming a concurrent test.
        let _g = crate::fault::TEST_GUARD.lock().unwrap_or_else(|p| p.into_inner());
        let pool = WorkerPool::start(SystemConfig::default(), 1, 4, 0).unwrap();
        let (pong, stop) = handle_line(r#"{"op":"ping","id":1}"#, &pool);
        assert!(!stop);
        let v = parse(&pong).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(v.get("id").and_then(Value::as_u64), Some(1));

        let (stats, stop) = handle_line(r#"{"op":"stats"}"#, &pool);
        assert!(!stop);
        let v = parse(&stats).unwrap();
        assert_eq!(v.get("workers").and_then(Value::as_u64), Some(1));

        let (bad, stop) = handle_line("not json", &pool);
        assert!(!stop);
        let v = parse(&bad).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(v.get("error").and_then(Value::as_str), Some("bad_request"));

        let (ack, stop) = handle_line(r#"{"op":"shutdown","id":"bye"}"#, &pool);
        assert!(stop);
        let v = parse(&ack).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(v.get("id").and_then(Value::as_str), Some("bye"));
        pool.shutdown();
    }
}
