//! Worker pool for the resident daemon: N OS threads executing jobs
//! against one shared [`JobEnv`] (disk store + in-memory artifact layer).
//!
//! Ownership contract (DESIGN.md §5): the shared layers hold only
//! **immutable** decoded artifacts behind `Arc` (CSRs, segmented CSRs,
//! permutations, datasets). All mutable execution state — engine scratch
//! pools, per-source atomic arrays, segment buffers — lives inside the
//! `PreparedApp` each job constructs and drops on its own worker thread,
//! so concurrent jobs never alias scratch even when they share every
//! artifact.
//!
//! Admission control: the queue is bounded ([`SubmitError::Overloaded`]
//! beyond `queue_cap`), a job carrying a deadline is rejected with
//! [`SubmitError` → deadline outcome] if no worker can *start* it in
//! time, and [`WorkerPool::shutdown`] drains: already-admitted jobs run
//! to completion, new submissions fail with
//! [`SubmitError::ShuttingDown`].

use crate::coordinator::{run_job_env, JobEnv, JobResult, JobSpec, SystemConfig};
use crate::store::{ArtifactStore, MemStats, MemStore};
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Why a submission was refused at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue already holds `queue_cap` jobs.
    Overloaded,
    /// [`WorkerPool::shutdown`] has begun; the pool only drains.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "admission queue full"),
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

/// Terminal state of an admitted job.
#[derive(Debug)]
pub enum Outcome {
    /// The job ran; `queue_s` is time spent waiting for a worker.
    Done {
        result: Result<JobResult>,
        queue_s: f64,
        run_s: f64,
    },
    /// The deadline elapsed before any worker could start the job.
    DeadlineExpired { queue_s: f64 },
}

struct Job {
    spec: JobSpec,
    deadline: Option<Instant>,
    enqueued: Instant,
    reply: Sender<Outcome>,
}

struct State {
    queue: VecDeque<Job>,
    shutting_down: bool,
}

struct Shared {
    state: Mutex<State>,
    available: Condvar,
    cfg: SystemConfig,
    store: Option<ArtifactStore>,
    mem: MemStore,
    queue_cap: usize,
    jobs_done: AtomicU64,
    /// Job panics swallowed by the per-job `catch_unwind` (each one
    /// became an error reply instead of a dead worker).
    panics_contained: AtomicU64,
    /// Worker threads currently in (or respawning into) `worker_loop`.
    /// Incremented before spawn, decremented as each thread exits, so
    /// `shutdown` can bounded-wait for respawned (detached) workers too.
    workers_alive: AtomicUsize,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, State> {
        // A worker that panics mid-job (registry bug) poisons nothing the
        // queue depends on; keep serving.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn env(&self) -> JobEnv<'_> {
        JobEnv {
            shared_store: self.store.as_ref(),
            mem: Some(&self.mem),
        }
    }
}

/// The resident execution pool: shared artifact layers + worker threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn `workers` threads over a fresh in-memory layer with
    /// `mem_budget` bytes (0 = unbounded) and, when the config enables
    /// it, one shared disk store. `queue_cap` bounds waiting jobs, with
    /// an effective floor of one slot per worker so a just-started pool
    /// can always be filled. Arms failpoints from the config (or
    /// `CAGRA_FAILPOINTS`) so a daemon's whole lifetime runs under the
    /// requested fault pressure.
    pub fn start(
        cfg: SystemConfig,
        workers: usize,
        queue_cap: usize,
        mem_budget: u64,
    ) -> Result<WorkerPool> {
        crate::fault::arm_from(&cfg.failpoints)?;
        let workers = workers.max(1);
        let store = if cfg.store_enabled {
            let s = ArtifactStore::open(&cfg.store_dir, cfg.store_cap_bytes)?;
            // All workers share this one store, so mapped artifacts are
            // one physical copy across every concurrent resident job.
            s.set_mmap_enabled(cfg.store_mmap);
            Some(s)
        } else {
            None
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                shutting_down: false,
            }),
            available: Condvar::new(),
            cfg,
            store,
            mem: MemStore::new(mem_budget),
            queue_cap,
            jobs_done: AtomicU64::new(0),
            panics_contained: AtomicU64::new(0),
            workers_alive: AtomicUsize::new(0),
        });
        let handles = (0..workers).map(|i| spawn_worker(&shared, i)).collect();
        Ok(WorkerPool {
            shared,
            workers,
            handles: Mutex::new(handles),
        })
    }

    /// Admit a job. On `Ok` the receiver yields exactly one [`Outcome`];
    /// on `Err` nothing was enqueued and the caller reports the refusal.
    pub fn submit(
        &self,
        spec: JobSpec,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Outcome>, SubmitError> {
        let (tx, rx) = channel();
        {
            let mut st = self.shared.lock();
            if st.shutting_down {
                return Err(SubmitError::ShuttingDown);
            }
            if st.queue.len() >= self.shared.queue_cap.max(self.workers) {
                return Err(SubmitError::Overloaded);
            }
            let now = Instant::now();
            st.queue.push_back(Job {
                spec,
                deadline: deadline.map(|d| now + d),
                enqueued: now,
                reply: tx,
            });
        }
        self.shared.available.notify_one();
        Ok(rx)
    }

    /// [`WorkerPool::submit`] + block for the outcome (per-connection
    /// handler threads and the bench harness use this).
    pub fn run_sync(
        &self,
        spec: JobSpec,
        deadline: Option<Duration>,
    ) -> Result<Outcome, SubmitError> {
        let rx = self.submit(spec, deadline)?;
        // A dropped sender (worker died mid-job) must not hang the
        // connection; surface it as a job failure.
        Ok(rx.recv().unwrap_or_else(|_| Outcome::Done {
            result: Err(anyhow::anyhow!("worker abandoned the job (internal error)")),
            queue_s: 0.0,
            run_s: 0.0,
        }))
    }

    pub fn mem_stats(&self) -> MemStats {
        self.shared.mem.stats()
    }

    /// Disk-store counters (None when the store is disabled).
    pub fn store_stats(&self) -> Option<crate::store::StoreStats> {
        self.shared.store.as_ref().map(|s| s.stats())
    }

    pub fn worker_count(&self) -> usize {
        self.workers
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.lock().queue.len()
    }

    pub fn jobs_done(&self) -> u64 {
        self.shared.jobs_done.load(Ordering::Relaxed)
    }

    /// Worker threads currently serving (original or respawned). Equals
    /// [`WorkerPool::worker_count`] whenever no thread is mid-respawn.
    pub fn workers_alive(&self) -> usize {
        self.shared.workers_alive.load(Ordering::SeqCst)
    }

    /// Job panics converted to error replies by the per-job containment.
    pub fn panics_contained(&self) -> u64 {
        self.shared.panics_contained.load(Ordering::Relaxed)
    }

    /// Graceful drain: stop admitting, let workers finish every
    /// already-queued job, then join them. Respawned workers are
    /// detached (no `JoinHandle`), so after joining the originals this
    /// bounded-waits for `workers_alive` to reach zero. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.lock();
            st.shutting_down = true;
        }
        self.shared.available.notify_all();
        let handles: Vec<_> = {
            let mut h = self.handles.lock().unwrap_or_else(|p| p.into_inner());
            h.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.shared.workers_alive.load(Ordering::SeqCst) > 0 {
            if Instant::now() > deadline {
                crate::log_warn!(
                    "shutdown: {} worker(s) still alive after drain timeout",
                    self.shared.workers_alive.load(Ordering::SeqCst)
                );
                break;
            }
            self.shared.available.notify_all();
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Spawn worker `id`, wrapped in the supervisor: if the thread dies (a
/// panic that escaped the per-job containment — in practice the
/// `worker.thread` failpoint or a bug in the loop itself), a detached
/// replacement is spawned so the pool's capacity self-heals. The
/// in-flight job, if any, surfaces to its client as an "abandoned"
/// error through the dropped reply sender.
fn spawn_worker(shared: &Arc<Shared>, id: usize) -> std::thread::JoinHandle<()> {
    shared.workers_alive.fetch_add(1, Ordering::SeqCst);
    let sh = shared.clone();
    std::thread::Builder::new()
        .name(format!("cagra-worker-{id}"))
        .spawn(move || {
            let died = std::panic::catch_unwind(AssertUnwindSafe(|| worker_loop(&sh))).is_err();
            sh.workers_alive.fetch_sub(1, Ordering::SeqCst);
            if died && !sh.lock().shutting_down {
                crate::log_warn!("worker {id} died; respawning");
                // Detached: `shutdown` accounts for it via workers_alive.
                drop(spawn_worker(&sh, id));
            }
        })
        .expect("spawning worker thread")
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.lock();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    break job;
                }
                if st.shutting_down {
                    return;
                }
                st = shared
                    .available
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        // Thread-death injection: *outside* the per-job containment
        // below, so a trigger unwinds the whole thread and exercises the
        // supervisor respawn. Evaluated once per popped job (an idle
        // pool cannot respawn-storm); either action means thread death.
        if crate::fault::check(crate::fault::Site::WorkerThread).is_some() {
            panic!("injected thread death at failpoint worker.thread");
        }
        let started = Instant::now();
        let queue_s = started.duration_since(job.enqueued).as_secs_f64();
        if job.deadline.is_some_and(|d| started > d) {
            // Too late to start: the client gave up at its deadline, so
            // running now would burn a worker on an unwanted answer.
            let _ = job.reply.send(Outcome::DeadlineExpired { queue_s });
            continue;
        }
        // Containment: a panicking job (or an injected `worker.job`
        // fault) becomes an error outcome; the worker keeps serving.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            crate::fault::failpoint(crate::fault::Site::WorkerJob)?;
            run_job_env(&job.spec, &shared.cfg, shared.env())
        }))
        .unwrap_or_else(|payload| {
            shared.panics_contained.fetch_add(1, Ordering::Relaxed);
            let msg = panic_message(payload.as_ref());
            crate::log_warn!("worker contained a job panic: {msg}");
            Err(anyhow::anyhow!("job panicked: {msg}"))
        });
        let run_s = started.elapsed().as_secs_f64();
        shared.jobs_done.fetch_add(1, Ordering::Relaxed);
        // A receiver that hung up (connection dropped) is not an error.
        let _ = job.reply.send(Outcome::Done {
            result,
            queue_s,
            run_s,
        });
    }
}

/// Best-effort text of a panic payload (`&str` and `String` cover
/// `panic!` and `assert!`; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every pool test holds the crate-wide failpoint guard: the
    /// registry is process-global, so a concurrent arming test would
    /// otherwise inject faults into these pools too.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        crate::fault::TEST_GUARD.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn small_spec() -> JobSpec {
        JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            iters: 2,
            ..Default::default()
        }
    }

    #[test]
    fn pool_runs_jobs_and_counts_them() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 2, 8, 0).unwrap();
        let outcome = pool.run_sync(small_spec(), None).unwrap();
        let Outcome::Done { result, run_s, .. } = outcome else {
            panic!("expected completion");
        };
        let r = result.unwrap();
        assert_eq!(r.metrics.iter_seconds.len(), 2);
        assert!(run_s > 0.0);
        assert_eq!(pool.jobs_done(), 1);
        // The pool always threads the memory layer through the job.
        assert!(r.metrics.mem.is_some());
    }

    #[test]
    fn bad_spec_is_an_error_outcome_not_a_dead_worker() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 1, 8, 0).unwrap();
        let bad = JobSpec {
            cf_k: Some(65),
            ..small_spec()
        };
        let Outcome::Done { result, .. } = pool.run_sync(bad, None).unwrap() else {
            panic!("expected completion");
        };
        assert!(result.is_err());
        // The worker survived the bad request and still serves.
        let Outcome::Done { result, .. } = pool.run_sync(small_spec(), None).unwrap() else {
            panic!("expected completion");
        };
        assert!(result.is_ok());
    }

    #[test]
    fn expired_deadline_skips_execution() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 1, 8, 0).unwrap();
        // Occupy the single worker so the deadline job waits in queue.
        let blocker = pool.submit(small_spec(), None).unwrap();
        let doomed = pool
            .submit(small_spec(), Some(Duration::from_nanos(1)))
            .unwrap();
        let outcome = doomed.recv().unwrap();
        assert!(
            matches!(outcome, Outcome::DeadlineExpired { .. }),
            "a 1ns deadline cannot be met from behind a running job"
        );
        assert!(matches!(blocker.recv().unwrap(), Outcome::Done { .. }));
    }

    #[test]
    fn overload_rejects_at_the_door() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 1, 1, 0).unwrap();
        let mut admitted = Vec::new();
        let mut rejected = 0;
        // Far more submissions than workers+queue_cap: the excess must be
        // refused (never silently dropped or unboundedly queued).
        for _ in 0..32 {
            match pool.submit(small_spec(), None) {
                Ok(rx) => admitted.push(rx),
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected > 0, "cap 1 must refuse some of 32 submissions");
        for rx in admitted {
            assert!(matches!(rx.recv().unwrap(), Outcome::Done { .. }));
        }
    }

    #[test]
    fn shutdown_drains_admitted_jobs() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 1, 8, 0).unwrap();
        let receivers: Vec<_> = (0..4)
            .map(|_| pool.submit(small_spec(), None).unwrap())
            .collect();
        pool.shutdown();
        // Every admitted job completed during the drain...
        for rx in receivers {
            let Outcome::Done { result, .. } = rx.recv().unwrap() else {
                panic!("drain must complete admitted jobs");
            };
            assert!(result.is_ok());
        }
        assert_eq!(pool.jobs_done(), 4);
        // ...and nothing is admitted afterwards.
        assert_eq!(
            pool.submit(small_spec(), None).unwrap_err(),
            SubmitError::ShuttingDown
        );
    }

    #[test]
    fn injected_job_panic_is_contained_and_counted() {
        let _g = guard();
        // Arm *after* start: the constructor (re)arms from the config,
        // which for a default config disarms everything.
        let pool = WorkerPool::start(SystemConfig::default(), 2, 8, 0).unwrap();
        crate::fault::configure("worker.job=panic@every:2").unwrap();
        let mut errs = 0;
        for _ in 0..4 {
            // run_sync serializes the jobs, so the every:2 trigger fires
            // on exactly the 2nd and 4th evaluations.
            let Outcome::Done { result, .. } = pool.run_sync(small_spec(), None).unwrap() else {
                panic!("expected completion");
            };
            if result.is_err() {
                errs += 1;
            }
        }
        assert_eq!(errs, 2, "every:2 over 4 jobs");
        assert_eq!(pool.panics_contained(), 2);
        assert_eq!(pool.workers_alive(), 2, "containment must not kill workers");
        crate::fault::disarm();
        let Outcome::Done { result, .. } = pool.run_sync(small_spec(), None).unwrap() else {
            panic!("expected completion");
        };
        assert!(result.is_ok(), "pool serves normally once disarmed");
    }

    #[test]
    fn dead_worker_thread_is_respawned() {
        let _g = guard();
        let pool = WorkerPool::start(SystemConfig::default(), 1, 8, 0).unwrap();
        crate::fault::configure("worker.thread=panic@every:1").unwrap();
        // The single worker dies while holding the popped job: the
        // client sees an "abandoned" error, never a hang.
        let Outcome::Done { result, .. } = pool.run_sync(small_spec(), None).unwrap() else {
            panic!("expected completion");
        };
        let msg = result.unwrap_err().to_string();
        assert!(msg.contains("abandoned"), "got {msg:?}");
        crate::fault::disarm();
        // The supervisor respawns a replacement...
        let deadline = Instant::now() + Duration::from_secs(10);
        while pool.workers_alive() < 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.workers_alive(), 1, "replacement worker never arrived");
        // ...which serves jobs like nothing happened.
        let Outcome::Done { result, .. } = pool.run_sync(small_spec(), None).unwrap() else {
            panic!("expected completion");
        };
        assert!(result.is_ok(), "respawned worker serves");
        assert_eq!(
            pool.panics_contained(),
            0,
            "thread death is respawn territory, not a contained job panic"
        );
    }
}
