//! Wire protocol for `cagra serve`: newline-delimited JSON over TCP or
//! stdio, built on [`crate::util::json`] (one request per line, one
//! response line per request, in order per connection).
//!
//! Requests are objects with an `op` field:
//!
//! ```text
//! {"op":"ping"}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! {"op":"run","app":"pagerank","variant":"both","graph":"livejournal-sim",
//!  "iters":3,"scale":0.015625,"damping":0.9,"deadline_ms":5000,"id":17}
//! ```
//!
//! `run` accepts exactly the `cagra batch` JobSpec surface (app, variant,
//! graph, iters, sources, scale, analyze, delta_epsilon, cf_k, damping,
//! bfs_source) plus `deadline_ms` (admission deadline) and `id` (any JSON
//! value, echoed verbatim in the response so clients can pipeline).
//! Unknown keys are rejected — a typo'd knob must fail loudly, not run a
//! silently-different job.
//!
//! Responses always carry `ok` and the echoed `id`; failures carry a
//! machine-matchable `error` kind from [`ErrorKind`] plus a human
//! `message`.

use crate::coordinator::{JobResult, JobSpec};
use crate::util::json::{parse, Value};
use anyhow::{bail, Result};

/// One parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    Ping { id: Option<Value> },
    Stats { id: Option<Value> },
    Shutdown { id: Option<Value> },
    Run(Box<RunRequest>),
}

/// The `op:"run"` payload: a full job plus serving controls.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub id: Option<Value>,
    pub spec: JobSpec,
    /// Admission deadline: if the job cannot *start* within this many
    /// milliseconds of submission, the server rejects it with
    /// [`ErrorKind::Deadline`] instead of running late.
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// The request id, for echoing into the response.
    pub fn id(&self) -> Option<&Value> {
        match self {
            Request::Ping { id } | Request::Stats { id } | Request::Shutdown { id } => id.as_ref(),
            Request::Run(r) => r.id.as_ref(),
        }
    }
}

/// Machine-matchable failure kinds (the `error` response field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Malformed JSON, unknown op/key, bad field type, unknown app.
    BadRequest,
    /// Admission queue full.
    Overloaded,
    /// Deadline elapsed before a worker could start the job.
    Deadline,
    /// The job itself errored (bad knob value, unknown dataset, ...).
    Failed,
    /// Server is draining; no new work accepted.
    ShuttingDown,
}

impl ErrorKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::BadRequest => "bad_request",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Deadline => "deadline",
            ErrorKind::Failed => "failed",
            ErrorKind::ShuttingDown => "shutting_down",
        }
    }
}

/// Parse one request line. Every failure is a [`ErrorKind::BadRequest`]
/// candidate — the caller renders the error back to the client.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = parse(line)?;
    let Value::Obj(fields) = &v else {
        bail!("request must be a JSON object");
    };
    let op = match v.get("op") {
        Some(Value::Str(s)) => s.as_str(),
        Some(_) => bail!("\"op\" must be a string"),
        None => bail!("missing \"op\" field"),
    };
    let id = v.get("id").cloned();
    match op {
        "ping" => {
            reject_unknown(fields, &["op", "id"])?;
            Ok(Request::Ping { id })
        }
        "stats" => {
            reject_unknown(fields, &["op", "id"])?;
            Ok(Request::Stats { id })
        }
        "shutdown" => {
            reject_unknown(fields, &["op", "id"])?;
            Ok(Request::Shutdown { id })
        }
        "run" => parse_run(fields, id),
        other => bail!("unknown op {other:?} (expected run|ping|stats|shutdown)"),
    }
}

fn reject_unknown(fields: &[(String, Value)], allowed: &[&str]) -> Result<()> {
    for (k, _) in fields {
        if !allowed.contains(&k.as_str()) {
            bail!("unknown request key {k:?} (allowed: {})", allowed.join("|"));
        }
    }
    Ok(())
}

fn parse_run(fields: &[(String, Value)], id: Option<Value>) -> Result<Request> {
    let mut spec = JobSpec::default();
    let mut app: Option<&str> = None;
    let mut variant: Option<&str> = None;
    let mut deadline_ms: Option<u64> = None;
    for (k, v) in fields {
        match k.as_str() {
            "op" | "id" => {}
            "app" => app = Some(str_field(k, v)?),
            "variant" => variant = Some(str_field(k, v)?),
            "graph" => spec.dataset = str_field(k, v)?.to_string(),
            "iters" => spec.iters = usize_field(k, v)?,
            "sources" => spec.num_sources = usize_field(k, v)?,
            "scale" => spec.scale = num_field(k, v)?,
            "analyze" => spec.analyze_memory = bool_field(k, v)?,
            "delta_epsilon" => spec.delta_epsilon = Some(num_field(k, v)?),
            "cf_k" => spec.cf_k = Some(usize_field(k, v)?),
            "damping" => spec.damping = Some(num_field(k, v)?),
            "bfs_source" => {
                let n = usize_field(k, v)?;
                spec.bfs_source = Some(u32::try_from(n).map_err(|_| {
                    anyhow::anyhow!("\"bfs_source\" {n} exceeds the vertex-id range")
                })?);
            }
            "deadline_ms" => deadline_ms = Some(usize_field(k, v)? as u64),
            other => bail!(
                "unknown run key {other:?} (allowed: op|id|app|variant|graph|iters|sources|\
                 scale|analyze|delta_epsilon|cf_k|damping|bfs_source|deadline_ms)"
            ),
        }
    }
    let Some(app) = app else {
        bail!("run request missing \"app\"");
    };
    let a = crate::apps::registry::find(app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app:?} (see `cagra apps`)"))?;
    spec.app = match variant {
        Some(v) => a.parse_variant(v)?,
        None => a.default_variant(),
    };
    Ok(Request::Run(Box::new(RunRequest {
        id,
        spec,
        deadline_ms,
    })))
}

fn str_field<'a>(k: &str, v: &'a Value) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| anyhow::anyhow!("{k:?} must be a string"))
}

fn num_field(k: &str, v: &Value) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow::anyhow!("{k:?} must be a number"))
}

fn usize_field(k: &str, v: &Value) -> Result<usize> {
    v.as_u64()
        .map(|n| n as usize)
        .ok_or_else(|| anyhow::anyhow!("{k:?} must be a non-negative integer"))
}

fn bool_field(k: &str, v: &Value) -> Result<bool> {
    match v {
        Value::Bool(b) => Ok(*b),
        _ => bail!("{k:?} must be a boolean"),
    }
}

fn base_response(id: Option<&Value>, op: &str, ok: bool) -> Vec<(String, Value)> {
    vec![
        ("id".to_string(), id.cloned().unwrap_or(Value::Null)),
        ("ok".to_string(), Value::Bool(ok)),
        ("op".to_string(), Value::Str(op.to_string())),
    ]
}

/// One compact response line (no trailing newline — the writer appends
/// the frame delimiter).
pub fn render_error(id: Option<&Value>, kind: ErrorKind, message: &str) -> String {
    let mut fields = base_response(id, "error", false);
    fields.push((
        "error".to_string(),
        Value::Str(kind.as_str().to_string()),
    ));
    fields.push(("message".to_string(), Value::Str(message.to_string())));
    Value::Obj(fields).render_compact()
}

pub fn render_pong(id: Option<&Value>) -> String {
    Value::Obj(base_response(id, "ping", true)).render_compact()
}

/// Everything a `stats` response reports: pool counters (including the
/// containment story: live workers, contained panics), the resident
/// layer, and — when the disk store is enabled — its self-healing
/// counters.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub mem: crate::store::MemStats,
    pub workers: usize,
    pub workers_alive: usize,
    pub panics_contained: u64,
    pub queue_depth: usize,
    pub jobs_done: u64,
    pub store: Option<crate::store::StoreStats>,
}

/// `stats` response: the resident-layer and pool counters a load
/// balancer or test harness polls.
pub fn render_stats(id: Option<&Value>, s: &StatsSnapshot) -> String {
    let mut fields = base_response(id, "stats", true);
    fields.push(("workers".to_string(), Value::Num(s.workers as f64)));
    fields.push((
        "workers_alive".to_string(),
        Value::Num(s.workers_alive as f64),
    ));
    fields.push((
        "panics_contained".to_string(),
        Value::Num(s.panics_contained as f64),
    ));
    fields.push(("queue_depth".to_string(), Value::Num(s.queue_depth as f64)));
    fields.push(("jobs_done".to_string(), Value::Num(s.jobs_done as f64)));
    fields.push(("mem".to_string(), mem_value(&s.mem)));
    if let Some(st) = &s.store {
        fields.push(("store".to_string(), store_value(st)));
    }
    Value::Obj(fields).render_compact()
}

pub fn render_shutdown_ack(id: Option<&Value>) -> String {
    Value::Obj(base_response(id, "shutdown", true)).render_compact()
}

fn store_value(s: &crate::store::StoreStats) -> Value {
    Value::Obj(vec![
        ("hits".to_string(), Value::Num(s.hits as f64)),
        ("misses".to_string(), Value::Num(s.misses as f64)),
        ("evictions".to_string(), Value::Num(s.evictions as f64)),
        ("entries".to_string(), Value::Num(s.entries as f64)),
        ("quarantined".to_string(), Value::Num(s.quarantined as f64)),
        ("rebuilds".to_string(), Value::Num(s.rebuilds as f64)),
    ])
}

fn mem_value(m: &crate::store::MemStats) -> Value {
    Value::Obj(vec![
        ("hits".to_string(), Value::Num(m.hits as f64)),
        ("misses".to_string(), Value::Num(m.misses as f64)),
        ("evictions".to_string(), Value::Num(m.evictions as f64)),
        ("entries".to_string(), Value::Num(m.entries as f64)),
        (
            "resident_bytes".to_string(),
            Value::Num(m.resident_bytes as f64),
        ),
        (
            "mapped_bytes".to_string(),
            Value::Num(m.mapped_bytes as f64),
        ),
        ("budget_bytes".to_string(), Value::Num(m.budget_bytes as f64)),
    ])
}

/// Successful `run` response: the job's scalar summary plus the metrics a
/// closed-loop client needs to validate and aggregate.
pub fn render_run_result(
    id: Option<&Value>,
    r: &JobResult,
    queue_s: f64,
    run_s: f64,
) -> String {
    let mut fields = base_response(id, "run", true);
    if let Some(app) = &r.metrics.app {
        fields.push(("app".to_string(), Value::Str(app.clone())));
    }
    fields.push(("summary".to_string(), Value::Num(r.summary)));
    fields.push((
        "iters".to_string(),
        Value::Num(r.metrics.iter_seconds.len() as f64),
    ));
    fields.push((
        "median_s".to_string(),
        Value::Num(r.metrics.median_iter_seconds()),
    ));
    fields.push(("edges".to_string(), Value::Num(r.metrics.edges as f64)));
    fields.push(("queue_ms".to_string(), Value::Num(queue_s * 1e3)));
    fields.push(("run_ms".to_string(), Value::Num(run_s * 1e3)));
    if let Some(m) = &r.metrics.mem {
        fields.push(("mem".to_string(), mem_value(m)));
    }
    if let Some(s) = &r.metrics.store {
        fields.push((
            "store".to_string(),
            Value::Obj(vec![
                ("hits".to_string(), Value::Num(s.hits as f64)),
                ("misses".to_string(), Value::Num(s.misses as f64)),
            ]),
        ));
    }
    Value::Obj(fields).render_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::pagerank;
    use crate::coordinator::AppKind;

    #[test]
    fn parses_full_run_request() {
        let line = r#"{"op":"run","id":7,"app":"pagerank","variant":"both",
            "graph":"twitter-sim","iters":4,"sources":2,"scale":0.25,
            "analyze":true,"delta_epsilon":1e-6,"cf_k":8,"damping":0.9,
            "bfs_source":3,"deadline_ms":250}"#
            .replace('\n', " ");
        let Request::Run(r) = parse_request(&line).unwrap() else {
            panic!("not a run request");
        };
        assert_eq!(r.id, Some(Value::Num(7.0)));
        assert!(matches!(
            r.spec.app,
            AppKind::PageRank(pagerank::Variant::ReorderedSegmented)
        ));
        assert_eq!(r.spec.dataset, "twitter-sim");
        assert_eq!(r.spec.iters, 4);
        assert_eq!(r.spec.num_sources, 2);
        assert_eq!(r.spec.scale, 0.25);
        assert!(r.spec.analyze_memory);
        assert_eq!(r.spec.delta_epsilon, Some(1e-6));
        assert_eq!(r.spec.cf_k, Some(8));
        assert_eq!(r.spec.damping, Some(0.9));
        assert_eq!(r.spec.bfs_source, Some(3));
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn run_defaults_match_jobspec_defaults() {
        let Request::Run(r) = parse_request(r#"{"op":"run","app":"pagerank"}"#).unwrap() else {
            panic!("not a run request");
        };
        let d = JobSpec::default();
        assert_eq!(r.spec.dataset, d.dataset);
        assert_eq!(r.spec.iters, d.iters);
        assert_eq!(r.spec.scale, d.scale);
        assert!(r.id.is_none() && r.deadline_ms.is_none());
    }

    #[test]
    fn control_ops_parse() {
        assert!(matches!(
            parse_request(r#"{"op":"ping"}"#).unwrap(),
            Request::Ping { id: None }
        ));
        assert!(matches!(
            parse_request(r#"{"op":"stats","id":"s1"}"#).unwrap(),
            Request::Stats { id: Some(_) }
        ));
        assert!(matches!(
            parse_request(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Shutdown { id: None }
        ));
    }

    #[test]
    fn malformed_requests_rejected() {
        for bad in [
            "",                                          // not JSON
            "[1,2]",                                     // not an object
            r#"{"app":"pagerank"}"#,                     // missing op
            r#"{"op":"fly"}"#,                           // unknown op
            r#"{"op":"ping","extra":1}"#,                // unknown control key
            r#"{"op":"run"}"#,                           // missing app
            r#"{"op":"run","app":"nope"}"#,              // unknown app
            r#"{"op":"run","app":"pagerank","variant":"nope"}"#,
            r#"{"op":"run","app":"pagerank","color":"red"}"#, // unknown run key
            r#"{"op":"run","app":"pagerank","iters":-1}"#,    // bad type
            r#"{"op":"run","app":"pagerank","iters":1.5}"#,
            r#"{"op":"run","app":"pagerank","analyze":"yes"}"#,
            r#"{"op":"run","app":"pagerank","graph":7}"#,
            r#"{"op":"run","app":"pagerank","bfs_source":4294967296}"#,
        ] {
            assert!(parse_request(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn responses_are_single_line_and_parse_back() {
        let id = Value::Str("req-1".into());
        let err = render_error(Some(&id), ErrorKind::Overloaded, "queue full");
        assert!(!err.contains('\n'));
        let v = parse(&err).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(v.get("error").and_then(Value::as_str), Some("overloaded"));
        assert_eq!(v.get("id").and_then(Value::as_str), Some("req-1"));

        let pong = render_pong(None);
        let v = parse(&pong).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(v.get("id"), Some(&Value::Null));

        let stats = render_stats(
            None,
            &StatsSnapshot {
                workers: 4,
                workers_alive: 4,
                panics_contained: 2,
                jobs_done: 9,
                store: Some(crate::store::StoreStats {
                    quarantined: 1,
                    rebuilds: 1,
                    ..Default::default()
                }),
                ..Default::default()
            },
        );
        let v = parse(&stats).unwrap();
        assert_eq!(v.get("workers").and_then(Value::as_u64), Some(4));
        assert_eq!(v.get("workers_alive").and_then(Value::as_u64), Some(4));
        assert_eq!(v.get("panics_contained").and_then(Value::as_u64), Some(2));
        assert_eq!(v.get("jobs_done").and_then(Value::as_u64), Some(9));
        assert!(v.get("mem").is_some());
        let store = v.get("store").expect("store block when enabled");
        assert_eq!(store.get("quarantined").and_then(Value::as_u64), Some(1));
        assert_eq!(store.get("rebuilds").and_then(Value::as_u64), Some(1));
    }

    #[test]
    fn run_response_carries_summary_and_latency() {
        let r = JobResult {
            metrics: crate::coordinator::metrics::Metrics {
                app: Some("pagerank/both".to_string()),
                iter_seconds: vec![0.01, 0.02],
                edges: 100,
                mem: Some(crate::store::MemStats::default()),
                ..Default::default()
            },
            summary: 1.25,
        };
        let line = render_run_result(Some(&Value::Num(3.0)), &r, 0.001, 0.05);
        assert!(!line.contains('\n'));
        let v = parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(v.get("summary").and_then(Value::as_f64), Some(1.25));
        assert_eq!(v.get("iters").and_then(Value::as_u64), Some(2));
        assert_eq!(v.get("id").and_then(Value::as_u64), Some(3));
        assert!(v.get("run_ms").and_then(Value::as_f64).unwrap() > 0.0);
        assert!(v.get("mem").is_some());
    }
}
