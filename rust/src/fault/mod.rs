//! Deterministic failpoints — the fault-injection substrate for the
//! containment story (DESIGN.md §8).
//!
//! The serve path's blast-radius-critical layers (store write/read/
//! decode/map, the in-memory artifact layer, worker job execution,
//! daemon connection I/O) each carry a named **site**. A disarmed site
//! costs exactly one relaxed atomic load — the same discipline as
//! [`crate::obs::recorder`] — so the `hot-path-alloc` audit regions and
//! the `zero_alloc` steady-state proof stay intact. An armed site fires
//! deterministically: `every:N` counts evaluations under the registry
//! lock, and `p:P,seed:S` draws from one seeded [`crate::util::rng::Rng`]
//! whose draw *sequence* (and therefore trigger count) is reproducible
//! even when the victims race.
//!
//! Grammar (via `CAGRA_FAILPOINTS` or `SystemConfig::failpoints`;
//! the environment variable wins):
//!
//! ```text
//! spec    := entry (';' entry)*
//! entry   := site '=' action '@' trigger
//! action  := 'err' | 'panic'
//! trigger := 'every:' N | 'p:' P [',seed:' S]
//! ```
//!
//! e.g. `store.write=err@every:3;worker.job=panic@p:0.1,seed:42`.
//!
//! Per-site trigger counters are surfaced through
//! [`crate::coordinator::Metrics`], run reports, and serve stats, so a
//! chaos run can assert exactly how much fault pressure was applied.

use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// The named injection sites, in registry order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Site {
    /// Persisting an artifact (`codec::write_file` via the store).
    StoreWrite,
    /// Reading an artifact file back (`codec::read_file`).
    StoreRead,
    /// Decoding artifact bytes (`codec::decode`).
    StoreDecode,
    /// Mapping an artifact file (`mmap::MappedRegion::map`).
    StoreMap,
    /// Inserting a built value into the resident layer ([`crate::store::MemStore`]).
    MemInsert,
    /// Evicting from the resident layer to its byte budget.
    MemEvict,
    /// Job execution inside `worker_loop` (contained by `catch_unwind`).
    WorkerJob,
    /// The worker loop itself, *outside* the job containment — fires as
    /// thread death, exercising supervisor respawn.
    WorkerThread,
    /// Daemon connection I/O (per request line).
    ConnIo,
}

/// All sites, index-aligned with the registry slots.
pub const SITES: [Site; 9] = [
    Site::StoreWrite,
    Site::StoreRead,
    Site::StoreDecode,
    Site::StoreMap,
    Site::MemInsert,
    Site::MemEvict,
    Site::WorkerJob,
    Site::WorkerThread,
    Site::ConnIo,
];

const SITE_COUNT: usize = SITES.len();

impl Site {
    /// The spec-grammar name of this site.
    pub fn name(self) -> &'static str {
        match self {
            Site::StoreWrite => "store.write",
            Site::StoreRead => "store.read",
            Site::StoreDecode => "store.decode",
            Site::StoreMap => "store.map",
            Site::MemInsert => "mem.insert",
            Site::MemEvict => "mem.evict",
            Site::WorkerJob => "worker.job",
            Site::WorkerThread => "worker.thread",
            Site::ConnIo => "conn.io",
        }
    }

    fn parse(name: &str) -> Option<Site> {
        SITES.iter().copied().find(|s| s.name() == name)
    }
}

/// What an armed site does when its trigger fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Surface an injected `Err` at the site.
    Err,
    /// Panic at the site (containment's job to survive it).
    Panic,
}

#[derive(Debug)]
enum Trigger {
    /// Fire on every Nth evaluation (N ≥ 1).
    Every(u64),
    /// Fire with probability `p` per evaluation, drawn from a seeded RNG.
    Prob(f64, Rng),
}

#[derive(Debug)]
struct Armed {
    action: Action,
    trigger: Trigger,
    /// Evaluations seen (drives `every:N`).
    evals: u64,
}

/// One relaxed load on the disarmed fast path; everything else is cold.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);

/// Trigger counters, one per site, readable without the registry lock.
static TRIGGERED: [AtomicU64; SITE_COUNT] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// The armed-site registry. Only touched when arming/disarming or when a
/// site is armed, never on the disarmed fast path.
static REGISTRY: Mutex<[Option<Armed>; SITE_COUNT]> =
    Mutex::new([None, None, None, None, None, None, None, None, None]);

/// Whether any failpoint is armed. This load is the *entire* cost of a
/// disarmed site on the hot path.
#[inline]
pub fn enabled() -> bool {
    ANY_ARMED.load(Ordering::Relaxed)
}

/// Evaluate a site: `None` when disarmed or the trigger does not fire.
#[inline]
pub fn check(site: Site) -> Option<Action> {
    if !enabled() {
        return None;
    }
    evaluate(site)
}

/// Fallible-site helper: injected `err` becomes an `Err`, injected
/// `panic` panics (for the containment layer to catch).
#[inline]
pub fn failpoint(site: Site) -> Result<()> {
    match check(site) {
        None => Ok(()),
        Some(Action::Err) => bail!("injected fault at failpoint {}", site.name()),
        Some(Action::Panic) => panic!("injected panic at failpoint {}", site.name()),
    }
}

#[cold]
fn evaluate(site: Site) -> Option<Action> {
    let mut reg = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
    let armed = reg[site as usize].as_mut()?;
    armed.evals += 1;
    let fires = match &mut armed.trigger {
        Trigger::Every(n) => armed.evals % *n == 0,
        Trigger::Prob(p, rng) => rng.coin(*p),
    };
    if !fires {
        return None;
    }
    TRIGGERED[site as usize].fetch_add(1, Ordering::Relaxed);
    Some(armed.action)
}

/// Arm sites from a spec string (see the module grammar). Replaces the
/// whole registry and resets trigger counters; an empty spec disarms.
pub fn configure(spec: &str) -> Result<()> {
    let mut slots: [Option<Armed>; SITE_COUNT] =
        [None, None, None, None, None, None, None, None, None];
    let mut any = false;
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let (site_name, rest) = entry
            .split_once('=')
            .with_context(|| format!("failpoint entry {entry:?}: expected site=action@trigger"))?;
        let site = Site::parse(site_name.trim())
            .with_context(|| format!("unknown failpoint site {site_name:?}"))?;
        let (action_name, trigger_spec) = rest
            .split_once('@')
            .with_context(|| format!("failpoint entry {entry:?}: expected action@trigger"))?;
        let action = match action_name.trim() {
            "err" => Action::Err,
            "panic" => Action::Panic,
            other => bail!("unknown failpoint action {other:?} (expected err|panic)"),
        };
        let trigger = parse_trigger(trigger_spec.trim())
            .with_context(|| format!("failpoint entry {entry:?}"))?;
        slots[site as usize] = Some(Armed {
            action,
            trigger,
            evals: 0,
        });
        any = true;
    }
    let mut reg = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
    *reg = slots;
    for c in &TRIGGERED {
        // audit: relaxed-ok — counter reset under the registry lock; readers
        // only consume these after their own (locked) evaluations.
        c.store(0, Ordering::Relaxed);
    }
    ANY_ARMED.store(any, Ordering::SeqCst);
    Ok(())
}

fn parse_trigger(spec: &str) -> Result<Trigger> {
    if let Some(n) = spec.strip_prefix("every:") {
        let n: u64 = n.trim().parse().context("every:N needs an integer N")?;
        if n == 0 {
            bail!("every:N needs N >= 1");
        }
        return Ok(Trigger::Every(n));
    }
    if let Some(rest) = spec.strip_prefix("p:") {
        let (p_str, seed) = match rest.split_once(",seed:") {
            Some((p, s)) => (p, s.trim().parse::<u64>().context("seed:S needs an integer S")?),
            None => (rest, 0x5EED),
        };
        let p: f64 = p_str.trim().parse().context("p:P needs a float P")?;
        if !(0.0..=1.0).contains(&p) {
            bail!("p:P needs P in [0, 1], got {p}");
        }
        return Ok(Trigger::Prob(p, Rng::new(seed)));
    }
    bail!("unknown trigger {spec:?} (expected every:N or p:P[,seed:S])")
}

/// Disarm every site and clear trigger counters.
pub fn disarm() {
    configure("").expect("empty spec always parses");
}

/// Arm from `CAGRA_FAILPOINTS` if set (even to empty, which disarms),
/// otherwise from the config spec. The process-wide entry point `main`
/// and the serve/worker constructors call.
pub fn arm_from(cfg_spec: &str) -> Result<()> {
    match std::env::var("CAGRA_FAILPOINTS") {
        Ok(env_spec) => configure(&env_spec).context("CAGRA_FAILPOINTS"),
        Err(_) => configure(cfg_spec).context("system.failpoints"),
    }
}

/// Times `site` has fired since the last [`configure`].
pub fn triggered(site: Site) -> u64 {
    TRIGGERED[site as usize].load(Ordering::Relaxed)
}

/// `(site name, trigger count)` for every site that has fired — empty
/// when nothing fired (the shape Metrics and run reports embed).
pub fn snapshot() -> Vec<(&'static str, u64)> {
    SITES
        .iter()
        .filter_map(|&s| {
            let n = triggered(s);
            (n > 0).then_some((s.name(), n))
        })
        .collect()
}

/// Serializes every unit test — in any module — that arms the
/// process-global registry or runs code whose sites a concurrent arming
/// test could trip. Integration tests get a fresh process and manage
/// their own serialization.
#[cfg(test)]
pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        TEST_GUARD.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disarmed_sites_never_fire() {
        let _g = locked();
        disarm();
        assert!(!enabled());
        for &s in &SITES {
            assert_eq!(check(s), None);
            assert!(failpoint(s).is_ok());
        }
        assert!(snapshot().is_empty());
    }

    #[test]
    fn every_n_fires_deterministically() {
        let _g = locked();
        configure("store.write=err@every:3").unwrap();
        let fired: Vec<bool> = (0..9).map(|_| check(Site::StoreWrite).is_some()).collect();
        assert_eq!(
            fired,
            [false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(triggered(Site::StoreWrite), 3);
        // Unarmed sites stay silent even while another site is armed.
        assert_eq!(check(Site::WorkerJob), None);
        assert_eq!(snapshot(), vec![("store.write", 3)]);
        disarm();
    }

    #[test]
    fn probabilistic_trigger_is_seed_reproducible() {
        let _g = locked();
        let run = || {
            configure("worker.job=panic@p:0.25,seed:42").unwrap();
            let fired: Vec<bool> = (0..64).map(|_| check(Site::WorkerJob).is_some()).collect();
            (fired, triggered(Site::WorkerJob))
        };
        let (a, na) = run();
        let (b, nb) = run();
        assert_eq!(a, b, "same seed must reproduce the firing sequence");
        assert_eq!(na, nb);
        assert!(na > 0 && na < 64, "p=0.25 over 64 draws fired {na} times");
        disarm();
    }

    #[test]
    fn grammar_parses_the_issue_example_and_rejects_junk() {
        let _g = locked();
        configure("store.write=err@every:3;worker.job=panic@p:0.1,seed:42").unwrap();
        assert!(enabled());
        assert_eq!(check(Site::StoreWrite), None);
        assert_eq!(check(Site::StoreWrite), None);
        assert_eq!(check(Site::StoreWrite), Some(Action::Err));
        for bad in [
            "nope.site=err@every:1",
            "store.write=explode@every:1",
            "store.write=err@often",
            "store.write=err@every:0",
            "store.write=err@p:1.5",
            "store.write",
        ] {
            assert!(configure(bad).is_err(), "accepted {bad:?}");
        }
        // A failed configure still leaves the previous registry armed —
        // but tests must not leak state:
        disarm();
        assert!(!enabled());
    }

    #[test]
    fn failpoint_helper_maps_actions() {
        let _g = locked();
        configure("store.read=err@every:1").unwrap();
        let e = failpoint(Site::StoreRead).unwrap_err();
        assert!(e.to_string().contains("store.read"), "{e:#}");
        configure("store.read=panic@every:1").unwrap();
        let p = std::panic::catch_unwind(|| failpoint(Site::StoreRead));
        assert!(p.is_err(), "panic action must panic");
        disarm();
    }

    #[test]
    fn arm_from_prefers_env_and_falls_back_to_config() {
        let _g = locked();
        // No env var in the test process: config spec applies.
        std::env::remove_var("CAGRA_FAILPOINTS");
        arm_from("mem.insert=err@every:1").unwrap();
        assert!(enabled());
        assert_eq!(check(Site::MemInsert), Some(Action::Err));
        std::env::set_var("CAGRA_FAILPOINTS", "mem.evict=err@every:1");
        arm_from("mem.insert=err@every:1").unwrap();
        assert_eq!(check(Site::MemInsert), None, "env spec replaces config");
        assert_eq!(check(Site::MemEvict), Some(Action::Err));
        std::env::remove_var("CAGRA_FAILPOINTS");
        disarm();
    }
}
