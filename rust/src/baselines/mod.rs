//! Reimplementations of the comparison frameworks' *memory-access
//! strategies* (§6.2, §6.4, Table 10). The original binaries are not
//! available offline, so each baseline reproduces the access pattern and
//! synchronization discipline that determines its cache behaviour
//! (DESIGN.md §3):
//!
//! - [`ligra_style`] — EdgeMap pull PageRank without the contribution
//!   precompute (per-edge division), Ligra's shape.
//! - [`graphmat_style`] — generic-semiring SpMV PageRank, GraphMat's
//!   shape.
//! - [`gridgraph_style`] — 2D-grid edge streaming with atomic updates
//!   (`E·atomics` sync overhead in Table 10).
//! - [`xstream_style`] — edge-centric scatter/shuffle/gather streaming
//!   partitions (`3E + KV` traffic, `shuffle(E)` random DRAM).
//! - [`hilbert`] — Hilbert-curve edge traversal: HSerial, HAtomic, HMerge
//!   (§6.4 / Figure 10).
//!
//! All five produce numerically-equivalent PageRank iterations (tests
//! enforce it), so runtime differences measure the access pattern alone.

pub mod ligra_style;
pub mod graphmat_style;
pub mod gridgraph_style;
pub mod xstream_style;
pub mod hilbert;
