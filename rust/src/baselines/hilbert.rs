//! Hilbert-curve edge ordering (§6.4, Figure 10).
//!
//! Edges are sorted along a Hilbert curve over the (src, dst) plane,
//! giving cache-oblivious locality in both the read and the written
//! vector. Three parallelizations from the paper:
//!
//! - **HSerial** — single-threaded traversal (the COST baseline [19]).
//! - **HAtomic** — parallel chunks of the edge list with atomic adds
//!   ("performance of atomic operations is 3× worse").
//! - **HMerge** — per-thread private output vectors merged at the end
//!   ([31]; "only 5% of the runtime is spent on merging").

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::as_atomic_f64;
use crate::parallel::{num_threads, parallel_ranges};
use std::sync::atomic::Ordering;
use std::sync::Mutex;

/// d2xy-style Hilbert index of point (x, y) on a 2^order × 2^order grid.
pub fn hilbert_index(order: u32, mut x: u32, mut y: u32) -> u64 {
    let side: u64 = 1 << order;
    let mut d: u64 = 0;
    let mut s: u32 = 1 << (order - 1);
    while s > 0 {
        let rx = u32::from((x & s) > 0);
        let ry = u32::from((y & s) > 0);
        d += (s as u64) * (s as u64) * ((3 * rx) ^ ry) as u64;
        // Rotate quadrant (classic xy2d rotation over the full side).
        if ry == 0 {
            if rx == 1 {
                x = (side - 1) as u32 - x;
                y = (side - 1) as u32 - y;
            }
            std::mem::swap(&mut x, &mut y);
        }
        s >>= 1;
    }
    d
}

/// Edge list sorted in Hilbert order (the preprocessing step; "comparable
/// to vertex reordering, since we need to sort all edges", §6.6).
pub struct HilbertEdges {
    pub n: usize,
    pub edges: Vec<(VertexId, VertexId)>,
}

impl HilbertEdges {
    pub fn build(g: &Csr) -> HilbertEdges {
        let n = g.num_vertices();
        let order = (usize::BITS - (n.max(2) - 1).leading_zeros()).max(1);
        let mut keyed: Vec<(u64, VertexId, VertexId)> = g
            .edges()
            .map(|(u, v)| (hilbert_index(order, u, v), u, v))
            .collect();
        keyed.sort_unstable();
        HilbertEdges {
            n,
            edges: keyed.into_iter().map(|(_, u, v)| (u, v)).collect(),
        }
    }
}

/// Parallelization strategy (Figure 10 series).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    HSerial,
    HAtomic,
    HMerge,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::HSerial => "HSerial",
            Mode::HAtomic => "HAtomic",
            Mode::HMerge => "HMerge",
        }
    }
}

/// Preprocessed Hilbert-order PageRank.
pub struct Prepared {
    h: HilbertEdges,
    mode: Mode,
    damping: f64,
    inv_deg: Vec<f64>,
    rank: Vec<f64>,
    next: Vec<f64>,
}

impl Prepared {
    pub fn new(g: &Csr, cfg: &SystemConfig, mode: Mode) -> Prepared {
        let n = g.num_vertices();
        Prepared {
            h: HilbertEdges::build(g),
            mode,
            damping: cfg.damping,
            inv_deg: (0..n)
                .map(|v| {
                    let d = g.degree(v as VertexId);
                    if d == 0 {
                        0.0
                    } else {
                        1.0 / d as f64
                    }
                })
                .collect(),
            rank: vec![1.0 / n as f64; n],
            next: vec![0.0; n],
        }
    }

    pub fn reset(&mut self) {
        self.rank.fill(1.0 / self.h.n as f64);
    }

    pub fn step(&mut self) {
        let n = self.h.n;
        let d = self.damping;
        self.next.fill(0.0);
        match self.mode {
            Mode::HSerial => {
                for &(u, v) in &self.h.edges {
                    self.next[v as usize] += self.rank[u as usize] * self.inv_deg[u as usize];
                }
            }
            Mode::HAtomic => {
                let next = as_atomic_f64(&mut self.next);
                let rank = &self.rank;
                let inv = &self.inv_deg;
                let edges = &self.h.edges;
                parallel_ranges(edges.len(), |lo, hi| {
                    for &(u, v) in &edges[lo..hi] {
                        next[v as usize]
                            .fetch_add(rank[u as usize] * inv[u as usize], Ordering::Relaxed);
                    }
                });
            }
            Mode::HMerge => {
                // Per-worker private vectors; each worker processes a
                // contiguous Hilbert range (its own locality region),
                // merged at the end — "creates per-thread private vectors
                // to write updates to, and merges them at the end".
                let nt = num_threads();
                let privates: Vec<Mutex<Vec<f64>>> =
                    (0..nt).map(|_| Mutex::new(vec![0.0f64; n])).collect();
                let rank = &self.rank;
                let inv = &self.inv_deg;
                let edges = &self.h.edges;
                let chunk = edges.len().div_ceil(nt);
                crate::parallel::run_on_all(&|t| {
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(edges.len());
                    if lo >= hi {
                        return;
                    }
                    let mut mine = privates[t].lock().unwrap_or_else(|p| p.into_inner());
                    for &(u, v) in &edges[lo..hi] {
                        mine[v as usize] += rank[u as usize] * inv[u as usize];
                    }
                });
                // Merge (parallel over vertex ranges).
                let next = crate::parallel::UnsafeSlice::new(&mut self.next);
                let merged: Vec<Vec<f64>> =
                    privates.into_iter().map(|m| m.into_inner().unwrap()).collect();
                parallel_ranges(n, |lo, hi| {
                    for v in lo..hi {
                        let mut acc = 0.0;
                        for p in &merged {
                            acc += p[v];
                        }
                        // SAFETY: each v in lo..hi belongs to exactly one
                        // task's range; v < n == next.len().
                        unsafe { next.write(v, acc) };
                    }
                });
            }
        }
        let base = (1.0 - d) / n as f64;
        for v in 0..n {
            self.next[v] = base + d * self.next[v];
        }
        std::mem::swap(&mut self.rank, &mut self.next);
    }

    pub fn run(&mut self, iters: usize) -> Vec<f64> {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        self.rank.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn hilbert_index_is_bijection_small() {
        let order = 3; // 8x8
        let mut seen = std::collections::HashSet::new();
        for x in 0..8 {
            for y in 0..8 {
                let d = hilbert_index(order, x, y);
                assert!(d < 64);
                assert!(seen.insert(d), "duplicate index {d} at ({x},{y})");
            }
        }
    }

    #[test]
    fn hilbert_neighbors_are_close() {
        // Consecutive curve positions differ by one grid step: locality.
        let order = 4;
        let mut pts = vec![(0u32, 0u32); 256];
        for x in 0..16 {
            for y in 0..16 {
                pts[hilbert_index(order, x, y) as usize] = (x, y);
            }
        }
        for w in pts.windows(2) {
            let dx = w[0].0.abs_diff(w[1].0);
            let dy = w[0].1.abs_diff(w[1].1);
            assert_eq!(dx + dy, 1, "curve jump {w:?}");
        }
    }

    #[test]
    fn all_modes_match_reference() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 9);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 4);
        for mode in [Mode::HSerial, Mode::HAtomic, Mode::HMerge] {
            let got = Prepared::new(&g, &cfg, mode).run(4);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "{} v={i}: {a} vs {b}",
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn edge_count_preserved() {
        let (n, e) = generators::rmat(8, 4, generators::RmatParams::graph500(), 10);
        let g = Csr::from_edges(n, &e);
        let h = HilbertEdges::build(&g);
        assert_eq!(h.edges.len(), g.num_edges());
    }
}
