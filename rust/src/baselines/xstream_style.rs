//! X-Stream-shaped PageRank: "edge-centric graph processing using
//! streaming partitions". Each iteration is
//!
//! 1. **Scatter**: stream all edges, emitting `(dst, update)` pairs into
//!    per-destination-partition shuffle buffers (the `shuffle(E)` random
//!    DRAM traffic of Table 10),
//! 2. **Gather**: per partition, stream its update list and apply to the
//!    partition's vertex slice.
//!
//! Total traffic ≈ `3E + KV` (edges read, updates written then read).

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::parallel_for_dynamic;
use std::sync::Mutex;

/// Streaming-partitioned state.
pub struct Prepared {
    n: usize,
    k: usize,
    interval: usize,
    damping: f64,
    edges: Vec<(VertexId, VertexId)>,
    inv_deg: Vec<f64>,
    rank: Vec<f64>,
    next: Vec<f64>,
}

impl Prepared {
    pub fn new(g: &Csr, cfg: &SystemConfig) -> Prepared {
        // Partition count: vertex slice fits LLC share (X-Stream sizes
        // streaming partitions to cache).
        let n = g.num_vertices();
        let k = (n * 8).div_ceil((cfg.llc_bytes / 2).max(1)).max(1);
        Self::with_partitions(g, cfg, k)
    }

    pub fn with_partitions(g: &Csr, cfg: &SystemConfig, k: usize) -> Prepared {
        let n = g.num_vertices();
        let k = k.max(1);
        Prepared {
            n,
            k,
            interval: n.div_ceil(k),
            damping: cfg.damping,
            edges: g.edges().collect(),
            inv_deg: (0..n)
                .map(|v| {
                    let d = g.degree(v as VertexId);
                    if d == 0 {
                        0.0
                    } else {
                        1.0 / d as f64
                    }
                })
                .collect(),
            rank: vec![1.0 / n as f64; n],
            next: vec![0.0; n],
        }
    }

    pub fn reset(&mut self) {
        self.rank.fill(1.0 / self.n as f64);
    }

    pub fn step(&mut self) {
        let d = self.damping;
        let n = self.n;
        // Scatter: per-partition update logs, appended under per-partition
        // locks (X-Stream's shuffle buffers).
        let buffers: Vec<Mutex<Vec<(u32, f64)>>> =
            (0..self.k).map(|_| Mutex::new(Vec::new())).collect();
        {
            let rank = &self.rank;
            let inv = &self.inv_deg;
            let interval = self.interval;
            let edges = &self.edges;
            parallel_for_dynamic(edges.len(), 4096, |i| {
                let (u, v) = edges[i];
                let upd = rank[u as usize] * inv[u as usize];
                let part = v as usize / interval;
                buffers[part].lock().unwrap_or_else(|p| p.into_inner()).push((v, upd));
            });
        }
        // Gather: apply each partition's updates to its vertex slice.
        self.next.fill(0.0);
        {
            let next = crate::parallel::UnsafeSlice::new(&mut self.next);
            let bufs: Vec<Vec<(u32, f64)>> =
                buffers.into_iter().map(|m| m.into_inner().unwrap()).collect();
            parallel_for_dynamic(bufs.len(), 1, |p| {
                for &(v, upd) in &bufs[p] {
                    // SAFETY: partition p owns its destination interval,
                    // so no other task aliases v; v < n by shuffle
                    // construction.
                    unsafe {
                        *next.get_mut(v as usize) += upd;
                    }
                }
            });
        }
        let base = (1.0 - d) / n as f64;
        for v in 0..n {
            self.next[v] = base + d * self.next[v];
        }
        std::mem::swap(&mut self.rank, &mut self.next);
    }

    pub fn run(&mut self, iters: usize) -> Vec<f64> {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        self.rank.clone()
    }

    pub fn partitions(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn matches_reference() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 7);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let got = Prepared::with_partitions(&g, &cfg, 5).run(5);
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn single_partition_ok() {
        let (n, e) = generators::rmat(7, 4, generators::RmatParams::graph500(), 8);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let got = Prepared::with_partitions(&g, &cfg, 1).run(3);
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 3);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
