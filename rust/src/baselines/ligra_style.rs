//! Ligra-shaped PageRank: dense pull EdgeMap with the per-edge division
//! the paper's baseline removes ("Our PageRank baseline is faster than
//! Ligra's implementations because we calculated the contribution of each
//! vertex beforehand", §6.2). Vertex-count-balanced (not cost-balanced)
//! chunking, matching Ligra's default scheduling.

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for_dynamic, UnsafeSlice};

/// Preprocessed state.
pub struct Prepared {
    n: usize,
    damping: f64,
    pull: Csr,
    degree: Vec<u32>,
    rank: Vec<f64>,
    next: Vec<f64>,
}

impl Prepared {
    pub fn new(g: &Csr, cfg: &SystemConfig) -> Prepared {
        let n = g.num_vertices();
        Prepared {
            n,
            damping: cfg.damping,
            pull: g.transpose(),
            degree: g.out_degrees(),
            rank: vec![1.0 / n as f64; n],
            next: vec![0.0; n],
        }
    }

    pub fn reset(&mut self) {
        self.rank.fill(1.0 / self.n as f64);
    }

    /// One iteration: per-edge `rank[u] / degree[u]` (division in the
    /// inner loop — Ligra's Algorithm-1 shape).
    pub fn step(&mut self) {
        let n = self.n;
        let d = self.damping;
        let base = (1.0 - d) / n as f64;
        let pull = &self.pull;
        let rank = &self.rank;
        let degree = &self.degree;
        let next = UnsafeSlice::new(&mut self.next);
        parallel_for_dynamic(n, 256, |v| {
            let mut acc = 0.0;
            for &u in pull.neighbors(v as VertexId) {
                let du = degree[u as usize] as f64;
                if du > 0.0 {
                    acc += rank[u as usize] / du; // per-edge division
                }
            }
            // SAFETY: each v in lo..hi belongs to exactly one task's
            // range; v < n == next.len().
            unsafe { next.write(v, base + d * acc) };
        });
        std::mem::swap(&mut self.rank, &mut self.next);
    }

    pub fn run(&mut self, iters: usize) -> Vec<f64> {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        self.rank.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn matches_reference() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 3);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let got = Prepared::new(&g, &cfg).run(5);
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
