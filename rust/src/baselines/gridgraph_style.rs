//! GridGraph-shaped PageRank: "2-level hierarchical partitioning" — the
//! edge list is pre-sharded into a P×P grid of blocks (source-interval ×
//! destination-interval) and streamed block by block. Updates within a
//! block go to a shared output vector with **atomic adds** — the
//! `E·atomics` synchronization overhead in Table 10 ("atomic updates
//! which are 3x more expensive").

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::as_atomic_f64;
use crate::parallel::parallel_for_dynamic;
use std::sync::atomic::Ordering;

/// A grid-partitioned graph (preprocessing measured like Table 9's
/// GridGraph comparison; the paper notes GridGraph's own grid build took
/// 193 s for Twitter).
pub struct Grid {
    pub p: usize,
    pub n: usize,
    /// `blocks[i*p + j]` = edges with src ∈ interval i, dst ∈ interval j.
    pub blocks: Vec<Vec<(VertexId, VertexId)>>,
    pub interval: usize,
}

impl Grid {
    pub fn build(g: &Csr, p: usize) -> Grid {
        let n = g.num_vertices();
        let p = p.max(1);
        let interval = n.div_ceil(p);
        let mut blocks = vec![Vec::new(); p * p];
        for (u, v) in g.edges() {
            let i = u as usize / interval;
            let j = v as usize / interval;
            blocks[i * p + j].push((u, v));
        }
        Grid {
            p,
            n,
            blocks,
            interval,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }
}

/// Preprocessed GridGraph-style PageRank.
pub struct Prepared {
    grid: Grid,
    damping: f64,
    inv_deg: Vec<f64>,
    rank: Vec<f64>,
    next: Vec<f64>,
}

impl Prepared {
    /// `p` defaults to splitting vertex data into LLC-sized intervals
    /// (the paper: "the number of partitions suggested in the GridGraph
    /// paper gave the best performance, since our machine has a similar
    /// LLC size").
    pub fn new(g: &Csr, cfg: &SystemConfig) -> Prepared {
        let n = g.num_vertices();
        let p = (n * 8).div_ceil((cfg.llc_bytes / 2).max(1)).max(1);
        Self::with_partitions(g, cfg, p)
    }

    pub fn with_partitions(g: &Csr, cfg: &SystemConfig, p: usize) -> Prepared {
        let n = g.num_vertices();
        let inv_deg = (0..n)
            .map(|v| {
                let d = g.degree(v as VertexId);
                if d == 0 {
                    0.0
                } else {
                    1.0 / d as f64
                }
            })
            .collect();
        Prepared {
            grid: Grid::build(g, p),
            damping: cfg.damping,
            inv_deg,
            rank: vec![1.0 / n as f64; n],
            next: vec![0.0; n],
        }
    }

    pub fn reset(&mut self) {
        self.rank.fill(1.0 / self.grid.n as f64);
    }

    /// One iteration: stream grid blocks in column-major order (GridGraph
    /// streams so the destination interval stays cache-resident), atomic
    /// adds into the shared output.
    pub fn step(&mut self) {
        let n = self.grid.n;
        let d = self.damping;
        self.next.fill(0.0);
        {
            let next_atomic = as_atomic_f64(&mut self.next);
            let rank = &self.rank;
            let inv = &self.inv_deg;
            let p = self.grid.p;
            for j in 0..p {
                for i in 0..p {
                    let block = &self.grid.blocks[i * p + j];
                    // Parallel within a block; contended atomic adds.
                    parallel_for_dynamic(block.len(), 1024, |e| {
                        let (u, v) = block[e];
                        let contrib = rank[u as usize] * inv[u as usize];
                        next_atomic[v as usize].fetch_add(contrib, Ordering::Relaxed);
                    });
                }
            }
        }
        let base = (1.0 - d) / n as f64;
        for v in 0..n {
            self.next[v] = base + d * self.next[v];
        }
        std::mem::swap(&mut self.rank, &mut self.next);
    }

    pub fn run(&mut self, iters: usize) -> Vec<f64> {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        self.rank.clone()
    }

    pub fn partitions(&self) -> usize {
        self.grid.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn grid_partitions_every_edge_once() {
        let (n, e) = generators::rmat(8, 6, generators::RmatParams::graph500(), 5);
        let g = Csr::from_edges(n, &e);
        let grid = Grid::build(&g, 4);
        assert_eq!(grid.num_edges(), g.num_edges());
        for i in 0..4 {
            for j in 0..4 {
                for &(u, v) in &grid.blocks[i * 4 + j] {
                    assert_eq!(u as usize / grid.interval, i);
                    assert_eq!(v as usize / grid.interval, j);
                }
            }
        }
    }

    #[test]
    fn matches_reference() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 6);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let got = Prepared::with_partitions(&g, &cfg, 7).run(5);
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
