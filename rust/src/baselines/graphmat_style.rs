//! GraphMat-shaped PageRank: "a framework based on sparse matrix
//! operations" — vertex programs mapped onto a generic-semiring SpMV
//! (y = Aᵀ·x under (⊕,⊗)), plus per-vertex apply. The semiring
//! indirection (function-pointer-free generics here, but with GraphMat's
//! send/process/apply structure) is the "other framework overhead" the
//! paper's baseline strips (§6.2).

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for, parallel_for_dynamic, UnsafeSlice};

/// A GraphMat-style vertex program: messages from source vertex state,
/// ⊕-reduction, and an apply step.
pub trait VertexProgram: Sync {
    type State: Copy + Send + Sync;
    type Msg: Copy + Send + Sync;

    fn send(&self, state: &Self::State) -> Self::Msg;
    fn reduce(&self, a: Self::Msg, b: Self::Msg) -> Self::Msg;
    fn identity(&self) -> Self::Msg;
    fn apply(&self, v: VertexId, acc: Self::Msg, state: &Self::State) -> Self::State;
}

/// Run one SpMV-style superstep of `prog` over the pull CSR.
pub fn superstep<P: VertexProgram>(
    prog: &P,
    pull: &Csr,
    states: &[P::State],
    out: &mut [P::State],
) {
    let n = pull.num_vertices();
    assert_eq!(states.len(), n);
    assert_eq!(out.len(), n);
    let out_slice = UnsafeSlice::new(out);
    parallel_for_dynamic(n, 256, |v| {
        let mut acc = prog.identity();
        for &u in pull.neighbors(v as VertexId) {
            acc = prog.reduce(acc, prog.send(&states[u as usize]));
        }
        // SAFETY: each v in lo..hi belongs to exactly one task's range;
        // v < n == out_slice.len().
        unsafe { out_slice.write(v, prog.apply(v as VertexId, acc, &states[v])) };
    });
}

/// PageRank as a GraphMat vertex program.
pub struct PageRankProgram {
    pub damping: f64,
    pub n: f64,
}

impl VertexProgram for PageRankProgram {
    /// (rank, out_degree).
    type State = (f64, u32);
    type Msg = f64;

    fn send(&self, &(rank, deg): &Self::State) -> f64 {
        if deg == 0 {
            0.0
        } else {
            rank / deg as f64 // division at send, GraphMat's shape
        }
    }

    fn reduce(&self, a: f64, b: f64) -> f64 {
        a + b
    }

    fn identity(&self) -> f64 {
        0.0
    }

    fn apply(&self, _v: VertexId, acc: f64, &(_, deg): &Self::State) -> Self::State {
        ((1.0 - self.damping) / self.n + self.damping * acc, deg)
    }
}

/// Preprocessed GraphMat-style PageRank runner.
pub struct Prepared {
    prog: PageRankProgram,
    pull: Csr,
    states: Vec<(f64, u32)>,
    scratch: Vec<(f64, u32)>,
}

impl Prepared {
    pub fn new(g: &Csr, cfg: &SystemConfig) -> Prepared {
        let n = g.num_vertices();
        let degree = g.out_degrees();
        let states: Vec<(f64, u32)> = degree.iter().map(|&d| (1.0 / n as f64, d)).collect();
        Prepared {
            prog: PageRankProgram {
                damping: cfg.damping,
                n: n as f64,
            },
            pull: g.transpose(),
            scratch: states.clone(),
            states,
        }
    }

    pub fn reset(&mut self) {
        let n = self.states.len() as f64;
        let states = &mut self.states;
        parallel_for(states.len(), {
            let s = UnsafeSlice::new(states);
            // SAFETY: each i touches only its own slot; i < len.
            move |i| unsafe {
                s.get_mut(i).0 = 1.0 / n;
            }
        });
    }

    pub fn step(&mut self) {
        superstep(&self.prog, &self.pull, &self.states, &mut self.scratch);
        std::mem::swap(&mut self.states, &mut self.scratch);
    }

    pub fn run(&mut self, iters: usize) -> Vec<f64> {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        self.states.iter().map(|&(r, _)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn matches_reference() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 4);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let got = Prepared::new(&g, &cfg).run(5);
        let want = crate::apps::pagerank::reference(&g, cfg.damping, 5);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn generic_program_min_plus() {
        // A different semiring exercises the genericity: min-plus
        // relaxation step == one Bellman-Ford round.
        struct MinPlus;
        impl VertexProgram for MinPlus {
            type State = f64;
            type Msg = f64;
            fn send(&self, s: &f64) -> f64 {
                s + 1.0
            }
            fn reduce(&self, a: f64, b: f64) -> f64 {
                a.min(b)
            }
            fn identity(&self) -> f64 {
                f64::INFINITY
            }
            fn apply(&self, _v: VertexId, acc: f64, s: &f64) -> f64 {
                s.min(acc)
            }
        }
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let pull = g.transpose();
        let states = vec![0.0, f64::INFINITY, f64::INFINITY];
        let mut out = states.clone();
        superstep(&MinPlus, &pull, &states, &mut out);
        assert_eq!(out, vec![0.0, 1.0, f64::INFINITY]);
        let states = out.clone();
        let mut out2 = states.clone();
        superstep(&MinPlus, &pull, &states, &mut out2);
        assert_eq!(out2, vec![0.0, 1.0, 2.0]);
    }
}
