//! PageRank-Delta: the frontier-thinned PageRank variant the paper lists
//! alongside BC as an "activeness checking + unpredictable vertex data"
//! application (§6.1). Only vertices whose rank changed by more than
//! `epsilon` propagate updates in the next iteration.

use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::AtomicF64;
use crate::parallel::parallel_for;
use std::sync::atomic::Ordering;

/// Result of a PageRank-Delta run.
#[derive(Debug, Clone)]
pub struct DeltaResult {
    pub values: Vec<f64>,
    pub iterations: usize,
    /// Active-vertex count per iteration (shows frontier decay).
    pub active_history: Vec<usize>,
}

/// Run PageRank-Delta until no vertex moves more than `epsilon`, or
/// `max_iters`.
pub fn run(g: &Csr, cfg: &SystemConfig, epsilon: f64, max_iters: usize) -> DeltaResult {
    let n = g.num_vertices();
    let d = cfg.damping;
    let pull = g.transpose();
    let inv_deg: Vec<f64> = (0..n)
        .map(|v| {
            let deg = g.degree(v as VertexId);
            if deg == 0 {
                0.0
            } else {
                1.0 / deg as f64
            }
        })
        .collect();
    let mut rank = vec![(1.0 - d) / n as f64; n];
    // delta[u] = change in u's rank last iteration (still to propagate).
    let mut delta: Vec<f64> = rank.clone();
    let mut active: Vec<bool> = vec![true; n];
    let mut history = Vec::new();
    let mut iters = 0;
    while iters < max_iters {
        iters += 1;
        let nactive = active.iter().filter(|&&a| a).count();
        history.push(nactive);
        if nactive == 0 {
            break;
        }
        // Pull the active neighbors' deltas.
        let new_delta: Vec<AtomicF64> = (0..n).map(|_| AtomicF64::new(0.0)).collect();
        {
            let active = &active;
            let delta = &delta;
            let inv_deg = &inv_deg;
            let pull = &pull;
            let nd = &new_delta;
            parallel_for(n, |v| {
                let mut acc = 0.0;
                for &u in pull.neighbors(v as VertexId) {
                    if active[u as usize] {
                        acc += delta[u as usize] * inv_deg[u as usize];
                    }
                }
                if acc != 0.0 {
                    nd[v].store(d * acc, Ordering::Relaxed);
                }
            });
        }
        let mut any = false;
        for v in 0..n {
            let nd = new_delta[v].load(Ordering::Relaxed);
            rank[v] += nd;
            delta[v] = nd;
            let is_active = nd.abs() > epsilon * rank[v].abs().max(1e-300);
            active[v] = is_active;
            any |= is_active;
        }
        if !any {
            break;
        }
    }
    DeltaResult {
        values: rank,
        iterations: iters,
        active_history: history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn converges_and_frontier_decays() {
        let (n, e) = generators::rmat(10, 8, generators::RmatParams::graph500(), 99);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let r = run(&g, &cfg, 1e-4, 100);
        assert!(r.iterations < 100, "did not converge: {}", r.iterations);
        // Frontier shrinks (weakly) towards the end.
        let h = &r.active_history;
        assert!(h[h.len() - 1] <= h[0]);
        assert!(r.values.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn approximates_power_iteration() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 98);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let exact = crate::apps::pagerank::reference(&g, cfg.damping, 60);
        let approx = run(&g, &cfg, 1e-9, 200);
        // Ranking of the top vertices must agree.
        let top = |xs: &[f64]| {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
            idx.truncate(10);
            idx
        };
        assert_eq!(top(&exact), top(&approx.values));
    }
}
