//! PageRank-Delta: the frontier-thinned PageRank variant the paper lists
//! alongside BC as an "activeness checking + unpredictable vertex data"
//! application (§6.1). Only vertices whose rank changed by more than
//! `epsilon` propagate updates in the next iteration.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::AtomicF64;
use crate::parallel::parallel_for;
use crate::store::StoreCtx;
use anyhow::{bail, Result};
use std::sync::atomic::Ordering;

/// Execution variant. PageRank-Delta's cache behaviour is dominated by
/// the shrinking frontier itself, so a single configuration is measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Baseline,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
        }
    }
}

/// Result of a PageRank-Delta run.
#[derive(Debug, Clone)]
pub struct DeltaResult {
    pub values: Vec<f64>,
    pub iterations: usize,
    /// Active-vertex count per iteration (shows frontier decay).
    pub active_history: Vec<usize>,
}

/// Preprocessed PageRank-Delta state: the pull CSR and reciprocal
/// degrees are built once; [`Prepared::step`] runs one frontier-thinned
/// iteration and is a no-op once converged.
pub struct Prepared {
    damping: f64,
    epsilon: f64,
    pull: Csr,
    inv_deg: Vec<f64>,
    rank: Vec<f64>,
    /// Change in each vertex's rank last iteration (still to propagate).
    delta: Vec<f64>,
    active: Vec<bool>,
    /// Per-iteration accumulation buffer, allocated once and fully
    /// rewritten every [`Prepared::step`] (contents dead between steps).
    new_delta: Vec<AtomicF64>,
    iterations: usize,
    active_history: Vec<usize>,
}

/// `active_history` capacity reserved up front; recording **saturates**
/// at this many entries so steady-state `step()` can never reallocate,
/// no matter how many iterations a run takes (PageRank-Delta converges
/// in tens of iterations — a thousand entries more than tells the
/// frontier-decay story; `iterations` keeps exact count regardless).
const HISTORY_RESERVE: usize = 1024;

impl Prepared {
    pub fn new(g: &Csr, cfg: &SystemConfig, epsilon: f64) -> Prepared {
        let n = g.num_vertices();
        let d = cfg.damping;
        let pull = g.transpose();
        let inv_deg: Vec<f64> = (0..n)
            .map(|v| {
                let deg = g.degree(v as VertexId);
                if deg == 0 {
                    0.0
                } else {
                    1.0 / deg as f64
                }
            })
            .collect();
        let rank = vec![(1.0 - d) / n as f64; n];
        let delta = rank.clone();
        Prepared {
            damping: d,
            epsilon,
            pull,
            inv_deg,
            rank,
            delta,
            active: vec![true; n],
            new_delta: (0..n).map(|_| AtomicF64::new(0.0)).collect(),
            iterations: 0,
            active_history: Vec::with_capacity(HISTORY_RESERVE),
        }
    }

    /// All frontiers empty: no vertex moved more than `epsilon` last
    /// iteration, so further steps are no-ops.
    pub fn converged(&self) -> bool {
        self.active.iter().all(|&a| !a)
    }

    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Active-vertex count per iteration (saturates at `HISTORY_RESERVE`
    /// entries; [`Prepared::iterations`] stays exact).
    pub fn active_history(&self) -> &[usize] {
        &self.active_history
    }

    /// Current ranks (original id space; no reordering variant exists).
    pub fn values(&self) -> &[f64] {
        &self.rank
    }

    /// One frontier-thinned iteration: pull the active neighbors' deltas,
    /// apply, and recompute activeness. A true no-op once converged —
    /// neither `iterations` nor `active_history` advances.
    pub fn step(&mut self) {
        if self.converged() {
            return;
        }
        let n = self.rank.len();
        self.iterations += 1;
        if self.active_history.len() < HISTORY_RESERVE {
            self.active_history
                .push(self.active.iter().filter(|&&a| a).count());
        }
        let d = self.damping;
        {
            let active = &self.active;
            let delta = &self.delta;
            let inv_deg = &self.inv_deg;
            let pull = &self.pull;
            let nd = &self.new_delta;
            // Unconditional store: every slot is rewritten each step, so
            // the reused buffer never needs clearing (and can never leak
            // the previous iteration's values).
            parallel_for(n, |v| {
                let mut acc = 0.0;
                for &u in pull.neighbors(v as VertexId) {
                    if active[u as usize] {
                        acc += delta[u as usize] * inv_deg[u as usize];
                    }
                }
                // audit: relaxed-ok — each v writes only its own slot;
                // the sequential fold below runs after the join.
                nd[v].store(d * acc, Ordering::Relaxed);
            });
        }
        for v in 0..n {
            let nd = self.new_delta[v].load(Ordering::Relaxed);
            self.rank[v] += nd;
            self.delta[v] = nd;
            self.active[v] = nd.abs() > self.epsilon * self.rank[v].abs().max(1e-300);
        }
    }

    /// Test hook: garbage the dead per-iteration buffer (`new_delta` is
    /// fully rewritten by each step; rank/delta/active are live state).
    pub fn poison_scratch(&mut self, seed: u64) {
        for (i, x) in self.new_delta.iter().enumerate() {
            let junk = f64::from_bits(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            // audit: relaxed-ok — single-threaded test hook on a dead buffer.
            x.store(junk, Ordering::Relaxed);
        }
    }
}

impl PreparedApp for Prepared {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::Iterative
    }

    fn step(&mut self) {
        Prepared::step(self)
    }

    /// Accumulated rank mass.
    fn summary(&self) -> f64 {
        self.rank.iter().sum()
    }

    fn scratch_bytes(&self) -> usize {
        self.new_delta.len() * 8 + self.active_history.capacity() * 8
    }
}

/// Run PageRank-Delta until no vertex moves more than `epsilon`, or
/// `max_iters`.
pub fn run(g: &Csr, cfg: &SystemConfig, epsilon: f64, max_iters: usize) -> DeltaResult {
    let mut p = Prepared::new(g, cfg, epsilon);
    while p.iterations < max_iters {
        p.step();
        if p.converged() {
            break;
        }
    }
    DeltaResult {
        values: p.rank,
        iterations: p.iterations,
        active_history: p.active_history,
    }
}

/// Registry adapter: PageRank-Delta as a [`GraphApp`]. The convergence
/// threshold comes from `SystemConfig::delta_epsilon`.
pub struct App;

const VARIANTS: &[VariantInfo] = &[VariantInfo {
    name: "baseline",
    aliases: &[],
    kind: AppKind::PageRankDelta(Variant::Baseline),
}];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "pagerank-delta"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pagerank_delta", "pr-delta", "prdelta"]
    }

    fn description(&self) -> &'static str {
        "PageRank-Delta — frontier-thinned PageRank (activeness checks + random vertex reads)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::PageRankDelta(Variant::Baseline)
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        _store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::PageRankDelta(_) = kind else {
            bail!("pagerank-delta app handed foreign kind {kind:?}")
        };
        Ok(Box::new(Prepared::new(g, cfg, cfg.delta_epsilon)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn converges_and_frontier_decays() {
        let (n, e) = generators::rmat(10, 8, generators::RmatParams::graph500(), 99);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let r = run(&g, &cfg, 1e-4, 100);
        assert!(r.iterations < 100, "did not converge: {}", r.iterations);
        // Frontier shrinks (weakly) towards the end.
        let h = &r.active_history;
        assert!(h[h.len() - 1] <= h[0]);
        assert!(r.values.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn approximates_power_iteration() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 98);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let exact = crate::apps::pagerank::reference(&g, cfg.damping, 60);
        let approx = run(&g, &cfg, 1e-9, 200);
        // Ranking of the top vertices must agree.
        let top = |xs: &[f64]| {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| xs[b].total_cmp(&xs[a]));
            idx.truncate(10);
            idx
        };
        assert_eq!(top(&exact), top(&approx.values));
    }

    #[test]
    fn stepping_past_convergence_is_a_noop() {
        let (n, e) = generators::rmat(8, 8, generators::RmatParams::graph500(), 97);
        let g = Csr::from_edges(n, &e);
        let cfg = SystemConfig::default();
        let mut p = Prepared::new(&g, &cfg, 1e-3);
        while !p.converged() && p.iterations() < 200 {
            p.step();
        }
        assert!(p.converged());
        let frozen = p.values().to_vec();
        let iters = p.iterations();
        let hist_len = p.active_history().len();
        p.step();
        p.step();
        assert_eq!(p.values(), &frozen[..]);
        assert_eq!(p.iterations(), iters, "converged steps must not count");
        assert_eq!(p.active_history().len(), hist_len);
    }
}
