//! Collaborative Filtering: matrix factorization by gradient descent
//! (Table 3 — "Collaborative Filtering is only implemented in GraphMat").
//!
//! Each vertex (user or item) carries a K-dim latent vector; one training
//! iteration updates users from their rated items and then items from
//! their raters:
//!
//! `U_u ← U_u − lr · Σ_i (U_u·V_i − r_ui) V_i`
//!
//! The random stream is the neighbor latent-vector reads — K doubles per
//! edge, so "full cache lines are used for per-vertex latent factor
//! vectors, leaving little room for cache line utilization improvements"
//! (reordering helps little, §6.3) but segmenting still confines the
//! random reads (2x+ speedups, Table 3).
//!
//! Ratings are synthesized deterministically from the edge endpoints
//! (1..=5), so runs are reproducible without the (unavailable) Netflix
//! data.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for, parallel_for_cost, UnsafeSlice};
use crate::segment::SegmentedCsr;
use crate::store::{StoreCtx, StoreKey};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Deterministic synthetic rating for edge (u, i) in 1..=5.
#[inline]
pub fn rating(u: VertexId, i: VertexId) -> f64 {
    let h = (u as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((i as u64).wrapping_mul(0xC2B2AE3D27D4EB4F));
    (1 + (h >> 33) % 5) as f64
}

/// CF execution variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Direct edge sweep (GraphMat-style SpMV shape).
    Baseline,
    /// CSR-segmented: latent reads confined to LLC-sized segments.
    Segmented,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Segmented => "segmenting",
        }
    }
}

/// Model state: row-major `n × k` latent matrix.
#[derive(Debug, Clone)]
pub struct Factors {
    pub k: usize,
    pub data: Vec<f64>,
}

impl Factors {
    pub fn init(n: usize, k: usize, seed: u64) -> Factors {
        let mut rng = Rng::new(seed);
        let data = (0..n * k).map(|_| 0.5 * rng.next_f64() / k as f64 + 0.05).collect();
        Factors { k, data }
    }

    #[inline]
    pub fn row(&self, v: VertexId) -> &[f64] {
        &self.data[v as usize * self.k..(v as usize + 1) * self.k]
    }
}

/// Preprocessed CF trainer over a bipartite user→item graph.
pub struct Prepared {
    variant: Variant,
    k: usize,
    lr: f64,
    n: usize,
    /// Pull CSRs: items' raters / users' rated items.
    user_pull: Csr,
    item_pull: Csr,
    /// Segmented forms of the two pulls (source-segmented by the *read*
    /// side), when variant == Segmented. `Arc`-pinned: shared read-only
    /// across concurrent resident jobs.
    seg_user: Option<Arc<SegmentedCsr>>,
    seg_item: Option<Arc<SegmentedCsr>>,
    pub factors: Factors,
    grad: Vec<f64>,
}

impl Prepared {
    /// Run all preprocessing for `variant`. The two segmented partitions
    /// (the CF preprocessing cost) go through the persistent artifact
    /// store; a [`StoreCtx::disabled`] context just builds them.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let n = g.num_vertices();
        let k = cfg.cf_k;
        assert!(k <= 64, "cf_k > 64 unsupported (segment-local stack buffer)");
        // Users update by pulling from items: pull CSR = in-edges of users
        // = transpose of (item→user)... the graph is user→item, so users
        // pull over the forward CSR (their out-edges) and items pull over
        // the transpose.
        let user_pull = g.clone();
        let item_pull = g.transpose();
        let (seg_user, seg_item) = if variant == Variant::Segmented {
            let elem = 8 * k;
            let seg_size = cfg.segment_size(elem);
            let block = cfg.merge_block(elem);
            let seg_for = |pull: &Csr, label: &str| -> Arc<SegmentedCsr> {
                store.get_or_build_arc(
                    StoreKey::segmented(store.fingerprint, label, seg_size, block),
                    || SegmentedCsr::build_with_block(&pull.transpose(), seg_size, block),
                )
            };
            (
                Some(seg_for(&user_pull, "cf-user")),
                Some(seg_for(&item_pull, "cf-item")),
            )
        } else {
            (None, None)
        };
        Prepared {
            variant,
            k,
            lr: cfg.cf_lr,
            n,
            user_pull,
            item_pull,
            seg_user,
            seg_item,
            factors: Factors::init(n, k, 0xCF),
            grad: vec![0.0; n * k],
        }
    }

    /// Sum of squared errors over all ratings (for loss curves).
    pub fn sse(&self) -> f64 {
        let k = self.k;
        let f = &self.factors;
        crate::parallel::parallel_reduce(
            self.n,
            || 0.0f64,
            |acc, u| {
                let mut acc = acc;
                let fu = f.row(u as VertexId);
                for &i in self.user_pull.neighbors(u as VertexId) {
                    let fi = f.row(i);
                    let pred: f64 = fu.iter().zip(fi).map(|(a, b)| a * b).sum();
                    let e = pred - rating(u as VertexId, i);
                    acc += e * e;
                }
                let _ = k;
                acc
            },
            |a, b| a + b,
        )
    }

    pub fn rmse(&self) -> f64 {
        let m = self.user_pull.num_edges().max(1);
        (self.sse() / m as f64).sqrt()
    }

    /// One training iteration: user phase then item phase.
    pub fn step(&mut self) {
        self.phase(/*users=*/ true);
        self.phase(/*users=*/ false);
    }

    /// One half-iteration: update one side's factors by pulling the other
    /// side's vectors.
    fn phase(&mut self, users: bool) {
        let k = self.k;
        let n = self.n;
        // Gradient accumulation into self.grad, then apply.
        self.grad.fill(0.0);
        match self.variant {
            Variant::Baseline => {
                let pull = if users { &self.user_pull } else { &self.item_pull };
                let f = &self.factors;
                let grad = UnsafeSlice::new(&mut self.grad);
                let cost = crate::graph::degree_prefix(pull);
                let total = *cost.last().unwrap();
                let threshold =
                    (total / (8 * crate::parallel::num_threads() as u64).max(1)).max(128);
                parallel_for_cost(
                    n,
                    threshold,
                    |lo, hi| cost[hi] - cost[lo],
                    |lo, hi| {
                        for v in lo..hi {
                            let fv = f.row(v as VertexId);
                            for &w in pull.neighbors(v as VertexId) {
                                let fw = f.row(w); // random K-double read
                                let pred: f64 = fv.iter().zip(fw).map(|(a, b)| a * b).sum();
                                let r = if users {
                                    rating(v as VertexId, w)
                                } else {
                                    rating(w, v as VertexId)
                                };
                                let e = pred - r;
                                for (j, &fwj) in fw.iter().enumerate() {
                                    // SAFETY: row v is written by exactly
                                    // one task (v in lo..hi), and
                                    // v*k+j < n*k == grad.len().
                                    unsafe {
                                        *grad.get_mut(v * k + j) += e * fwj;
                                    }
                                }
                            }
                        }
                    },
                );
            }
            Variant::Segmented => {
                // Per-segment pass: destination rows' gradients accumulate
                // segment-locally, then a vector-valued cache-aware merge.
                let sg = if users {
                    self.seg_user.as_ref().unwrap()
                } else {
                    self.seg_item.as_ref().unwrap()
                };
                let f = &self.factors;
                let grad = UnsafeSlice::new(&mut self.grad);
                for seg in &sg.segments {
                    let nd = seg.num_dsts();
                    let total = seg.num_edges() as u64;
                    let threshold =
                        (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(64);
                    parallel_for_cost(
                        nd,
                        threshold,
                        |lo, hi| seg.offsets[hi] - seg.offsets[lo],
                        |lo, hi| {
                            for idx in lo..hi {
                                let v = seg.dst_ids[idx];
                                let fv = f.row(v);
                                let e0 = seg.offsets[idx] as usize;
                                let e1 = seg.offsets[idx + 1] as usize;
                                let mut acc = [0.0f64; 64];
                                let acc = &mut acc[..k];
                                for &w in &seg.sources[e0..e1] {
                                    let fw = f.row(w); // random read, segment-confined
                                    let pred: f64 =
                                        fv.iter().zip(fw.iter()).map(|(a, b)| a * b).sum();
                                    let r = if users { rating(v, w) } else { rating(w, v) };
                                    let e = pred - r;
                                    for (a, &fwj) in acc.iter_mut().zip(fw.iter()) {
                                        *a += e * fwj;
                                    }
                                }
                                for (j, &aj) in acc.iter().enumerate() {
                                    // SAFETY: destination rows may repeat
                                    // across segments, but each (segment,
                                    // dst) pair is unique, dst index idx
                                    // belongs to one task, and segments
                                    // run sequentially — so no two tasks
                                    // alias row v within a pass; v*k+j <
                                    // n*k == grad.len().
                                    unsafe {
                                        *grad.get_mut(v as usize * k + j) += aj;
                                    }
                                }
                            }
                        },
                    );
                }
            }
        }
        // Apply: F -= lr * grad.
        let lr = self.lr;
        let f = UnsafeSlice::new(&mut self.factors.data);
        let grad = &self.grad;
        parallel_for(n, |v| {
            for j in 0..k {
                // SAFETY: each v updates only row v of the factor matrix;
                // v*k+j < n*k == f.len().
                unsafe {
                    *f.get_mut(v * k + j) -= lr * grad[v * k + j];
                }
            }
        });
    }

    pub fn num_edges(&self) -> usize {
        self.user_pull.num_edges()
    }
}

impl PreparedApp for Prepared {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::Iterative
    }

    fn step(&mut self) {
        Prepared::step(self)
    }

    /// RMSE over all ratings after the iterations run so far.
    fn summary(&self) -> f64 {
        self.rmse()
    }
}

/// Registry adapter: Collaborative Filtering as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Cf(Variant::Baseline),
    },
    VariantInfo {
        name: "segmenting",
        aliases: &["segment", "optimized"],
        kind: AppKind::Cf(Variant::Segmented),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "cf"
    }

    fn description(&self) -> &'static str {
        "Collaborative Filtering — gradient-descent matrix factorization (K-double latent rows)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Cf(Variant::Segmented)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        kind == AppKind::Cf(Variant::Segmented)
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Cf(v) = kind else {
            bail!("cf app handed foreign kind {kind:?}")
        };
        Ok(Box::new(Prepared::prepare(g, cfg, v, store)))
    }
}

/// Preprocess + train for `iters` iterations; returns final RMSE.
pub fn run(g: &Csr, cfg: &SystemConfig, variant: Variant, iters: usize) -> (Prepared, f64) {
    let mut p = Prepared::prepare(g, cfg, variant, &StoreCtx::disabled());
    for _ in 0..iters {
        p.step();
    }
    let rmse = p.rmse();
    (p, rmse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn bipartite() -> Csr {
        let (n, edges) = generators::bipartite_zipf(600, 80, 6_000, 1.1, 9);
        let mut b = crate::graph::CsrBuilder::new(n);
        b.extend(edges);
        b.build()
    }

    #[test]
    fn training_reduces_rmse() {
        let g = bipartite();
        let mut cfg = SystemConfig::default();
        cfg.cf_lr = 5e-3;
        let mut p = Prepared::prepare(&g, &cfg, Variant::Baseline, &StoreCtx::disabled());
        let before = p.rmse();
        for _ in 0..12 {
            p.step();
        }
        let after = p.rmse();
        assert!(after < before, "rmse {before} -> {after}");
        assert!(after.is_finite());
    }

    #[test]
    fn segmented_matches_baseline() {
        let g = bipartite();
        let mut cfg = SystemConfig::default();
        cfg.llc_bytes = 16 * 1024; // force multiple segments (K=8 → 128 ids)
        let mut a = Prepared::prepare(&g, &cfg, Variant::Baseline, &StoreCtx::disabled());
        let mut b = Prepared::prepare(&g, &cfg, Variant::Segmented, &StoreCtx::disabled());
        for _ in 0..3 {
            a.step();
            b.step();
        }
        for (x, y) in a.factors.data.iter().zip(&b.factors.data) {
            assert!(
                (x - y).abs() <= 1e-9 * x.abs().max(1.0),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn ratings_deterministic_and_in_range() {
        for u in 0..100u32 {
            for i in 0..20u32 {
                let r = rating(u, i);
                assert!((1.0..=5.0).contains(&r));
                assert_eq!(r, rating(u, i));
            }
        }
    }

    #[test]
    fn k_larger_than_eight_supported() {
        let g = bipartite();
        let mut cfg = SystemConfig::default();
        cfg.cf_k = 16;
        let mut p = Prepared::prepare(&g, &cfg, Variant::Segmented, &StoreCtx::disabled());
        p.step();
        assert!(p.rmse().is_finite());
    }
}
