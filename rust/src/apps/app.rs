//! The unified application API: every workload is a [`GraphApp`] that the
//! coordinator drives through one generic pipeline.
//!
//! The paper's framing is that frequency-based clustering (§3) and CSR
//! segmenting (§4) are *framework-level* techniques that "can be easily
//! implemented on top of optimized graph frameworks" — which only holds if
//! applications plug into the framework through a single surface instead
//! of being hand-wired into the coordinator. This module is that surface:
//!
//! - [`AppKind`] — a fully-parsed (app, variant) pair. Each application
//!   keeps its own typed variant enum (`pagerank::Variant`,
//!   `bc::Variant`, ...); `AppKind` is the closed union the pipeline and
//!   `JobSpec` carry around.
//! - [`GraphApp`] — the dyn-compatible application object: name/aliases,
//!   the variant table ([`VariantInfo`]) that drives CLI parsing and
//!   `cagra apps`, the artifact-store policy ([`GraphApp::uses_store`]),
//!   and [`GraphApp::prepare`], which runs all preprocessing and returns a
//!   ready-to-execute [`PreparedApp`].
//! - [`PreparedApp`] + [`ExecutionShape`] — how the generic job loop
//!   drives a prepared instance: iterative apps expose `step()` (one
//!   iteration per call), per-source apps expose `run_source()` (one full
//!   traversal per call), and every app reports a scalar `summary()` for
//!   smoke-checking runs.
//!
//! The registry of all implementations lives in
//! [`crate::apps::registry`]; `run_job` never matches on a concrete app.

use crate::cache::StallEstimate;
use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::store::StoreCtx;
use anyhow::{bail, Result};

use super::{bc, bfs, cc, cf, pagerank, pagerank_delta, sssp, triangle};

/// A fully-parsed application + variant. This is what `JobSpec` carries
/// and what every [`GraphApp`] method receives; each app interprets only
/// its own arm (the registry guarantees it is never handed another's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    PageRank(pagerank::Variant),
    PageRankDelta(pagerank_delta::Variant),
    Cf(cf::Variant),
    Bc(bc::Variant),
    Bfs(bfs::Variant),
    Sssp(sssp::Variant),
    Cc(cc::Variant),
    Triangle(triangle::Variant),
}

impl AppKind {
    /// Canonical registry name of the app this kind belongs to.
    pub fn app_name(self) -> &'static str {
        match self {
            AppKind::PageRank(_) => "pagerank",
            AppKind::PageRankDelta(_) => "pagerank-delta",
            AppKind::Cf(_) => "cf",
            AppKind::Bc(_) => "bc",
            AppKind::Bfs(_) => "bfs",
            AppKind::Sssp(_) => "sssp",
            AppKind::Cc(_) => "cc",
            AppKind::Triangle(_) => "triangle",
        }
    }

    /// Display name of the variant (the app's own `Variant::name()`).
    pub fn variant_name(self) -> &'static str {
        match self {
            AppKind::PageRank(v) => v.name(),
            AppKind::PageRankDelta(v) => v.name(),
            AppKind::Cf(v) => v.name(),
            AppKind::Bc(v) => v.name(),
            AppKind::Bfs(v) => v.name(),
            AppKind::Sssp(v) => v.name(),
            AppKind::Cc(v) => v.name(),
            AppKind::Triangle(v) => v.name(),
        }
    }

    /// Parse `--app` / `--variant` strings through the registry.
    pub fn parse(app: &str, variant: &str) -> Result<AppKind> {
        super::registry::parse(app, variant)
    }
}

/// One row of an app's variant table: the canonical CLI spelling, the
/// accepted aliases, and the parsed kind. `cagra apps`, `AppKind::parse`,
/// and the round-trip tests all read the same table, so help text cannot
/// drift from what the parser accepts.
#[derive(Debug, Clone, Copy)]
pub struct VariantInfo {
    /// Canonical variant name (always parseable).
    pub name: &'static str,
    /// Additional accepted spellings (including the display name when it
    /// differs from the canonical CLI one, e.g. "reordering+segmenting").
    pub aliases: &'static [&'static str],
    /// The parsed (app, variant) pair.
    pub kind: AppKind,
}

/// How the generic job loop drives a [`PreparedApp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionShape {
    /// `step()` runs one iteration; the loop calls it `JobSpec::iters`
    /// times (PageRank, PageRank-Delta, CF, CC).
    Iterative,
    /// `run_source(src)` runs one full traversal; the loop calls it once
    /// per source from [`default_sources`] (BFS, BC, SSSP).
    PerSource,
    /// The degenerate case: all work happens at prepare time and the
    /// result is already in `summary()` (Triangle Counting). The loop
    /// executes nothing, so per-iteration metrics stay empty instead of
    /// timing no-ops into a bogus throughput figure.
    OneShot,
}

/// A preprocessed, ready-to-execute application instance. Construction
/// (via [`GraphApp::prepare`]) performs all preprocessing — reordering,
/// segmenting, transposes — so the pipeline can time preprocessing and
/// execution separately (paper Table 9 vs Tables 2–5).
pub trait PreparedApp {
    /// Which of the two driver loops this instance expects.
    fn shape(&self) -> ExecutionShape;

    /// One iteration ([`ExecutionShape::Iterative`] apps only).
    fn step(&mut self) {
        panic!("step() called on a per-source app");
    }

    /// One traversal from `source`, in **original** vertex-id space
    /// ([`ExecutionShape::PerSource`] apps only). Results accumulate
    /// across calls (BC sums dependency scores, BFS sums reached counts).
    fn run_source(&mut self, source: VertexId) {
        let _ = source;
        panic!("run_source() called on an iterative app");
    }

    /// Deterministic scalar summary of everything executed so far (rank
    /// L1 mass, RMSE, reached count, max centrality, ...). Finite and
    /// nonzero on any non-degenerate run; used for smoke checks and the
    /// warm-vs-cold bitwise store invariants.
    fn summary(&self) -> f64;

    /// Bytes of reusable execution scratch this instance holds so its
    /// steady state allocates nothing — engine [`EngineScratch`] pools,
    /// per-source atomic arrays, per-segment buffers. Excludes the graph
    /// structures themselves. Surfaced in `Metrics` so the memory cost of
    /// preallocation is visible, not guessed; 0 means the app has no
    /// reusable scratch (one-shot apps).
    ///
    /// [`EngineScratch`]: crate::engine::EngineScratch
    fn scratch_bytes(&self) -> usize {
        0
    }
}

/// A registered application. Implementations are zero-sized adapter
/// structs (`pagerank::App`, `bc::App`, ...) listed in
/// [`crate::apps::registry::APPS`]; the trait is dyn-compatible so the
/// coordinator can hold `&'static dyn GraphApp` and stay app-agnostic.
pub trait GraphApp: Sync {
    /// Canonical registry name (`cagra run --app <name>`).
    fn name(&self) -> &'static str;

    /// Accepted alternative app names.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `cagra apps`.
    fn description(&self) -> &'static str;

    /// The variant table: every variant this app can run, with parse
    /// aliases. The table is the single source of truth for CLI parsing,
    /// help output, and sweep enumeration.
    fn variants(&self) -> &'static [VariantInfo];

    /// The variant used when the CLI gives none (each app's "optimized"
    /// configuration by convention).
    fn default_variant(&self) -> AppKind;

    /// Whether `prepare` would route preprocessing artifacts through the
    /// persistent store for this variant. The pipeline skips opening the
    /// store (and fingerprinting the graph) entirely when this is false,
    /// so `--store` adds no overhead or misleading 0-hit stats to
    /// variants that do no cacheable preprocessing.
    fn uses_store(&self, kind: AppKind) -> bool {
        let _ = kind;
        false
    }

    /// Run all preprocessing for `kind` and return the executable
    /// instance. `store` persists/fetches preprocessing artifacts (the
    /// Table 9 amortization); pass [`StoreCtx::disabled`] for the
    /// no-store path — same code path, the builders just always run.
    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>>;

    /// Simulated memory-system stall estimate for one representative
    /// execution unit under `kind`, if this app supports analysis
    /// (`JobSpec::analyze_memory`).
    fn simulate(&self, g: &Csr, cfg: &SystemConfig, kind: AppKind) -> Option<StallEstimate> {
        let _ = (g, cfg, kind);
        None
    }

    /// Parse a variant string against [`GraphApp::variants`].
    fn parse_variant(&self, variant: &str) -> Result<AppKind> {
        for info in self.variants() {
            if info.name == variant || info.aliases.iter().any(|&a| a == variant) {
                return Ok(info.kind);
            }
        }
        let known: Vec<&str> = self.variants().iter().map(|i| i.name).collect();
        bail!(
            "unknown {} variant {variant:?} (expected one of: {})",
            self.name(),
            known.join("|")
        )
    }
}

/// Deterministic source selection for per-source apps: the paper's
/// evaluation uses "12 different starting points"; we pick the `count`
/// highest-degree vertices (original ids). Shared by BFS, BC, and SSSP so
/// every per-source job is comparable.
pub fn default_sources(g: &Csr, count: usize) -> Vec<VertexId> {
    let mut by_degree: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
    by_degree.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    by_degree.truncate(count);
    by_degree
}
