//! The evaluation's applications (§6.1 "Applications"), unified behind
//! one API.
//!
//! Every workload implements [`GraphApp`] (defined in [`app`]) and is
//! listed in [`registry::APPS`]; the coordinator's `run_job`, the CLI,
//! and the benches drive all of them through the same
//! prepare → execute → summarize pipeline, so the paper's cache
//! optimizations stay framework-level instead of per-app wiring:
//!
//! - [`pagerank`] — iterative, activeness-free, dominated by random
//!   vertex reads (the running example).
//! - [`pagerank_delta`] — PageRank-Delta (frontier-thinned PageRank;
//!   activeness checks + unpredictable vertex reads).
//! - [`cf`] — Collaborative Filtering: matrix factorization by gradient
//!   descent; full cache lines per vertex (K-double latent vectors).
//! - [`bc`] — Betweenness Centrality (Brandes): frontier-driven with
//!   activeness checks + random vertex reads.
//! - [`bfs`] — Breadth-First Search: activeness-only, smallest working
//!   set.
//! - [`sssp`] — single-source shortest paths (Bellman–Ford over
//!   frontiers), the class BC represents.
//! - [`triangle`] — Triangle Counting (degree-ordered, activeness-free).
//! - [`cc`] — Connected Components via min-label propagation through the
//!   generic SegmentedEdgeMap (the §4.4 associative-commutative claim).
//!
//! Each module contributes: its typed `Variant` enum, a `Prepared`
//! execution state (preprocessing separated from iteration, Table 9), a
//! serial reference implementation for the golden tests, and a zero-sized
//! `App` adapter implementing [`GraphApp`].

pub mod app;
pub mod registry;

pub mod pagerank;
pub mod cf;
pub mod bc;
pub mod bfs;
pub mod sssp;
pub mod pagerank_delta;
pub mod triangle;
pub mod cc;

pub use app::{default_sources, AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
