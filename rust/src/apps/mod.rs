//! The evaluation's applications (§6.1 "Applications"):
//!
//! - [`pagerank`] — iterative, activeness-free, dominated by random vertex
//!   reads (the running example).
//! - [`cf`] — Collaborative Filtering: matrix factorization by gradient
//!   descent; full cache lines per vertex (K-double latent vectors).
//! - [`bc`] — Betweenness Centrality (Brandes): frontier-driven with
//!   activeness checks + random vertex reads.
//! - [`bfs`] — Breadth-First Search: activeness-only, smallest working
//!   set.
//! - [`sssp`] — single-source shortest paths (Bellman–Ford over
//!   frontiers), the class BC represents.
//! - [`pagerank_delta`] — PageRank-Delta (frontier-thinned PageRank).
//! - [`triangle`] — Triangle Counting (degree-ordered, activeness-free).
//! - [`cc`] — Connected Components via min-label propagation through the
//!   generic SegmentedEdgeMap (the §4.4 associative-commutative claim).

pub mod pagerank;
pub mod cf;
pub mod bc;
pub mod bfs;
pub mod sssp;
pub mod pagerank_delta;
pub mod triangle;
pub mod cc;
