//! Connected Components by min-label propagation — the §4.4 claim made
//! concrete: "Segmenting can be applied in any graph algorithm that
//! aggregates data over the neighbors of each vertex using an associative
//! and commutative operation". CC's aggregation is `min`, so the whole
//! app is a loop around the generic [`segmented_edge_map`].
//!
//! Components are computed over the *undirected* view (labels flow both
//! ways), matching the usual CC definition on these datasets.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::coordinator::SystemConfig;
use crate::engine::segmented_edge_map;
use crate::graph::{Csr, CsrBuilder, VertexId};
use crate::segment::{SegmentBuffers, SegmentedCsr};
use crate::store::{StoreCtx, StoreKey};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Store label for CC's symmetrized working structures. Both variants key
/// off this: the segmented partition as a segmented artifact, the
/// baseline's pull CSR with a `-pull` suffix. The label is CC-specific
/// (unlike the degree-sort permutation, no other app consumes the
/// symmetrized view today).
const SYM_LABEL: &str = "cc-sym";

/// CC execution variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Direct pull sweeps over the symmetrized CSR.
    Baseline,
    /// Sweeps through the generic SegmentedEdgeMap.
    Segmented,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Segmented => "segmenting",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[Variant::Baseline, Variant::Segmented]
    }
}

/// Result labels: `labels[v]` = min vertex id in v's component.
#[derive(Debug, Clone)]
pub struct CcResult {
    pub labels: Vec<VertexId>,
    pub iterations: usize,
    pub num_components: usize,
}

/// Symmetrize a digraph (used by both variants and by tests).
pub fn symmetrize(g: &Csr) -> Csr {
    let mut b = CsrBuilder::new(g.num_vertices());
    for (u, v) in g.edges() {
        b.add_edge(u, v);
        b.add_edge(v, u);
    }
    b.build()
}

/// Preprocessed CC state: the symmetrized view (and its segmented or
/// pull form) is built once; [`Prepared::sweep`] runs one min-label
/// propagation pass.
pub struct Prepared {
    variant: Variant,
    /// Symmetrized segmented partition, `Arc`-pinned: shared read-only
    /// across concurrent resident jobs (`cagra serve`).
    seg: Option<Arc<SegmentedCsr>>,
    /// Per-segment intermediate label buffers, built once and reused by
    /// every [`Prepared::sweep`] (the sweep fully rewrites them — their
    /// contents between sweeps are dead). Owned per job, never shared.
    seg_bufs: Option<SegmentBuffers<VertexId>>,
    pull: Option<Arc<Csr>>,
    labels: Vec<VertexId>,
    next: Vec<VertexId>,
    iterations: usize,
    converged: bool,
}

impl Prepared {
    /// Run all preprocessing for `variant`. The symmetrized working
    /// structure goes through the persistent store: a cold run
    /// symmetrizes and builds (then persists) the variant's iteration
    /// structure — the segmented partition of the symmetrized graph for
    /// [`Variant::Segmented`], its transposed pull CSR for
    /// [`Variant::Baseline`] — and a warm run loads it (mapped in place
    /// where possible), performing zero `symmetrize`/partition work (the
    /// last uncached O(|E|) preprocessing named in ROADMAP.md). A
    /// [`StoreCtx::disabled`] context is the no-store path. The
    /// intermediate symmetrized out-CSR is never persisted: iterations
    /// only ever read the derived structure, so caching the intermediate
    /// would decode as much as it skips.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let n = g.num_vertices();
        let seg = match variant {
            Variant::Segmented => {
                let seg_size = cfg.segment_size(4);
                let block = cfg.merge_block(4);
                let sg = store.get_or_build_arc(
                    StoreKey::segmented(store.fingerprint, SYM_LABEL, seg_size, block),
                    || SegmentedCsr::build_with_block(&symmetrize(g), seg_size, block),
                );
                // Decoded artifacts are structurally validated by the
                // codec but not against the live graph.
                assert_eq!(sg.num_vertices, n, "cc segmented artifact dimension mismatch");
                Some(sg)
            }
            Variant::Baseline => None,
        };
        let pull = match variant {
            Variant::Baseline => {
                let pull_label = format!("{SYM_LABEL}-pull");
                let p = store.get_or_build_arc(
                    StoreKey::ordering(store.fingerprint, &pull_label),
                    || symmetrize(g).transpose(),
                );
                assert_eq!(p.num_vertices(), n, "cc pull artifact dimension mismatch");
                Some(p)
            }
            Variant::Segmented => None,
        };
        let seg_bufs: Option<SegmentBuffers<VertexId>> =
            seg.as_ref().map(|sg| SegmentBuffers::with_fill(sg, 0));
        Prepared {
            variant,
            seg,
            seg_bufs,
            pull,
            labels: (0..n as VertexId).collect(),
            next: vec![0 as VertexId; n],
            iterations: 0,
            converged: false,
        }
    }

    /// One propagation sweep; returns whether any label changed.
    pub fn sweep(&mut self) -> bool {
        let n = self.labels.len();
        self.iterations += 1;
        match self.variant {
            Variant::Segmented => {
                let sg = self.seg.as_ref().unwrap();
                let bufs = self.seg_bufs.as_mut().unwrap();
                let l = &self.labels;
                segmented_edge_map(
                    sg,
                    |u| l[u as usize],
                    |a, b| a.min(b),
                    VertexId::MAX,
                    bufs,
                    &mut self.next,
                );
            }
            Variant::Baseline => {
                let p = self.pull.as_ref().unwrap();
                let l = &self.labels;
                let slice = crate::parallel::UnsafeSlice::new(&mut self.next);
                crate::parallel::parallel_for(n, |v| {
                    let mut m = VertexId::MAX;
                    for &u in p.neighbors(v as VertexId) {
                        m = m.min(l[u as usize]);
                    }
                    // SAFETY: each v in lo..hi belongs to exactly one
                    // task's range; v < n == slice.len().
                    unsafe { slice.write(v, m) };
                });
            }
        }
        // Apply: label = min(own, best neighbor); detect fixpoint.
        let mut changed = false;
        for v in 0..n {
            let cand = self.next[v].min(self.labels[v]);
            if cand != self.labels[v] {
                self.labels[v] = cand;
                changed = true;
            }
        }
        self.converged = !changed;
        changed
    }

    /// Current labels (min vertex id seen per component so far).
    pub fn labels(&self) -> &[VertexId] {
        &self.labels
    }

    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Components implied by the current labels (exact once converged).
    pub fn num_components(&self) -> usize {
        self.labels
            .iter()
            .enumerate()
            .filter(|&(v, &l)| l as usize == v)
            .count()
    }

    /// Test hook: garbage every dead buffer — `next` and the per-segment
    /// buffers are fully rewritten by each sweep (`labels` is live state
    /// and stays untouched).
    pub fn poison_scratch(&mut self, seed: u64) {
        for (i, x) in self.next.iter_mut().enumerate() {
            *x = (seed as u32).wrapping_add(i as u32).wrapping_mul(2654435761);
        }
        if let Some(bufs) = &mut self.seg_bufs {
            for buf in &mut bufs.per_segment {
                for (i, x) in buf.iter_mut().enumerate() {
                    *x = (seed as u32) ^ (i as u32).wrapping_mul(0x9E3779B9);
                }
            }
        }
    }
}

impl PreparedApp for Prepared {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::Iterative
    }

    fn step(&mut self) {
        if !self.converged {
            self.sweep();
        }
    }

    /// Number of components implied by the labels so far (≥ 1 on any
    /// nonempty graph).
    fn summary(&self) -> f64 {
        self.num_components() as f64
    }

    fn scratch_bytes(&self) -> usize {
        self.next.len() * 4 + self.seg_bufs.as_ref().map_or(0, |b| b.bytes())
    }
}

/// Registry adapter: Connected Components as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Cc(Variant::Baseline),
    },
    VariantInfo {
        name: "segmenting",
        aliases: &["segment", "optimized"],
        kind: AppKind::Cc(Variant::Segmented),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn description(&self) -> &'static str {
        "Connected Components — min-label propagation through the generic SegmentedEdgeMap (§4.4)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Cc(Variant::Segmented)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        // Unlike the frontier apps' baselines, CC's baseline still does
        // O(|E|) preprocessing (symmetrize + transpose), so both variants
        // have an artifact worth persisting.
        matches!(kind, AppKind::Cc(_))
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Cc(v) = kind else {
            bail!("cc app handed foreign kind {kind:?}")
        };
        Ok(Box::new(Prepared::prepare(g, cfg, v, store)))
    }
}

/// Run CC until the labels stop changing.
pub fn run(g: &Csr, cfg: &SystemConfig, variant: Variant, max_iters: usize) -> CcResult {
    let mut p = Prepared::prepare(g, cfg, variant, &StoreCtx::disabled());
    while p.iterations < max_iters {
        if !p.sweep() {
            break;
        }
    }
    let num_components = p.num_components();
    CcResult {
        labels: p.labels,
        iterations: p.iterations,
        num_components,
    }
}

/// Serial union-find reference.
pub fn reference(g: &Csr) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for (u, v) in g.edges() {
        let ru = find(&mut parent, u);
        let rv = find(&mut parent, v);
        if ru != rv {
            parent[ru.max(rv) as usize] = ru.min(rv);
        }
    }
    // Normalize: label = min id in component.
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    #[test]
    fn two_components() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let cfg = SystemConfig::default();
        for v in [Variant::Baseline, Variant::Segmented] {
            let r = run(&g, &cfg, v, 100);
            assert_eq!(r.labels, vec![0, 0, 0, 3, 3], "{v:?}");
            assert_eq!(r.num_components, 2);
        }
    }

    #[test]
    fn matches_union_find_on_rmat() {
        let (n, e) = generators::rmat(10, 4, generators::RmatParams::graph500(), 44);
        let g = Csr::from_edges(n, &e);
        let want = reference(&g);
        let cfg = SystemConfig {
            llc_bytes: 32 * 1024, // force several segments
            ..Default::default()
        };
        for v in [Variant::Baseline, Variant::Segmented] {
            let r = run(&g, &cfg, v, 1000);
            assert_eq!(r.labels, want, "{v:?}");
        }
    }

    #[test]
    fn prop_variants_agree_and_match_reference() {
        check("cc segmented == baseline == union-find", 10, |gen| {
            let (n, edges) = gen.edges(2..120, 2);
            let g = Csr::from_edges(n, &edges);
            let want = reference(&g);
            let cfg = SystemConfig {
                llc_bytes: 1024,
                ..Default::default()
            };
            for v in [Variant::Baseline, Variant::Segmented] {
                let r = run(&g, &cfg, v, 10 * n + 10);
                assert_eq!(r.labels, want, "{v:?}");
            }
        });
    }

    #[test]
    fn isolated_vertices_self_labeled() {
        let g = Csr::from_edges(3, &[]);
        let r = run(&g, &SystemConfig::default(), Variant::Segmented, 10);
        assert_eq!(r.labels, vec![0, 1, 2]);
        assert_eq!(r.num_components, 3);
    }
}
