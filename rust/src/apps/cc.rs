//! Connected Components by min-label propagation — the §4.4 claim made
//! concrete: "Segmenting can be applied in any graph algorithm that
//! aggregates data over the neighbors of each vertex using an associative
//! and commutative operation". CC's aggregation is `min`, so the whole
//! app is a loop around the generic [`segmented_edge_map`].
//!
//! Components are computed over the *undirected* view (labels flow both
//! ways), matching the usual CC definition on these datasets.

use crate::coordinator::SystemConfig;
use crate::engine::segmented_edge_map;
use crate::graph::{Csr, CsrBuilder, VertexId};
use crate::segment::SegmentedCsr;

/// CC execution variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Direct pull sweeps over the symmetrized CSR.
    Baseline,
    /// Sweeps through the generic SegmentedEdgeMap.
    Segmented,
}

/// Result labels: `labels[v]` = min vertex id in v's component.
#[derive(Debug, Clone)]
pub struct CcResult {
    pub labels: Vec<VertexId>,
    pub iterations: usize,
    pub num_components: usize,
}

/// Symmetrize a digraph (used by both variants and by tests).
pub fn symmetrize(g: &Csr) -> Csr {
    let mut b = CsrBuilder::new(g.num_vertices());
    for (u, v) in g.edges() {
        b.add_edge(u, v);
        b.add_edge(v, u);
    }
    b.build()
}

/// Run CC until the labels stop changing.
pub fn run(g: &Csr, cfg: &SystemConfig, variant: Variant, max_iters: usize) -> CcResult {
    let n = g.num_vertices();
    let sym = symmetrize(g);
    let seg = match variant {
        Variant::Segmented => Some(SegmentedCsr::build_with_block(
            &sym,
            cfg.segment_size(4),
            cfg.merge_block(4),
        )),
        Variant::Baseline => None,
    };
    let pull = match variant {
        Variant::Baseline => Some(sym.transpose()),
        Variant::Segmented => None,
    };
    let mut labels: Vec<VertexId> = (0..n as VertexId).collect();
    let mut next = vec![0 as VertexId; n];
    let mut iterations = 0;
    while iterations < max_iters {
        iterations += 1;
        match variant {
            Variant::Segmented => {
                let sg = seg.as_ref().unwrap();
                let l = &labels;
                segmented_edge_map(sg, |u| l[u as usize], |a, b| a.min(b), VertexId::MAX, &mut next);
            }
            Variant::Baseline => {
                let p = pull.as_ref().unwrap();
                let l = &labels;
                let slice = crate::parallel::UnsafeSlice::new(&mut next);
                crate::parallel::parallel_for(n, |v| {
                    let mut m = VertexId::MAX;
                    for &u in p.neighbors(v as VertexId) {
                        m = m.min(l[u as usize]);
                    }
                    unsafe { slice.write(v, m) };
                });
            }
        }
        // Apply: label = min(own, best neighbor); detect fixpoint.
        let mut changed = false;
        for v in 0..n {
            let cand = next[v].min(labels[v]);
            if cand != labels[v] {
                labels[v] = cand;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let mut num_components = 0;
    for (v, &l) in labels.iter().enumerate() {
        if l as usize == v {
            num_components += 1;
        }
    }
    CcResult {
        labels,
        iterations,
        num_components,
    }
}

/// Serial union-find reference.
pub fn reference(g: &Csr) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    for (u, v) in g.edges() {
        let ru = find(&mut parent, u);
        let rv = find(&mut parent, v);
        if ru != rv {
            parent[ru.max(rv) as usize] = ru.min(rv);
        }
    }
    // Normalize: label = min id in component.
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    #[test]
    fn two_components() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let cfg = SystemConfig::default();
        for v in [Variant::Baseline, Variant::Segmented] {
            let r = run(&g, &cfg, v, 100);
            assert_eq!(r.labels, vec![0, 0, 0, 3, 3], "{v:?}");
            assert_eq!(r.num_components, 2);
        }
    }

    #[test]
    fn matches_union_find_on_rmat() {
        let (n, e) = generators::rmat(10, 4, generators::RmatParams::graph500(), 44);
        let g = Csr::from_edges(n, &e);
        let want = reference(&g);
        let cfg = SystemConfig {
            llc_bytes: 32 * 1024, // force several segments
            ..Default::default()
        };
        for v in [Variant::Baseline, Variant::Segmented] {
            let r = run(&g, &cfg, v, 1000);
            assert_eq!(r.labels, want, "{v:?}");
        }
    }

    #[test]
    fn prop_variants_agree_and_match_reference() {
        check("cc segmented == baseline == union-find", 10, |gen| {
            let (n, edges) = gen.edges(2..120, 2);
            let g = Csr::from_edges(n, &edges);
            let want = reference(&g);
            let cfg = SystemConfig {
                llc_bytes: 1024,
                ..Default::default()
            };
            for v in [Variant::Baseline, Variant::Segmented] {
                let r = run(&g, &cfg, v, 10 * n + 10);
                assert_eq!(r.labels, want, "{v:?}");
            }
        });
    }

    #[test]
    fn isolated_vertices_self_labeled() {
        let g = Csr::from_edges(3, &[]);
        let r = run(&g, &SystemConfig::default(), Variant::Segmented, 10);
        assert_eq!(r.labels, vec![0, 1, 2]);
        assert_eq!(r.num_components, 3);
    }
}
