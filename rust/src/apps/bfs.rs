//! Breadth-First Search (Table 5): direction-optimizing over the engine's
//! push/pull EdgeMap, with the optional bitvector frontier and vertex
//! reordering variants measured in §6.3 / Table 8.
//!
//! The `Prepared` state owns all per-traversal working memory — the
//! parent array and the engine's [`EngineScratch`] — so repeated
//! `run_source` calls perform zero heap allocation once the first
//! traversal has sized the scratch pools (asserted by
//! `tests/zero_alloc.rs`).

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::cache::StallEstimate;
use crate::coordinator::SystemConfig;
use crate::engine::{edge_map, EdgeMapOpts, EngineScratch, VertexSubset};
use crate::graph::{Csr, VertexId};
use crate::reorder;
use crate::store::StoreCtx;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// BFS optimization mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Ligra-style direction-optimizing BFS (the Table 5 baseline).
    Baseline,
    /// + degree reordering.
    Reordered,
    /// + bitvector frontier ("using bitvector to keep track of the
    ///   active vertices set", §6.3).
    Bitvector,
    /// + both (Tables 7/8's best row).
    ReorderedBitvector,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Reordered => "reordering",
            Variant::Bitvector => "bitvector",
            Variant::ReorderedBitvector => "reordering+bitvector",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[
            Variant::Baseline,
            Variant::Reordered,
            Variant::Bitvector,
            Variant::ReorderedBitvector,
        ]
    }

    fn reordered(self) -> bool {
        matches!(self, Variant::Reordered | Variant::ReorderedBitvector)
    }

    fn bitvector(self) -> bool {
        matches!(self, Variant::Bitvector | Variant::ReorderedBitvector)
    }
}

/// Preprocessed BFS state (reordering happens once; Table 9), plus the
/// reusable traversal buffers (allocated once; every buffer is reset —
/// not re-allocated — at the start of each traversal).
pub struct Prepared {
    variant: Variant,
    g: Csr,
    g_in: Csr,
    /// Permutation old→new when reordered, `Arc`-pinned (shared
    /// read-only across concurrent resident jobs).
    perm: Option<Arc<crate::store::ArcSlice<VertexId>>>,
    inv: Option<Vec<VertexId>>,
    /// Working-id-space parent array, reset (fill, no alloc) per source.
    parent: Vec<AtomicU32>,
    scratch: EngineScratch,
}

impl Prepared {
    /// Run all preprocessing for `variant`. The reordering permutation
    /// goes through the persistent store (same ordering key as PageRank
    /// and BC, so the artifact is shared across apps on the same
    /// dataset); a [`StoreCtx::disabled`] context is the no-store path.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let (work, perm) = if variant.reordered() {
            let perm = reorder::cached_degree_sort_perm(g, cfg.coarsen, store);
            (g.relabel(&perm), Some(perm))
        } else {
            (g.clone(), None)
        };
        let g_in = work.transpose();
        let inv = perm.as_ref().map(|p| reorder::invert(p));
        let n = work.num_vertices();
        Prepared {
            variant,
            g: work,
            g_in,
            perm,
            inv,
            parent: (0..n).map(|_| AtomicU32::new(u32::MAX)).collect(),
            scratch: EngineScratch::new(n),
        }
    }

    /// Map an original-space vertex id into the working (possibly
    /// reordered) id space.
    fn working_id(&self, v: VertexId) -> VertexId {
        match &self.perm {
            Some(p) => p[v as usize],
            None => v,
        }
    }

    /// BFS from `src` (working id space) into the owned parent array.
    /// Allocation-free after the first traversal.
    fn run_inner(&mut self, src: VertexId) {
        let n = self.g.num_vertices();
        let parent = &self.parent;
        // audit: relaxed-ok — each v writes only its own slot, and the
        // traversal starts after the parallel_for joins (a full barrier).
        crate::parallel::parallel_for(n, |v| parent[v].store(u32::MAX, Ordering::Relaxed));
        // audit: relaxed-ok — single-threaded setup before the traversal.
        parent[src as usize].store(src, Ordering::Relaxed);
        let scratch = &mut self.scratch;
        let mut frontier = {
            let mut ids = scratch.take_ids();
            ids.push(src);
            VertexSubset::from_ids(n, ids)
        };
        let opts = EdgeMapOpts {
            bitvector_frontier: self.variant.bitvector(),
            ..Default::default()
        };
        while !frontier.is_empty() {
            let next = edge_map(
                &self.g,
                &self.g_in,
                &frontier,
                |s, d| {
                    parent[d as usize]
                        .compare_exchange(u32::MAX, s, Ordering::Relaxed, Ordering::Relaxed)
                        .is_ok()
                },
                |d| parent[d as usize].load(Ordering::Relaxed) == u32::MAX,
                opts,
                scratch,
            );
            scratch.recycle(std::mem::replace(&mut frontier, next));
        }
        scratch.recycle(frontier);
    }

    /// BFS from `source` (original id). Returns parents in original id
    /// space (`u32::MAX` = unreached; source's parent is itself).
    ///
    /// This convenience API materializes a result vector; the
    /// steady-state pipeline path ([`PreparedBfs::run_source`]) stays on
    /// the allocation-free internal buffers instead.
    pub fn run(&mut self, source: VertexId) -> Vec<VertexId> {
        let src = self.working_id(source);
        self.run_inner(src);
        let n = self.g.num_vertices();
        let raw: Vec<VertexId> = self
            .parent
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect();
        // Map back to original ids.
        match (&self.perm, &self.inv) {
            (Some(_p), Some(inv)) => {
                let mut out = vec![u32::MAX; n];
                for new in 0..n {
                    let old = inv[new] as usize;
                    let pn = raw[new];
                    out[old] = if pn == u32::MAX { u32::MAX } else { inv[pn as usize] };
                }
                out
            }
            _ => raw,
        }
    }

    /// Test hook: garbage every dead buffer (see
    /// [`EngineScratch::poison`]; the parent array is reset at the start
    /// of each traversal, so it is dead between sources too).
    pub fn poison_scratch(&mut self, seed: u64) {
        self.scratch.poison(seed);
        for (i, p) in self.parent.iter().enumerate() {
            // audit: relaxed-ok — single-threaded test hook on a dead buffer.
            p.store((seed as u32).wrapping_add(i as u32), Ordering::Relaxed);
        }
    }

    fn reusable_bytes(&self) -> usize {
        self.scratch.peak_bytes() + self.parent.len() * 4
    }
}

/// [`PreparedApp`] adapter: accumulates the reached-vertex count across
/// `run_source` calls.
pub struct PreparedBfs {
    prep: Prepared,
    reached: u64,
}

impl PreparedApp for PreparedBfs {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::PerSource
    }

    fn run_source(&mut self, source: VertexId) {
        let src = self.prep.working_id(source);
        self.prep.run_inner(src);
        // Reached count is permutation-invariant: count in working space.
        self.reached += self
            .prep
            .parent
            .iter()
            .filter(|p| p.load(Ordering::Relaxed) != u32::MAX)
            .count() as u64;
    }

    /// Total vertices reached over all sources run so far.
    fn summary(&self) -> f64 {
        self.reached as f64
    }

    fn scratch_bytes(&self) -> usize {
        self.prep.reusable_bytes()
    }
}

/// Registry adapter: BFS as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Bfs(Variant::Baseline),
    },
    VariantInfo {
        name: "reordering",
        aliases: &["reorder"],
        kind: AppKind::Bfs(Variant::Reordered),
    },
    VariantInfo {
        name: "bitvector",
        aliases: &[],
        kind: AppKind::Bfs(Variant::Bitvector),
    },
    VariantInfo {
        name: "both",
        aliases: &["optimized", "reordering+bitvector"],
        kind: AppKind::Bfs(Variant::ReorderedBitvector),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn description(&self) -> &'static str {
        "Breadth-First Search — direction-optimizing, activeness-only (smallest working set)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Bfs(Variant::ReorderedBitvector)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        matches!(kind, AppKind::Bfs(v) if v.reordered())
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Bfs(v) = kind else {
            bail!("bfs app handed foreign kind {kind:?}")
        };
        Ok(Box::new(PreparedBfs {
            prep: Prepared::prepare(g, cfg, v, store),
            reached: 0,
        }))
    }

    /// One pull sweep: frontier membership plus the 4-byte parent probe —
    /// the smallest per-vertex payload of the frontier apps (Table 8).
    fn simulate(&self, g: &Csr, cfg: &SystemConfig, kind: AppKind) -> Option<StallEstimate> {
        let AppKind::Bfs(v) = kind else { return None };
        Some(crate::cache::stall::simulate_frontier_app(
            g,
            cfg.llc_bytes,
            4,
            v.reordered(),
            v.bitvector(),
        ))
    }
}

/// Serial reference BFS (visit order irrelevant; only reachability/level
/// equivalence is checked).
pub fn reference_levels(g: &Csr, source: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    let mut level = vec![u32::MAX; n];
    level[source as usize] = 0;
    let mut queue = std::collections::VecDeque::from([source]);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if level[v as usize] == u32::MAX {
                level[v as usize] = level[u as usize] + 1;
                queue.push_back(v);
            }
        }
    }
    level
}

/// Levels implied by a parent array (for validation).
pub fn levels_from_parents(g: &Csr, source: VertexId, parents: &[VertexId]) -> Vec<u32> {
    let n = g.num_vertices();
    let mut level = vec![u32::MAX; n];
    for v in 0..n {
        if parents[v] == u32::MAX {
            continue;
        }
        // Walk up to the source.
        let mut cur = v as VertexId;
        let mut steps = 0u32;
        while cur != source && steps <= n as u32 {
            cur = parents[cur as usize];
            steps += 1;
        }
        level[v] = if cur == source { steps } else { u32::MAX };
    }
    level
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn graph() -> Csr {
        let (n, e) = generators::rmat(10, 8, generators::RmatParams::graph500(), 77);
        Csr::from_edges(n, &e)
    }

    #[test]
    fn all_variants_match_reference_levels() {
        let g = graph();
        let source = (0..g.num_vertices())
            .max_by_key(|&v| g.degree(v as u32))
            .unwrap() as VertexId;
        let want = reference_levels(&g, source);
        for &v in Variant::all() {
            let mut p = Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
            let parents = p.run(source);
            let got = levels_from_parents(&g, source, &parents);
            assert_eq!(got, want, "{}", v.name());
        }
    }

    #[test]
    fn repeated_runs_reuse_scratch_identically() {
        let g = graph();
        let source = (0..g.num_vertices())
            .max_by_key(|&v| g.degree(v as u32))
            .unwrap() as VertexId;
        let want = reference_levels(&g, source);
        let mut p = Prepared::prepare(
            &g,
            &SystemConfig::default(),
            Variant::ReorderedBitvector,
            &StoreCtx::disabled(),
        );
        for round in 0..3 {
            p.poison_scratch(0xB5 + round);
            let parents = p.run(source);
            assert_eq!(
                levels_from_parents(&g, source, &parents),
                want,
                "round {round}"
            );
        }
    }

    #[test]
    fn unreachable_marked() {
        // 0 -> 1; 2 isolated.
        let g = Csr::from_edges(3, &[(0, 1)]);
        let mut p =
            Prepared::prepare(&g, &SystemConfig::default(), Variant::Baseline, &StoreCtx::disabled());
        let parents = p.run(0);
        assert_eq!(parents[0], 0);
        assert_eq!(parents[1], 0);
        assert_eq!(parents[2], u32::MAX);
    }

    #[test]
    fn parent_edges_exist() {
        let g = graph();
        let mut p = Prepared::prepare(
            &g,
            &SystemConfig::default(),
            Variant::ReorderedBitvector,
            &StoreCtx::disabled(),
        );
        let parents = p.run(3);
        for v in 0..g.num_vertices() {
            let pv = parents[v];
            if pv != u32::MAX && pv as usize != v {
                assert!(
                    g.neighbors(pv).contains(&(v as u32)),
                    "claimed parent edge {pv}->{v} missing"
                );
            }
        }
    }
}
