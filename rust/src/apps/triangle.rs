//! Triangle counting — the §6.1 "no activeness checking" class PageRank
//! represents, and the prior use of degree reordering the paper cites
//! ([27]: "reordering vertices by degree has been used for reducing
//! asymptotic running time for high performance Triangle Counting").
//!
//! Algorithm: orient each undirected edge from lower- to higher-rank
//! endpoint under the degree order, then count per-vertex sorted-list
//! intersections. Degree orientation bounds the out-degree, which is why
//! the reordering *is* the asymptotic optimization here.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::coordinator::SystemConfig;
use crate::graph::{Csr, VertexId};
use crate::parallel::parallel_reduce;
use crate::store::StoreCtx;
use anyhow::{bail, Result};

/// Execution variant. Degree orientation *is* the optimization here (it
/// bounds the out-degree), so there is a single configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    DegreeOrdered,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::DegreeOrdered => "degree-ordered",
        }
    }
}

/// Count triangles in the undirected version of `g`.
pub fn count(g: &Csr) -> u64 {
    let n = g.num_vertices();
    // Build the undirected, deduped adjacency.
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(g.num_edges() * 2);
    for (u, v) in g.edges() {
        if u != v {
            edges.push((u.min(v), u.max(v)));
        }
    }
    edges.sort_unstable();
    edges.dedup();
    // Degree rank (by undirected degree, ties by id).
    let mut deg = vec![0u32; n];
    for &(u, v) in &edges {
        deg[u as usize] += 1;
        deg[v as usize] += 1;
    }
    let rank_of = |v: VertexId| (deg[v as usize], v);
    // Orient each edge from lower rank to higher rank.
    let oriented: Vec<(VertexId, VertexId)> = edges
        .iter()
        .map(|&(u, v)| {
            if rank_of(u) < rank_of(v) {
                (u, v)
            } else {
                (v, u)
            }
        })
        .collect();
    let fwd = Csr::from_edges(n, &oriented).sorted();
    // For every oriented edge (u,v): count |N+(u) ∩ N+(v)|.
    parallel_reduce(
        n,
        || 0u64,
        |acc, u| {
            let mut acc = acc;
            let nu = fwd.neighbors(u as VertexId);
            for &v in nu {
                acc += intersect_count(nu, fwd.neighbors(v));
            }
            acc
        },
        |a, b| a + b,
    )
}

/// |a ∩ b| for sorted slices.
fn intersect_count(a: &[VertexId], b: &[VertexId]) -> u64 {
    let mut i = 0;
    let mut j = 0;
    let mut c = 0;
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// [`PreparedApp`] adapter. Triangle counting is
/// [`ExecutionShape::OneShot`]: the count is computed at prepare time
/// (orientation + sorting dominate, i.e. the work *is* preprocessing),
/// the driver loop executes nothing, and `summary()` is final from the
/// start. `step()` is overridden as a no-op so a caller driving this
/// like an iterative app cannot panic or recount.
pub struct PreparedTriangle {
    count: u64,
}

impl PreparedApp for PreparedTriangle {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::OneShot
    }

    fn step(&mut self) {}

    /// The triangle count.
    fn summary(&self) -> f64 {
        self.count as f64
    }
}

/// Registry adapter: Triangle Counting as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[VariantInfo {
    name: "degree-ordered",
    aliases: &["baseline", "optimized"],
    kind: AppKind::Triangle(Variant::DegreeOrdered),
}];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "triangle"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["tc"]
    }

    fn description(&self) -> &'static str {
        "Triangle Counting — degree-oriented sorted-intersection (one-shot, activeness-free)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Triangle(Variant::DegreeOrdered)
    }

    fn prepare(
        &self,
        g: &Csr,
        _cfg: &SystemConfig,
        kind: AppKind,
        _store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Triangle(_) = kind else {
            bail!("triangle app handed foreign kind {kind:?}")
        };
        Ok(Box::new(PreparedTriangle { count: count(g) }))
    }
}

/// O(V³)-ish brute force for tests.
pub fn reference(g: &Csr) -> u64 {
    let n = g.num_vertices();
    let mut adj = vec![vec![false; n]; n];
    for (u, v) in g.edges() {
        if u != v {
            adj[u as usize][v as usize] = true;
            adj[v as usize][u as usize] = true;
        }
    }
    let mut c = 0;
    for a in 0..n {
        for b in a + 1..n {
            if !adj[a][b] {
                continue;
            }
            c += (b + 1..n).filter(|&d| adj[a][d] && adj[b][d]).count() as u64;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    #[test]
    fn known_small_cases() {
        // Triangle.
        let g = Csr::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        assert_eq!(count(&g), 1);
        // K4 has 4 triangles.
        let k4 = Csr::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert_eq!(count(&k4), 4);
        // Square (no diagonal) has none.
        let sq = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        assert_eq!(count(&sq), 0);
    }

    #[test]
    fn duplicate_and_reverse_edges_ignored() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 0), (0, 2)]);
        assert_eq!(count(&g), 1);
    }

    #[test]
    fn prop_matches_brute_force() {
        check("triangle count == brute force", 12, |gen| {
            let (n, edges) = gen.edges(3..40, 3);
            let g = Csr::from_edges(n, &edges);
            assert_eq!(count(&g), reference(&g));
        });
    }

    #[test]
    fn rmat_plausible() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 13);
        let g = Csr::from_edges(n, &e);
        let t = count(&g);
        // Power-law graphs have many triangles; sanity range only.
        assert!(t > 0);
    }
}
