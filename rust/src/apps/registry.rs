//! The application registry: the one list every pipeline entry point —
//! `run_job`, the CLI (`cagra run`, `cagra apps`), and the benches —
//! resolves apps through. Registering an app here is the *only* step
//! needed to make a new workload reachable from the whole toolchain.

use super::app::{AppKind, GraphApp};
use super::{bc, bfs, cc, cf, pagerank, pagerank_delta, sssp, triangle};
use anyhow::{bail, Result};

/// All registered applications — the paper's §6.1 suite, complete.
pub static APPS: &[&'static dyn GraphApp] = &[
    &pagerank::App,
    &pagerank_delta::App,
    &cf::App,
    &bc::App,
    &bfs::App,
    &sssp::App,
    &cc::App,
    &triangle::App,
];

/// Look an app up by canonical name or alias.
pub fn find(name: &str) -> Option<&'static dyn GraphApp> {
    APPS.iter()
        .copied()
        .find(|a| a.name() == name || a.aliases().iter().any(|&al| al == name))
}

/// The registered app a parsed [`AppKind`] belongs to. Infallible by
/// construction: every `AppKind` arm names a registered app.
pub fn app_for(kind: AppKind) -> &'static dyn GraphApp {
    find(kind.app_name()).expect("every AppKind maps to a registered app")
}

/// Parse `--app` / `--variant` strings into an [`AppKind`].
pub fn parse(app: &str, variant: &str) -> Result<AppKind> {
    match find(app) {
        Some(a) => a.parse_variant(variant),
        None => {
            let names: Vec<&str> = APPS.iter().map(|a| a.name()).collect();
            bail!("unknown app {app:?} (expected one of: {})", names.join("|"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn eight_apps_registered_with_unique_names() {
        assert_eq!(APPS.len(), 8);
        let mut seen = HashSet::new();
        for app in APPS {
            assert!(seen.insert(app.name()), "duplicate app name {}", app.name());
            for alias in app.aliases() {
                assert!(seen.insert(alias), "alias {alias} collides");
            }
            assert!(!app.variants().is_empty(), "{} has no variants", app.name());
        }
    }

    #[test]
    fn default_variant_is_advertised() {
        for app in APPS {
            let d = app.default_variant();
            assert!(
                app.variants().iter().any(|v| v.kind == d),
                "{}: default variant not in variants() table",
                app.name()
            );
        }
    }

    #[test]
    fn find_resolves_names_and_aliases() {
        assert_eq!(find("pagerank").unwrap().name(), "pagerank");
        assert_eq!(find("pr").unwrap().name(), "pagerank");
        assert_eq!(find("tc").unwrap().name(), "triangle");
        assert!(find("nope").is_none());
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(parse("nope", "baseline").is_err());
        assert!(parse("pagerank", "nope").is_err());
    }

    #[test]
    fn store_policy_matches_preprocessing_cost() {
        // The pipeline only opens/fingerprints the store for variants that
        // do cacheable preprocessing. CC preprocesses (symmetrize) in BOTH
        // variants; frontier baselines and PageRank's baseline do nothing
        // cacheable and must skip the store entirely.
        for &v in cc::Variant::all() {
            let kind = AppKind::Cc(v);
            assert!(app_for(kind).uses_store(kind), "cc/{v:?} must use the store");
        }
        for kind in [
            AppKind::Bfs(bfs::Variant::Baseline),
            AppKind::Bc(bc::Variant::Baseline),
            AppKind::PageRank(pagerank::Variant::Baseline),
        ] {
            assert!(!app_for(kind).uses_store(kind), "{kind:?} must skip the store");
        }
        let both = AppKind::PageRank(pagerank::Variant::ReorderedSegmented);
        assert!(app_for(both).uses_store(both));
    }
}
