//! Single-source shortest paths (frontier-based Bellman–Ford) — the §6.1
//! "applications that involve vertices' activeness checking" class that
//! Betweenness Centrality represents. Edge weights are synthesized
//! deterministically (1..=16) from the endpoints.
//!
//! The `Prepared` state owns the distance array and the engine's
//! [`EngineScratch`], so repeated `run_source` calls allocate nothing
//! once the first traversal has sized the scratch pools.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::cache::StallEstimate;
use crate::coordinator::SystemConfig;
use crate::engine::{edge_map, EdgeMapOpts, EngineScratch, VertexSubset};
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::AtomicF64;
use crate::reorder;
use crate::store::StoreCtx;
use anyhow::{bail, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Deterministic edge weight in 1..=16.
#[inline]
pub fn weight(u: VertexId, v: VertexId) -> f64 {
    let h = (u as u64)
        .wrapping_mul(0xA24BAED4963EE407)
        .wrapping_add((v as u64).wrapping_mul(0x9FB21C651E98DF25));
    (1 + (h >> 56) % 16) as f64
}

/// Optimization mix (reordering only; SSSP's frontier churn defeats
/// segmenting, like BFS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Baseline,
    Reordered,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Reordered => "reordering",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[Variant::Baseline, Variant::Reordered]
    }
}

/// Preprocessed SSSP state plus reusable traversal buffers (reset, never
/// re-allocated, per source).
pub struct Prepared {
    g: Csr,
    g_in: Csr,
    /// Permutation old→new when reordered, `Arc`-pinned (shared
    /// read-only across concurrent resident jobs).
    perm: Option<Arc<crate::store::ArcSlice<VertexId>>>,
    inv: Option<Vec<VertexId>>,
    /// Working-id-space distances, reset per source.
    dist: Vec<AtomicF64>,
    scratch: EngineScratch,
}

impl Prepared {
    /// Run all preprocessing for `variant`. The reordering permutation
    /// goes through the persistent store — the same degree-sort key
    /// PageRank/BC/BFS share, so any of them warms the others on the same
    /// dataset. A [`StoreCtx::disabled`] context is the no-store path.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let (work, perm) = match variant {
            Variant::Reordered => {
                let perm = reorder::cached_degree_sort_perm(g, cfg.coarsen, store);
                (g.relabel(&perm), Some(perm))
            }
            Variant::Baseline => (g.clone(), None),
        };
        let g_in = work.transpose();
        let inv = perm.as_ref().map(|p| reorder::invert(p));
        let n = work.num_vertices();
        Prepared {
            g: work,
            g_in,
            perm,
            inv,
            dist: (0..n).map(|_| AtomicF64::new(f64::INFINITY)).collect(),
            scratch: EngineScratch::new(n),
        }
    }

    /// Map an original-space vertex id into the working (possibly
    /// reordered) id space.
    fn working_id(&self, v: VertexId) -> VertexId {
        match &self.perm {
            Some(p) => p[v as usize],
            None => v,
        }
    }

    /// Bellman–Ford from `src` (working id space) into the owned distance
    /// array. Allocation-free after the first traversal.
    fn run_inner(&mut self, src: VertexId) {
        let n = self.g.num_vertices();
        let dist = &self.dist;
        // audit: relaxed-ok — each v writes only its own slot, and the
        // traversal starts after the parallel_for joins (a full barrier).
        crate::parallel::parallel_for(n, |v| dist[v].store(f64::INFINITY, Ordering::Relaxed));
        // audit: relaxed-ok — single-threaded setup before the traversal.
        dist[src as usize].store(0.0, Ordering::Relaxed);
        // Weight of working-space edge (s,d) = weight of original edge.
        let inv = &self.inv;
        let orig = |v: VertexId| -> VertexId {
            match inv {
                Some(inv) => inv[v as usize],
                None => v,
            }
        };
        let scratch = &mut self.scratch;
        let mut frontier = {
            let mut ids = scratch.take_ids();
            ids.push(src);
            VertexSubset::from_ids(n, ids)
        };
        let mut rounds = 0usize;
        while !frontier.is_empty() && rounds <= n {
            rounds += 1;
            let next = edge_map(
                &self.g,
                &self.g_in,
                &frontier,
                |s, d| {
                    let cand = dist[s as usize].load(Ordering::Relaxed) + weight(orig(s), orig(d));
                    let prev = dist[d as usize].fetch_min(cand, Ordering::Relaxed);
                    cand < prev
                },
                |_| true,
                EdgeMapOpts::default(),
                scratch,
            );
            scratch.recycle(std::mem::replace(&mut frontier, next));
        }
        scratch.recycle(frontier);
    }

    /// Distances from `source` (original ids); unreachable = +inf.
    ///
    /// Weights are defined on **original** endpoint ids so reordering does
    /// not change the metric. This convenience API materializes a result
    /// vector; the pipeline path ([`PreparedSssp::run_source`]) stays on
    /// the allocation-free internal buffers.
    pub fn run(&mut self, source: VertexId) -> Vec<f64> {
        let src = self.working_id(source);
        self.run_inner(src);
        let raw: Vec<f64> = self.dist.iter().map(|d| d.load(Ordering::Relaxed)).collect();
        match &self.perm {
            Some(p) => reorder::unpermute(&raw, p),
            None => raw,
        }
    }

    /// Test hook: garbage every dead buffer (distances are reset at the
    /// start of each traversal).
    pub fn poison_scratch(&mut self, seed: u64) {
        self.scratch.poison(seed);
        for (i, d) in self.dist.iter().enumerate() {
            // audit: relaxed-ok — single-threaded test hook on a dead buffer.
            d.store(-(seed as f64) - i as f64, Ordering::Relaxed);
        }
    }

    fn reusable_bytes(&self) -> usize {
        self.scratch.peak_bytes() + self.dist.len() * 8
    }
}

/// [`PreparedApp`] adapter: accumulates the total finite distance mass
/// across `run_source` calls.
pub struct PreparedSssp {
    prep: Prepared,
    total: f64,
}

impl PreparedApp for PreparedSssp {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::PerSource
    }

    fn run_source(&mut self, source: VertexId) {
        let src = self.prep.working_id(source);
        self.prep.run_inner(src);
        // The finite-distance sum is permutation-invariant: read it from
        // the working-space buffer without materializing/unpermuting.
        self.total += self
            .prep
            .dist
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .filter(|d| d.is_finite())
            .sum::<f64>();
    }

    /// Sum of all finite shortest-path distances over all sources run so
    /// far (Bellman–Ford converges to the unique distance vector, so this
    /// is deterministic despite the relaxed atomics).
    fn summary(&self) -> f64 {
        self.total
    }

    fn scratch_bytes(&self) -> usize {
        self.prep.reusable_bytes()
    }
}

/// Registry adapter: SSSP as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Sssp(Variant::Baseline),
    },
    VariantInfo {
        name: "reordering",
        aliases: &["reorder", "optimized"],
        kind: AppKind::Sssp(Variant::Reordered),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn description(&self) -> &'static str {
        "Single-source shortest paths — frontier Bellman-Ford, deterministic synthetic weights"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Sssp(Variant::Reordered)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        matches!(kind, AppKind::Sssp(Variant::Reordered))
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Sssp(v) = kind else {
            bail!("sssp app handed foreign kind {kind:?}")
        };
        Ok(Box::new(PreparedSssp {
            prep: Prepared::prepare(g, cfg, v, store),
            total: 0.0,
        }))
    }

    /// One pull relaxation sweep: frontier membership plus each
    /// neighbor's 8-byte tentative distance (no bitvector variant).
    fn simulate(&self, g: &Csr, cfg: &SystemConfig, kind: AppKind) -> Option<StallEstimate> {
        let AppKind::Sssp(v) = kind else { return None };
        Some(crate::cache::stall::simulate_frontier_app(
            g,
            cfg.llc_bytes,
            8,
            matches!(v, Variant::Reordered),
            false,
        ))
    }
}

/// Serial Dijkstra reference (weights are positive).
pub fn reference(g: &Csr, source: VertexId) -> Vec<f64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_vertices();
    let mut dist = vec![f64::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut heap: BinaryHeap<(Reverse<u64>, VertexId)> = BinaryHeap::new();
    heap.push((Reverse(0), source));
    while let Some((Reverse(dbits), u)) = heap.pop() {
        let du = f64::from_bits(dbits);
        if du > dist[u as usize] {
            continue;
        }
        for &v in g.neighbors(u) {
            let cand = du + weight(u, v);
            if cand < dist[v as usize] {
                dist[v as usize] = cand;
                heap.push((Reverse(cand.to_bits()), v));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn matches_dijkstra() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 66);
        let g = Csr::from_edges(n, &e);
        let src = super::super::bc::default_sources(&g, 1)[0];
        let want = reference(&g, src);
        for v in [Variant::Baseline, Variant::Reordered] {
            let mut p = Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
            let got = p.run(src);
            for i in 0..n {
                assert_eq!(got[i], want[i], "variant {v:?} vertex {i}");
            }
        }
    }

    #[test]
    fn repeated_runs_reuse_scratch_identically() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 66);
        let g = Csr::from_edges(n, &e);
        let src = super::super::bc::default_sources(&g, 1)[0];
        let want = reference(&g, src);
        let mut p = Prepared::prepare(
            &g,
            &SystemConfig::default(),
            Variant::Reordered,
            &StoreCtx::disabled(),
        );
        for round in 0..3u64 {
            p.poison_scratch(round.wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(p.run(src), want, "round {round}");
        }
    }

    #[test]
    fn weights_positive_and_deterministic() {
        for u in 0..50u32 {
            for v in 0..50u32 {
                let w = weight(u, v);
                assert!((1.0..=16.0).contains(&w));
                assert_eq!(w, weight(u, v));
            }
        }
    }

    #[test]
    fn disconnected_vertices_infinite() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2)]);
        let mut p =
            Prepared::prepare(&g, &SystemConfig::default(), Variant::Baseline, &StoreCtx::disabled());
        let d = p.run(0);
        assert_eq!(d[0], 0.0);
        assert!(d[3].is_infinite());
    }
}
