//! Single-source shortest paths (frontier-based Bellman–Ford) — the §6.1
//! "applications that involve vertices' activeness checking" class that
//! Betweenness Centrality represents. Edge weights are synthesized
//! deterministically (1..=16) from the endpoints.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::coordinator::SystemConfig;
use crate::engine::{edge_map, EdgeMapOpts, VertexSubset};
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::AtomicF64;
use crate::reorder;
use crate::store::StoreCtx;
use anyhow::{bail, Result};
use std::sync::atomic::Ordering;

/// Deterministic edge weight in 1..=16.
#[inline]
pub fn weight(u: VertexId, v: VertexId) -> f64 {
    let h = (u as u64)
        .wrapping_mul(0xA24BAED4963EE407)
        .wrapping_add((v as u64).wrapping_mul(0x9FB21C651E98DF25));
    (1 + (h >> 56) % 16) as f64
}

/// Optimization mix (reordering only; SSSP's frontier churn defeats
/// segmenting, like BFS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Baseline,
    Reordered,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Reordered => "reordering",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[Variant::Baseline, Variant::Reordered]
    }
}

/// Preprocessed SSSP state.
pub struct Prepared {
    g: Csr,
    g_in: Csr,
    perm: Option<Vec<VertexId>>,
    inv: Option<Vec<VertexId>>,
}

impl Prepared {
    /// Preprocess without the artifact store (coarsening threshold from
    /// the default [`SystemConfig`]).
    pub fn new(g: &Csr, variant: Variant) -> Prepared {
        Self::new_cached(g, &SystemConfig::default(), variant, None)
    }

    /// Like [`Prepared::new`], but the reordering permutation goes
    /// through the persistent store when `store` is present — the same
    /// degree-sort key PageRank/BC/BFS share, so any of them warms the
    /// others on the same dataset.
    pub fn new_cached(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: Option<StoreCtx<'_>>,
    ) -> Prepared {
        let (work, perm) = match variant {
            Variant::Reordered => {
                let perm = reorder::cached_degree_sort_perm(g, cfg.coarsen, store);
                (g.relabel(&perm), Some(perm))
            }
            Variant::Baseline => (g.clone(), None),
        };
        let g_in = work.transpose();
        let inv = perm.as_ref().map(|p| reorder::invert(p));
        Prepared {
            g: work,
            g_in,
            perm,
            inv,
        }
    }

    /// Distances from `source` (original ids); unreachable = +inf.
    ///
    /// Weights are defined on **original** endpoint ids so reordering does
    /// not change the metric.
    pub fn run(&self, source: VertexId) -> Vec<f64> {
        let n = self.g.num_vertices();
        let src = match &self.perm {
            Some(p) => p[source as usize],
            None => source,
        };
        // Weight of working-space edge (s,d) = weight of original edge.
        let orig = |v: VertexId| -> VertexId {
            match &self.inv {
                Some(inv) => inv[v as usize],
                None => v,
            }
        };
        let dist: Vec<AtomicF64> = (0..n).map(|_| AtomicF64::new(f64::INFINITY)).collect();
        dist[src as usize].store(0.0, Ordering::Relaxed);
        let mut frontier = VertexSubset::single(n, src);
        let mut rounds = 0usize;
        while !frontier.is_empty() && rounds <= n {
            rounds += 1;
            frontier = edge_map(
                &self.g,
                &self.g_in,
                &frontier,
                |s, d| {
                    let cand = dist[s as usize].load(Ordering::Relaxed) + weight(orig(s), orig(d));
                    let prev = dist[d as usize].fetch_min(cand, Ordering::Relaxed);
                    cand < prev
                },
                |_| true,
                EdgeMapOpts::default(),
            );
        }
        let raw: Vec<f64> = dist.iter().map(|d| d.load(Ordering::Relaxed)).collect();
        match &self.perm {
            Some(p) => reorder::unpermute(&raw, p),
            None => raw,
        }
    }
}

/// [`PreparedApp`] adapter: accumulates the total finite distance mass
/// across `run_source` calls.
pub struct PreparedSssp {
    prep: Prepared,
    total: f64,
}

impl PreparedApp for PreparedSssp {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::PerSource
    }

    fn run_source(&mut self, source: VertexId) {
        let dist = self.prep.run(source);
        self.total += dist.iter().filter(|d| d.is_finite()).sum::<f64>();
    }

    /// Sum of all finite shortest-path distances over all sources run so
    /// far (Bellman–Ford converges to the unique distance vector, so this
    /// is deterministic despite the relaxed atomics).
    fn summary(&self) -> f64 {
        self.total
    }
}

/// Registry adapter: SSSP as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Sssp(Variant::Baseline),
    },
    VariantInfo {
        name: "reordering",
        aliases: &["reorder", "optimized"],
        kind: AppKind::Sssp(Variant::Reordered),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn description(&self) -> &'static str {
        "Single-source shortest paths — frontier Bellman-Ford, deterministic synthetic weights"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Sssp(Variant::Reordered)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        matches!(kind, AppKind::Sssp(Variant::Reordered))
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: Option<StoreCtx<'_>>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Sssp(v) = kind else {
            bail!("sssp app handed foreign kind {kind:?}")
        };
        Ok(Box::new(PreparedSssp {
            prep: Prepared::new_cached(g, cfg, v, store),
            total: 0.0,
        }))
    }
}

/// Serial Dijkstra reference (weights are positive).
pub fn reference(g: &Csr, source: VertexId) -> Vec<f64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_vertices();
    let mut dist = vec![f64::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut heap: BinaryHeap<(Reverse<u64>, VertexId)> = BinaryHeap::new();
    heap.push((Reverse(0), source));
    while let Some((Reverse(dbits), u)) = heap.pop() {
        let du = f64::from_bits(dbits);
        if du > dist[u as usize] {
            continue;
        }
        for &v in g.neighbors(u) {
            let cand = du + weight(u, v);
            if cand < dist[v as usize] {
                dist[v as usize] = cand;
                heap.push((Reverse(cand.to_bits()), v));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn matches_dijkstra() {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 66);
        let g = Csr::from_edges(n, &e);
        let src = super::super::bc::default_sources(&g, 1)[0];
        let want = reference(&g, src);
        for v in [Variant::Baseline, Variant::Reordered] {
            let p = Prepared::new(&g, v);
            let got = p.run(src);
            for i in 0..n {
                assert_eq!(got[i], want[i], "variant {v:?} vertex {i}");
            }
        }
    }

    #[test]
    fn weights_positive_and_deterministic() {
        for u in 0..50u32 {
            for v in 0..50u32 {
                let w = weight(u, v);
                assert!((1.0..=16.0).contains(&w));
                assert_eq!(w, weight(u, v));
            }
        }
    }

    #[test]
    fn disconnected_vertices_infinite() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2)]);
        let p = Prepared::new(&g, Variant::Baseline);
        let d = p.run(0);
        assert_eq!(d[0], 0.0);
        assert!(d[3].is_infinite());
    }
}
