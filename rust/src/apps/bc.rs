//! Betweenness Centrality (Table 4): Brandes' algorithm over the
//! frontier engine — forward BFS accumulating shortest-path counts, then
//! a level-synchronous backward dependency sweep. "Betweenness Centrality
//! represents the applications that involve vertices' activeness checking
//! and making unpredictable access to vertices' data" (§6.1).
//!
//! The paper evaluates 12 starting points (Table 4) and the
//! reordering/bitvector optimization grid (Table 7).
//!
//! All per-source working memory — σ/level/δ arrays, the per-level
//! frontier stack, and the engine's [`EngineScratch`] — lives in the
//! `Prepared` state and is reset (never re-allocated) per source; the
//! per-level frontiers draw their storage from the scratch pools and are
//! recycled after the backward sweep.

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::cache::StallEstimate;
use crate::coordinator::SystemConfig;
use crate::engine::{edge_map, EdgeMapOpts, EngineScratch, VertexSubset};
use crate::graph::{Csr, VertexId};
use crate::parallel::atomics::AtomicF64;
use crate::reorder;
use crate::store::StoreCtx;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

/// BC optimization mix — the same grid as BFS (Tables 7/8), but BC's own
/// enum: the two apps are tuned independently and must not share a type
/// just because today's variant *names* coincide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Ligra-style direction-optimizing Brandes (the Table 4 baseline).
    Baseline,
    /// + degree reordering.
    Reordered,
    /// + bitvector frontier.
    Bitvector,
    /// + both (Table 7's best row).
    ReorderedBitvector,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Reordered => "reordering",
            Variant::Bitvector => "bitvector",
            Variant::ReorderedBitvector => "reordering+bitvector",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[
            Variant::Baseline,
            Variant::Reordered,
            Variant::Bitvector,
            Variant::ReorderedBitvector,
        ]
    }

    fn reordered(self) -> bool {
        matches!(self, Variant::Reordered | Variant::ReorderedBitvector)
    }
}

/// Preprocessed BC state plus the reusable per-source traversal buffers.
pub struct Prepared {
    variant: Variant,
    g: Csr,
    g_in: Csr,
    /// Permutation old→new when reordered, `Arc`-pinned (shared
    /// read-only across concurrent resident jobs).
    perm: Option<Arc<crate::store::ArcSlice<VertexId>>>,
    /// σ = number of shortest paths (reset per source).
    sigma: Vec<AtomicU64>,
    /// BFS depth (reset per source).
    level: Vec<AtomicU32>,
    /// Dependency scores δ (reset per source).
    delta: Vec<AtomicF64>,
    /// Per-level frontier stack; drained (and its frontiers recycled)
    /// after every backward sweep, so only the Vec's capacity persists.
    frontiers: Vec<VertexSubset>,
    scratch: EngineScratch,
}

impl Prepared {
    /// Run all preprocessing for `variant`. The reordering permutation
    /// goes through the persistent store: warm runs load the degree sort
    /// — mapped in place where possible — instead of re-sorting (the
    /// relabel itself is recomputed; it is a cheap scatter compared to
    /// the sort). The key matches PageRank's, so the permutation is
    /// shared across apps on the same dataset. A [`StoreCtx::disabled`]
    /// context is the no-store path.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let (work, perm) = if variant.reordered() {
            let perm = reorder::cached_degree_sort_perm(g, cfg.coarsen, store);
            (g.relabel(&perm), Some(perm))
        } else {
            (g.clone(), None)
        };
        let g_in = work.transpose();
        let n = work.num_vertices();
        Prepared {
            variant,
            g: work,
            g_in,
            perm,
            sigma: (0..n).map(|_| AtomicU64::new(0)).collect(),
            level: (0..n).map(|_| AtomicU32::new(u32::MAX)).collect(),
            delta: (0..n).map(|_| AtomicF64::new(0.0)).collect(),
            frontiers: Vec::new(),
            scratch: EngineScratch::new(n),
        }
    }

    /// Accumulate BC scores from the given source vertices (original
    /// ids). Returns per-vertex centrality in original id space.
    pub fn run(&mut self, sources: &[VertexId]) -> Vec<f64> {
        let n = self.g.num_vertices();
        let mut bc = vec![0.0f64; n];
        for &s0 in sources {
            let s = self.working_id(s0);
            self.accumulate_from(s, &mut bc);
        }
        match &self.perm {
            Some(p) => reorder::unpermute(&bc, p),
            None => bc,
        }
    }

    /// Map an original-space vertex id into the working (possibly
    /// reordered) id space.
    fn working_id(&self, v: VertexId) -> VertexId {
        match &self.perm {
            Some(p) => p[v as usize],
            None => v,
        }
    }

    fn accumulate_from(&mut self, s: VertexId, bc: &mut [f64]) {
        let n = self.g.num_vertices();
        let bitvector = matches!(self.variant, Variant::Bitvector | Variant::ReorderedBitvector);
        let opts = EdgeMapOpts {
            bitvector_frontier: bitvector,
            ..Default::default()
        };
        let g = &self.g;
        let g_in = &self.g_in;
        let sigma = &self.sigma;
        let level = &self.level;
        let delta = &self.delta;
        let frontiers = &mut self.frontiers;
        let scratch = &mut self.scratch;
        // Reset per-source state (fills, no allocation).
        crate::parallel::parallel_for(n, |v| {
            // audit: relaxed-ok — each v writes only its own slot, and the
            // traversal starts after the parallel_for joins (a barrier).
            sigma[v].store(0, Ordering::Relaxed);
            level[v].store(u32::MAX, Ordering::Relaxed); // audit: relaxed-ok — as above
            delta[v].store(0.0, Ordering::Relaxed); // audit: relaxed-ok — as above
        });
        // audit: relaxed-ok — single-threaded setup before the traversal.
        sigma[s as usize].store(1, Ordering::Relaxed);
        level[s as usize].store(0, Ordering::Relaxed); // audit: relaxed-ok — as above
        debug_assert!(frontiers.is_empty());
        frontiers.push({
            let mut ids = scratch.take_ids();
            ids.push(s);
            VertexSubset::from_ids(n, ids)
        });
        let mut depth = 0u32;
        loop {
            let cur = frontiers.last().unwrap();
            if cur.is_empty() {
                let f = frontiers.pop().unwrap();
                scratch.recycle(f);
                break;
            }
            depth += 1;
            let next = edge_map(
                g,
                g_in,
                frontiers.last().unwrap(),
                |u, v| {
                    // u is at depth-1; v unvisited or at depth.
                    let lv = &level[v as usize];
                    let was = lv.load(Ordering::Relaxed);
                    if was == u32::MAX {
                        // First touch this round (races resolved by CAS).
                        let _ = lv.compare_exchange(
                            u32::MAX,
                            depth,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        );
                    }
                    if level[v as usize].load(Ordering::Relaxed) == depth {
                        sigma[v as usize]
                            .fetch_add(sigma[u as usize].load(Ordering::Relaxed), Ordering::Relaxed);
                        was == u32::MAX
                    } else {
                        false
                    }
                },
                |v| {
                    let l = level[v as usize].load(Ordering::Relaxed);
                    l == u32::MAX || l == depth
                },
                opts,
                scratch,
            );
            if next.is_empty() {
                scratch.recycle(next);
                break;
            }
            frontiers.push(next);
        }
        // Backward sweep: δ(v) = Σ_{w ∈ succ(v)} σ(v)/σ(w) · (1 + δ(w)).
        // For each v at depth d-1, sum over out-neighbors w at depth d;
        // the frontier's id slice is borrowed or pool-materialized by the
        // scratch helper (no per-level allocation).
        for d in (1..frontiers.len()).rev() {
            let frontier = &frontiers[d - 1];
            scratch.with_frontier_ids(frontier, |ids| {
                crate::parallel::parallel_for(ids.len(), |i| {
                    let v = ids[i];
                    let lv = level[v as usize].load(Ordering::Relaxed);
                    let mut acc = 0.0;
                    for &w in g.neighbors(v) {
                        if level[w as usize].load(Ordering::Relaxed) == lv + 1 {
                            let sw = sigma[w as usize].load(Ordering::Relaxed);
                            if sw > 0 {
                                let ratio = sigma[v as usize].load(Ordering::Relaxed) as f64
                                    / sw as f64;
                                acc += ratio * (1.0 + delta[w as usize].load(Ordering::Relaxed));
                            }
                        }
                    }
                    if acc != 0.0 {
                        delta[v as usize].fetch_add(acc, Ordering::Relaxed);
                    }
                });
            });
        }
        // Recycle every level's frontier storage for the next source.
        for f in frontiers.drain(..) {
            scratch.recycle(f);
        }
        for v in 0..n {
            if v as VertexId != s {
                bc[v] += delta[v].load(Ordering::Relaxed);
            }
        }
    }

    /// Test hook: garbage every dead buffer (σ/level/δ are reset at the
    /// start of each source).
    pub fn poison_scratch(&mut self, seed: u64) {
        self.scratch.poison(seed);
        for (i, x) in self.sigma.iter().enumerate() {
            // audit: relaxed-ok — single-threaded test hook on dead buffers.
            x.store(seed.wrapping_add(i as u64), Ordering::Relaxed);
        }
        for x in &self.level {
            // audit: relaxed-ok — single-threaded test hook on dead buffers.
            x.store(seed as u32 | 1, Ordering::Relaxed);
        }
        for x in &self.delta {
            // audit: relaxed-ok — single-threaded test hook on dead buffers.
            x.store(-1.25 - seed as f64, Ordering::Relaxed);
        }
    }

    fn reusable_bytes(&self) -> usize {
        self.scratch.peak_bytes()
            + self.sigma.len() * 8
            + self.level.len() * 4
            + self.delta.len() * 8
    }
}

/// Serial reference Brandes (exact) for validation.
pub fn reference(g: &Csr, sources: &[VertexId]) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0f64; n];
    for &s in sources {
        let mut sigma = vec![0u64; n];
        let mut dist = vec![i64::MAX; n];
        let mut order: Vec<VertexId> = Vec::new();
        sigma[s as usize] = 1;
        dist[s as usize] = 0;
        let mut q = std::collections::VecDeque::from([s]);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &v in g.neighbors(u) {
                if dist[v as usize] == i64::MAX {
                    dist[v as usize] = dist[u as usize] + 1;
                    q.push_back(v);
                }
                if dist[v as usize] == dist[u as usize] + 1 {
                    sigma[v as usize] += sigma[u as usize];
                }
            }
        }
        let mut delta = vec![0.0f64; n];
        for &u in order.iter().rev() {
            for &v in g.neighbors(u) {
                if dist[v as usize] == dist[u as usize] + 1 && sigma[v as usize] > 0 {
                    delta[u as usize] += sigma[u as usize] as f64 / sigma[v as usize] as f64
                        * (1.0 + delta[v as usize]);
                }
            }
        }
        for v in 0..n {
            if v as VertexId != s {
                bc[v] += delta[v];
            }
        }
    }
    bc
}

// The paper's evaluation uses "12 different starting points"; the
// highest-degree source picker now lives in the unified app API (shared
// by BFS/BC/SSSP) and is re-exported here for its historical callers.
pub use super::app::default_sources;

/// [`PreparedApp`] adapter: accumulates centrality across `run_source`
/// calls, exactly like [`Prepared::run`] over the same source list.
pub struct PreparedBc {
    prep: Prepared,
    /// Accumulated scores in the working id space.
    scores: Vec<f64>,
}

impl PreparedApp for PreparedBc {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::PerSource
    }

    fn run_source(&mut self, source: VertexId) {
        let s = self.prep.working_id(source);
        self.prep.accumulate_from(s, &mut self.scores);
    }

    /// Max accumulated centrality. The max is permutation-invariant, so
    /// it is taken in the working id space without unpermuting.
    fn summary(&self) -> f64 {
        self.scores.iter().cloned().fold(0.0, f64::max)
    }

    fn scratch_bytes(&self) -> usize {
        self.prep.reusable_bytes() + self.scores.len() * 8
    }
}

/// Registry adapter: Betweenness Centrality as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::Bc(Variant::Baseline),
    },
    VariantInfo {
        name: "reordering",
        aliases: &["reorder"],
        kind: AppKind::Bc(Variant::Reordered),
    },
    VariantInfo {
        name: "bitvector",
        aliases: &[],
        kind: AppKind::Bc(Variant::Bitvector),
    },
    VariantInfo {
        name: "both",
        aliases: &["optimized", "reordering+bitvector"],
        kind: AppKind::Bc(Variant::ReorderedBitvector),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "bc"
    }

    fn description(&self) -> &'static str {
        "Betweenness Centrality (Brandes) — frontier-driven, activeness checks + random vertex reads"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::Bc(Variant::ReorderedBitvector)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        matches!(kind, AppKind::Bc(v) if v.reordered())
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::Bc(v) = kind else {
            bail!("bc app handed foreign kind {kind:?}")
        };
        let n = g.num_vertices();
        Ok(Box::new(PreparedBc {
            prep: Prepared::prepare(g, cfg, v, store),
            scores: vec![0.0; n],
        }))
    }

    /// One pull sweep reading frontier membership plus each neighbor's
    /// 8-byte σ path count (Table 7's access mix).
    fn simulate(&self, g: &Csr, cfg: &SystemConfig, kind: AppKind) -> Option<StallEstimate> {
        let AppKind::Bc(v) = kind else { return None };
        let bitvector = matches!(v, Variant::Bitvector | Variant::ReorderedBitvector);
        Some(crate::cache::stall::simulate_frontier_app(
            g,
            cfg.llc_bytes,
            8,
            v.reordered(),
            bitvector,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn graph() -> Csr {
        let (n, e) = generators::rmat(9, 8, generators::RmatParams::graph500(), 55);
        Csr::from_edges(n, &e)
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-7 * y.abs().max(1.0),
                "v={i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn matches_reference_single_source() {
        let g = graph();
        let sources = default_sources(&g, 1);
        let want = reference(&g, &sources);
        for &v in Variant::all() {
            let mut p = Prepared::prepare(&g, &SystemConfig::default(), v, &StoreCtx::disabled());
            let got = p.run(&sources);
            assert_close(&got, &want);
        }
    }

    #[test]
    fn matches_reference_multi_source() {
        let g = graph();
        let sources = default_sources(&g, 4);
        let want = reference(&g, &sources);
        let mut p = Prepared::prepare(
            &g,
            &SystemConfig::default(),
            Variant::ReorderedBitvector,
            &StoreCtx::disabled(),
        );
        let got = p.run(&sources);
        assert_close(&got, &want);
    }

    #[test]
    fn scratch_reuse_across_sources_matches_reference() {
        // The multi-source run above already reuses σ/level/δ and the
        // engine scratch across sources; poison between sources to prove
        // nothing leaks through the reused buffers.
        let g = graph();
        let sources = default_sources(&g, 4);
        let want = reference(&g, &sources);
        let mut p = Prepared::prepare(
            &g,
            &SystemConfig::default(),
            Variant::ReorderedBitvector,
            &StoreCtx::disabled(),
        );
        let n = g.num_vertices();
        let mut bc = vec![0.0f64; n];
        for (k, &s0) in sources.iter().enumerate() {
            p.poison_scratch(0xF00D + k as u64);
            let s = p.perm.as_ref().map_or(s0, |pm| pm[s0 as usize]);
            p.accumulate_from(s, &mut bc);
        }
        let got = match &p.perm {
            Some(pm) => reorder::unpermute(&bc, pm),
            None => bc,
        };
        assert_close(&got, &want);
    }

    #[test]
    fn line_graph_known_values() {
        // 0→1→2→3: BC(1)=2 (paths 0-2,0-3... from source 0 only: pairs
        // (0,2),(0,3) pass through 1 → δ=2; vertex 2 gets δ=1).
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let mut p =
            Prepared::prepare(&g, &SystemConfig::default(), Variant::Baseline, &StoreCtx::disabled());
        let got = p.run(&[0]);
        assert_close(&got, &[0.0, 2.0, 1.0, 0.0]);
    }
}
