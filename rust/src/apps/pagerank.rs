//! PageRank (Algorithm 1) in every variant the evaluation measures.
//!
//! The per-iteration update is `newRank[v] = (1-d)/n + d · Σ_{u→v}
//! rank[u]/outdeg(u)`. Our baseline precomputes per-source contributions
//! (`rank[u]/outdeg(u)`) once per iteration — the trick that makes "our
//! baseline faster than Ligra ... because we calculated the contribution
//! of each vertex beforehand" (§6.2) — and replaces division by a
//! reciprocal multiply ("we change division operations to multiplication
//! of reciprocal").

use super::app::{AppKind, ExecutionShape, GraphApp, PreparedApp, VariantInfo};
use crate::cache::StallEstimate;
use crate::coordinator::SystemConfig;
use crate::graph::{degree_prefix, Csr, VertexId};
use crate::parallel::{parallel_for, parallel_for_cost, UnsafeSlice};
use crate::reorder;
use crate::segment::{SegmentBuffers, SegmentedCsr};
use crate::store::{StoreCtx, StoreKey};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Which optimization mix to run (Figure 2 / Figure 8's bar groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Optimized pull baseline (contribution precompute, cost-balanced).
    Baseline,
    /// Baseline + degree reordering (§3).
    Reordered,
    /// Baseline + CSR segmenting (§4).
    Segmented,
    /// Both techniques (the paper's "Optimized Version").
    ReorderedSegmented,
    /// The Figure 2 lower bound: random reads replaced by reads of vertex
    /// 0 — "of course the result is incorrect".
    NoRandomLowerBound,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Reordered => "reordering",
            Variant::Segmented => "segmenting",
            Variant::ReorderedSegmented => "reordering+segmenting",
            Variant::NoRandomLowerBound => "no-random-lower-bound",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[
            Variant::Baseline,
            Variant::Reordered,
            Variant::Segmented,
            Variant::ReorderedSegmented,
        ]
    }
}

/// Reciprocal out-degrees (0 for sinks) in `g`'s id space.
fn inv_out_degrees(g: &Csr) -> Vec<f64> {
    (0..g.num_vertices())
        .map(|v| {
            let d = g.degree(v as VertexId);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f64
            }
        })
        .collect()
}

/// Reciprocal out-degrees scattered into permuted id space
/// (`out[perm[v]] = 1/deg_g(v)`) — bitwise identical to reading degrees
/// off the relabeled CSR, without materializing it.
fn permuted_inv_degrees(g: &Csr, perm: &[VertexId]) -> Vec<f64> {
    let n = g.num_vertices();
    // A decoded permutation is validated as a bijection on 0..perm.len()
    // by the codec but not against this graph; mismatched lengths must
    // panic here rather than write out of bounds below.
    assert_eq!(perm.len(), n, "permutation length != graph vertex count");
    let mut out = vec![0.0f64; n];
    let slice = UnsafeSlice::new(&mut out);
    parallel_for(n, |v| {
        let d = g.degree(v as VertexId);
        let inv = if d == 0 { 0.0 } else { 1.0 / d as f64 };
        // SAFETY: perm is a bijection, so writes are disjoint.
        unsafe { slice.write(perm[v] as usize, inv) };
    });
    out
}

/// Result: ranks in **original** vertex-id space.
#[derive(Debug, Clone)]
pub struct PageRankResult {
    pub values: Vec<f64>,
    pub iterations: usize,
}

/// Preprocessed state so benches can time iterations separately from
/// preprocessing (Table 9 measures preprocessing on its own).
pub struct Prepared {
    variant: Variant,
    n: usize,
    damping: f64,
    /// Out-degrees in the working id space (reciprocal-multiplied).
    inv_deg: Vec<f64>,
    /// Pull CSR (transpose) for unsegmented variants. `Arc`-pinned so a
    /// resident process (`cagra serve`) shares one decoded copy across
    /// concurrent jobs; per-job mutable state stays owned below.
    pull: Option<Arc<Csr>>,
    /// Degree prefix over `pull` for cost-based balancing.
    pull_cost: Option<Vec<u64>>,
    /// Segmented structure for segmented variants (shared, read-only).
    seg: Option<Arc<SegmentedCsr>>,
    seg_bufs: Option<SegmentBuffers>,
    /// Permutation old→new when reordered (to map results back).
    perm: Option<Arc<crate::store::ArcSlice<VertexId>>>,
    /// Scratch rank vectors.
    rank: Vec<f64>,
    next: Vec<f64>,
    contrib: Vec<f64>,
}

impl Prepared {
    /// Run all preprocessing for `variant` (reorder and/or segment).
    /// Preprocessing artifacts go through `store`: a cold run builds and
    /// persists the permutation and the variant's working structure (the
    /// transposed pull CSR for the reordered pull variant, the segmented
    /// partition for segmented ones); a warm run loads them — mapped in
    /// place where possible — instead of recomputing (paper Table 9's
    /// amortization). A [`StoreCtx::disabled`] context is the no-store
    /// path: the same code, builders always run. The relabeled out-CSR
    /// is never persisted: it is only a cold-build intermediate — degrees
    /// come from `g` + the permutation.
    pub fn prepare(
        g: &Csr,
        cfg: &SystemConfig,
        variant: Variant,
        store: &StoreCtx<'_>,
    ) -> Prepared {
        let n = g.num_vertices();
        // Honor cfg.coarsen exactly (coarsen = 1 is the §3.2 exact sort,
        // anything else the §3.3 banded sort); the store label comes from
        // reorder::degree_sort_label so differently-coarsened artifacts
        // can never alias and the permutation is shared with BC/BFS.
        let coarsen = cfg.coarsen.max(1);
        let ord_label = reorder::degree_sort_label(coarsen);
        let perm = match variant {
            Variant::Reordered | Variant::ReorderedSegmented => {
                Some(reorder::cached_degree_sort_perm(g, coarsen, store))
            }
            _ => None,
        };
        let (inv_deg, pull, pull_cost, seg, seg_bufs) = match variant {
            Variant::Segmented | Variant::ReorderedSegmented => {
                let seg_size = cfg.segment_size(8);
                let block = cfg.merge_block(8);
                let seg_label = match &perm {
                    Some(_) => ord_label.as_str(),
                    None => "original",
                };
                let sg = store.get_or_build_arc(
                    StoreKey::segmented(store.fingerprint, seg_label, seg_size, block),
                    || match &perm {
                        Some(p) => SegmentedCsr::build_with_block(&g.relabel(p), seg_size, block),
                        None => SegmentedCsr::build_with_block(g, seg_size, block),
                    },
                );
                assert_eq!(sg.num_vertices, n, "segmented artifact dimension mismatch");
                let bufs = SegmentBuffers::for_graph(&sg);
                let inv_deg = match &perm {
                    Some(p) => permuted_inv_degrees(g, p),
                    None => inv_out_degrees(g),
                };
                (inv_deg, None, None, Some(sg), Some(bufs))
            }
            // Pull variants iterate over the transpose, so that is what
            // gets persisted for the reordered case — caching the
            // intermediate out-CSR would cost as much to decode as the
            // relabel it skips while leaving the expensive transpose to
            // rerun every time.
            _ => {
                let (inv_deg, pull) = match &perm {
                    Some(p) => {
                        let pull_label = format!("{ord_label}-pull");
                        let pull = store.get_or_build_arc(
                            StoreKey::ordering(store.fingerprint, &pull_label),
                            || g.relabel(p).transpose(),
                        );
                        (permuted_inv_degrees(g, p), pull)
                    }
                    None => (inv_out_degrees(g), Arc::new(g.transpose())),
                };
                let cost = degree_prefix(&pull);
                (inv_deg, Some(pull), Some(cost), None, None)
            }
        };
        Prepared {
            variant,
            n,
            damping: cfg.damping,
            inv_deg,
            pull,
            pull_cost,
            seg,
            seg_bufs,
            perm,
            rank: vec![1.0 / n as f64; n],
            next: vec![0.0; n],
            contrib: vec![0.0; n],
        }
    }

    /// Reset ranks to the uniform start.
    pub fn reset(&mut self) {
        self.rank.fill(1.0 / self.n as f64);
    }

    /// One PageRank iteration in the working id space.
    pub fn step(&mut self) {
        let n = self.n;
        let d = self.damping;
        let base = (1.0 - d) / n as f64;
        // Contribution precompute: contrib[u] = rank[u] * (1/deg[u]).
        {
            let contrib = UnsafeSlice::new(&mut self.contrib);
            let rank = &self.rank;
            let inv = &self.inv_deg;
            // SAFETY: each u writes only slot u; u < n == contrib.len().
            parallel_for(n, |u| unsafe { contrib.write(u, rank[u] * inv[u]) });
        }
        match self.variant {
            Variant::Baseline | Variant::Reordered => {
                let pull = self.pull.as_ref().unwrap();
                let cost = self.pull_cost.as_ref().unwrap();
                let contrib = &self.contrib;
                let next = UnsafeSlice::new(&mut self.next);
                let total = *cost.last().unwrap();
                let threshold =
                    (total / (8 * crate::parallel::num_threads() as u64).max(1)).max(512);
                parallel_for_cost(
                    n,
                    threshold,
                    |lo, hi| cost[hi] - cost[lo],
                    |lo, hi| {
                        for v in lo..hi {
                            let mut acc = 0.0;
                            for &u in pull.neighbors(v as VertexId) {
                                acc += contrib[u as usize];
                            }
                            // SAFETY: each v in lo..hi belongs to exactly
                            // one task's range; v < n == next.len().
                            unsafe { next.write(v, base + d * acc) };
                        }
                    },
                );
            }
            Variant::NoRandomLowerBound => {
                // All random reads redirected to a cache-resident cell —
                // the Figure 2 lower bound (intentionally incorrect
                // ranks).
                let pull = self.pull.as_ref().unwrap();
                let cost = self.pull_cost.as_ref().unwrap();
                let c0 = self.contrib[0];
                let next = UnsafeSlice::new(&mut self.next);
                let total = *cost.last().unwrap();
                let threshold =
                    (total / (8 * crate::parallel::num_threads() as u64).max(1)).max(512);
                parallel_for_cost(
                    n,
                    threshold,
                    |lo, hi| cost[hi] - cost[lo],
                    |lo, hi| {
                        for v in lo..hi {
                            let mut acc = 0.0;
                            for &_u in pull.neighbors(v as VertexId) {
                                acc += c0; // read serviced from L1
                            }
                            // SAFETY: each v in lo..hi belongs to exactly
                            // one task's range; v < n == next.len().
                            unsafe { next.write(v, base + d * acc) };
                        }
                    },
                );
            }
            Variant::Segmented | Variant::ReorderedSegmented => {
                let sg = self.seg.as_ref().unwrap();
                let bufs = self.seg_bufs.as_mut().unwrap();
                let contrib = &self.contrib;
                // aggregate fills next with base + d * Σ contrib.
                let mut agg = std::mem::take(&mut self.next);
                for s in 0..sg.num_segments() {
                    sg.process_segment_slice(s, contrib, &mut bufs.per_segment[s]);
                }
                agg.fill(0.0);
                crate::segment::merge(sg, bufs, &mut agg);
                let next = UnsafeSlice::new(&mut agg);
                // SAFETY: each v touches only its own cell; v < n.
                parallel_for(n, |v| unsafe {
                    let cell = next.get_mut(v);
                    *cell = base + d * *cell;
                });
                self.next = agg;
            }
        }
        std::mem::swap(&mut self.rank, &mut self.next);
    }

    /// Current ranks mapped back to original vertex-id space (no reset).
    pub fn values(&self) -> Vec<f64> {
        match &self.perm {
            Some(p) => reorder::unpermute(&self.rank, p),
            None => self.rank.clone(),
        }
    }

    /// Run `iters` iterations and return ranks in original id space.
    pub fn run(&mut self, iters: usize) -> PageRankResult {
        self.reset();
        for _ in 0..iters {
            self.step();
        }
        PageRankResult {
            values: self.values(),
            iterations: iters,
        }
    }

    /// L1 error between successive iterations (for convergence loops).
    pub fn delta(&self) -> f64 {
        self.rank
            .iter()
            .zip(&self.next)
            .map(|(a, b)| (a - b).abs())
            .sum()
    }

    pub fn num_edges(&self) -> usize {
        match (&self.pull, &self.seg) {
            (Some(p), _) => p.num_edges(),
            (_, Some(s)) => s.num_edges(),
            _ => 0,
        }
    }
}

impl PreparedApp for Prepared {
    fn shape(&self) -> ExecutionShape {
        ExecutionShape::Iterative
    }

    fn step(&mut self) {
        Prepared::step(self)
    }

    /// Rank L1 mass in original id space — deterministic, so warm and
    /// cold store runs must agree bitwise.
    fn summary(&self) -> f64 {
        self.values().iter().sum()
    }

    fn scratch_bytes(&self) -> usize {
        (self.rank.len() + self.next.len() + self.contrib.len()) * 8
            + self.seg_bufs.as_ref().map_or(0, |b| b.bytes())
    }
}

/// Registry adapter: PageRank as a [`GraphApp`].
pub struct App;

const VARIANTS: &[VariantInfo] = &[
    VariantInfo {
        name: "baseline",
        aliases: &[],
        kind: AppKind::PageRank(Variant::Baseline),
    },
    VariantInfo {
        name: "reordering",
        aliases: &["reorder"],
        kind: AppKind::PageRank(Variant::Reordered),
    },
    VariantInfo {
        name: "segmenting",
        aliases: &["segment"],
        kind: AppKind::PageRank(Variant::Segmented),
    },
    VariantInfo {
        name: "both",
        aliases: &["optimized", "reordering+segmenting"],
        kind: AppKind::PageRank(Variant::ReorderedSegmented),
    },
    VariantInfo {
        name: "lower-bound",
        aliases: &["no-random-lower-bound"],
        kind: AppKind::PageRank(Variant::NoRandomLowerBound),
    },
];

impl GraphApp for App {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pr"]
    }

    fn description(&self) -> &'static str {
        "PageRank — iterative, activeness-free random vertex reads (the running example)"
    }

    fn variants(&self) -> &'static [VariantInfo] {
        VARIANTS
    }

    fn default_variant(&self) -> AppKind {
        AppKind::PageRank(Variant::ReorderedSegmented)
    }

    fn uses_store(&self, kind: AppKind) -> bool {
        // Only variants that actually preprocess (reorder and/or segment)
        // have artifacts worth persisting.
        matches!(
            kind,
            AppKind::PageRank(Variant::Reordered)
                | AppKind::PageRank(Variant::Segmented)
                | AppKind::PageRank(Variant::ReorderedSegmented)
        )
    }

    fn prepare(
        &self,
        g: &Csr,
        cfg: &SystemConfig,
        kind: AppKind,
        store: &StoreCtx<'_>,
    ) -> Result<Box<dyn PreparedApp>> {
        let AppKind::PageRank(v) = kind else {
            bail!("pagerank app handed foreign kind {kind:?}")
        };
        Ok(Box::new(Prepared::prepare(g, cfg, v, store)))
    }

    fn simulate(&self, g: &Csr, cfg: &SystemConfig, kind: AppKind) -> Option<StallEstimate> {
        let AppKind::PageRank(v) = kind else { return None };
        Some(crate::coordinator::job::simulate_pagerank(g, cfg, v))
    }
}

/// Convenience: preprocess + run.
pub fn run(g: &Csr, cfg: &SystemConfig, variant: Variant, iters: usize) -> PageRankResult {
    Prepared::prepare(g, cfg, variant, &StoreCtx::disabled()).run(iters)
}

/// Serial reference implementation (no tricks) for correctness tests.
pub fn reference(g: &Csr, damping: f64, iters: usize) -> Vec<f64> {
    let n = g.num_vertices();
    let pull = g.transpose();
    let mut rank = vec![1.0 / n as f64; n];
    let mut next = vec![0.0; n];
    for _ in 0..iters {
        for (v, cell) in next.iter_mut().enumerate() {
            let mut acc = 0.0;
            for &u in pull.neighbors(v as VertexId) {
                let du = g.degree(u) as f64;
                acc += rank[u as usize] / du;
            }
            *cell = (1.0 - damping) / n as f64 + damping * acc;
        }
        std::mem::swap(&mut rank, &mut next);
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn graph() -> Csr {
        let (n, e) = generators::rmat(10, 8, generators::RmatParams::graph500(), 31);
        Csr::from_edges(n, &e)
    }

    fn assert_close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * y.abs().max(1e-12),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn baseline_matches_reference() {
        let g = graph();
        let cfg = SystemConfig::default();
        let got = run(&g, &cfg, Variant::Baseline, 5);
        let want = reference(&g, cfg.damping, 5);
        assert_close(&got.values, &want, 1e-10);
    }

    #[test]
    fn all_variants_agree() {
        let g = graph();
        let mut cfg = SystemConfig::default();
        cfg.llc_bytes = 4096; // force many segments at this scale
        let want = reference(&g, cfg.damping, 4);
        for &v in Variant::all() {
            let got = run(&g, &cfg, v, 4);
            assert_close(&got.values, &want, 1e-9);
        }
    }

    #[test]
    fn lower_bound_is_incorrect_but_runs() {
        let g = graph();
        let cfg = SystemConfig::default();
        let lb = run(&g, &cfg, Variant::NoRandomLowerBound, 3);
        let want = reference(&g, cfg.damping, 3);
        // Same shape, finite, but *not* equal to the true ranks.
        assert_eq!(lb.values.len(), want.len());
        assert!(lb.values.iter().all(|v| v.is_finite()));
        let diff: f64 = lb.values.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "lower bound accidentally correct?");
    }

    #[test]
    fn ranks_sum_bounded() {
        // With dangling mass dropped, total rank stays in (0, 1].
        let g = graph();
        let cfg = SystemConfig::default();
        let r = run(&g, &cfg, Variant::ReorderedSegmented, 10);
        let total: f64 = r.values.iter().sum();
        assert!(total > 0.1 && total <= 1.0 + 1e-9, "total={total}");
    }

    #[test]
    fn step_reuses_prepared_state() {
        let g = graph();
        let cfg = SystemConfig::default();
        let mut p = Prepared::prepare(&g, &cfg, Variant::Segmented, &StoreCtx::disabled());
        let a = p.run(3);
        let b = p.run(3); // reset + rerun must reproduce
        assert_eq!(a.values, b.values);
    }
}
