//! Vertex reordering (paper §3).
//!
//! Reorganizes the physical layout of vertex data so frequently-accessed
//! (high-out-degree) vertices share cache lines. The permutation
//! convention throughout: `perm[old_id] = new_id`.
//!
//! Orderings provided:
//! - [`Ordering::DegreeSort`] — exact descending out-degree sort (§3.2),
//!   proven optimal for the independent-access cache model (§5).
//! - [`Ordering::CoarseDegreeSort`] — the §3.3 refinement: *stable* sort by
//!   `⌊degree/10⌋` so vertices with similar degree keep their original
//!   relative order, preserving community locality of the input ordering;
//!   the long tail of cold vertices is not reordered at all.
//! - [`Ordering::Random`] — random permutation (used as an adversarial
//!   baseline, e.g. the randomized-Twitter experiment in §6.2/Fig 7).
//! - [`Ordering::Bfs`] — BFS visit order (crawl-style locality).
//! - [`Ordering::Identity`] — no-op, the "original order" baseline.

use crate::graph::{datasets::bfs_order, Csr, VertexId};
use crate::parallel::parallel_for;
use crate::util::rng::Rng;

/// A reordering strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    Identity,
    /// Descending out-degree (parallel sort).
    DegreeSort,
    /// Stable descending sort by `⌊degree/threshold⌋` (default threshold
    /// 10) — §3.3.
    CoarseDegreeSort,
    /// Uniform random permutation (seeded).
    Random,
    /// BFS visit order from the max-degree vertex.
    Bfs,
}

impl Ordering {
    pub fn name(self) -> &'static str {
        match self {
            Ordering::Identity => "original",
            Ordering::DegreeSort => "degree-sorted",
            Ordering::CoarseDegreeSort => "coarse-degree-sorted",
            Ordering::Random => "random",
            Ordering::Bfs => "bfs",
        }
    }

    /// All orderings (for sweeps).
    pub fn all() -> &'static [Ordering] {
        &[
            Ordering::Identity,
            Ordering::DegreeSort,
            Ordering::CoarseDegreeSort,
            Ordering::Random,
            Ordering::Bfs,
        ]
    }
}

/// Historical seed for [`Ordering::Random`]; configurable via
/// `SystemConfig::random_seed` (the default keeps sweeps reproducible).
pub const DEFAULT_RANDOM_SEED: u64 = 0xD1CE;

/// Compute the permutation (`perm[old] = new`) for an ordering over `g`,
/// using [`DEFAULT_RANDOM_SEED`] for the random ordering.
pub fn permutation(g: &Csr, ordering: Ordering) -> Vec<VertexId> {
    permutation_seeded(g, ordering, DEFAULT_RANDOM_SEED)
}

/// [`permutation`] with an explicit seed for [`Ordering::Random`] (the
/// other orderings are deterministic and ignore it).
pub fn permutation_seeded(g: &Csr, ordering: Ordering, random_seed: u64) -> Vec<VertexId> {
    match ordering {
        Ordering::Identity => (0..g.num_vertices() as VertexId).collect(),
        Ordering::DegreeSort => degree_sort_perm(g, 1),
        Ordering::CoarseDegreeSort => degree_sort_perm(g, 10),
        Ordering::Random => Rng::new(random_seed).permutation(g.num_vertices()),
        Ordering::Bfs => bfs_order(g),
    }
}

/// Reorder a graph: returns the relabeled CSR and the permutation used
/// (`perm[old] = new`), so callers can map results back to original ids.
pub fn reorder(g: &Csr, ordering: Ordering) -> (Csr, Vec<VertexId>) {
    reorder_seeded(g, ordering, DEFAULT_RANDOM_SEED)
}

/// [`reorder`] with an explicit seed for [`Ordering::Random`].
pub fn reorder_seeded(g: &Csr, ordering: Ordering, random_seed: u64) -> (Csr, Vec<VertexId>) {
    let perm = permutation_seeded(g, ordering, random_seed);
    if matches!(ordering, Ordering::Identity) {
        return (g.clone(), perm);
    }
    (g.relabel(&perm), perm)
}

/// Invert a permutation: `inv[new] = old`.
pub fn invert(perm: &[VertexId]) -> Vec<VertexId> {
    let mut inv = vec![0 as VertexId; perm.len()];
    for (old, &new) in perm.iter().enumerate() {
        inv[new as usize] = old as VertexId;
    }
    inv
}

/// Map a per-vertex value vector from new-id space back to old-id space.
pub fn unpermute<T: Copy + Default + Send + Sync>(values: &[T], perm: &[VertexId]) -> Vec<T> {
    assert_eq!(values.len(), perm.len());
    let mut out = vec![T::default(); values.len()];
    let slice = crate::parallel::UnsafeSlice::new(&mut out);
    // SAFETY: perm is a bijection on 0..len, so each old id writes a
    // distinct in-bounds slot.
    parallel_for(perm.len(), |old| unsafe {
        slice.write(old, values[perm[old] as usize]);
    });
    out
}

/// Degree sort with coarsening: stable descending sort of vertices by
/// `degree/coarsen`. `coarsen = 1` is the exact sort of §3.2; `coarsen =
/// 10` is the §3.3 variant that preserves the input's relative order
/// inside each degree band ("sort vertices by ⌊outDegree/10⌋ using a
/// stable sort").
pub fn degree_sort_perm(g: &Csr, coarsen: u32) -> Vec<VertexId> {
    let coarsen = coarsen.max(1);
    let n = g.num_vertices();
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    // Stable sort by descending coarsened degree. (std stable sort is the
    // parallel-STL-sort stand-in; it is the preprocessing path, measured
    // separately in Table 9.)
    order.sort_by_key(|&v| std::cmp::Reverse(g.degree(v) / coarsen));
    // order[new] = old  =>  perm[old] = new.
    let mut perm = vec![0 as VertexId; n];
    for (new, &old) in order.iter().enumerate() {
        perm[old as usize] = new as VertexId;
    }
    perm
}

/// Store label for [`degree_sort_perm`] artifacts. The single source of
/// the on-disk key shape: every app that persists a degree sort keys it
/// through here, so the artifact is shared across apps per dataset.
pub fn degree_sort_label(coarsen: u32) -> String {
    format!("degree-sorted-c{}", coarsen.max(1))
}

/// [`degree_sort_perm`] routed through the storage context: one key per
/// (dataset fingerprint, coarsen), shared by every reordering app
/// (PageRank, BC, BFS), so one app's cold run warms the others. A
/// disabled context just computes the permutation — the same single code
/// path either way. The loaded permutation is length-checked against the
/// live graph before it can reach any unchecked scatter.
pub fn cached_degree_sort_perm(
    g: &Csr,
    coarsen: u32,
    store: &crate::store::StoreCtx<'_>,
) -> std::sync::Arc<crate::store::ArcSlice<VertexId>> {
    let coarsen = coarsen.max(1);
    let perm = store.get_or_build_arc(
        crate::store::StoreKey::ordering(store.fingerprint, &degree_sort_label(coarsen)),
        || degree_sort_perm(g, coarsen).into(),
    );
    assert_eq!(perm.len(), g.num_vertices(), "permutation length != graph vertex count");
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    fn skewed() -> Csr {
        let (n, edges) = generators::zipf_out(512, 4096, 1.0, 11);
        Csr::from_edges(n, &edges)
    }

    #[test]
    fn degree_sort_is_descending() {
        let g = skewed();
        let (h, _) = reorder(&g, Ordering::DegreeSort);
        let degs = h.out_degrees();
        for w in degs.windows(2) {
            assert!(w[0] >= w[1], "degrees not descending: {} < {}", w[0], w[1]);
        }
    }

    #[test]
    fn coarse_sort_descending_in_bands() {
        let g = skewed();
        let (h, _) = reorder(&g, Ordering::CoarseDegreeSort);
        let degs = h.out_degrees();
        for w in degs.windows(2) {
            assert!(w[0] / 10 >= w[1] / 10);
        }
    }

    #[test]
    fn coarse_sort_stable_within_band() {
        let g = skewed();
        let perm = degree_sort_perm(&g, 10);
        // Vertices with the same coarsened degree must preserve original
        // relative order: old a < old b and band(a)==band(b) => new a < new b.
        let inv = invert(&perm);
        let mut last_in_band: std::collections::HashMap<u32, VertexId> = Default::default();
        for new in 0..g.num_vertices() {
            let old = inv[new];
            let band = g.degree(old) / 10;
            if let Some(&prev_old) = last_in_band.get(&band) {
                assert!(prev_old < old, "band {band}: {prev_old} !< {old}");
            }
            last_in_band.insert(band, old);
        }
    }

    #[test]
    fn reorder_preserves_edge_structure() {
        let g = skewed();
        for &o in Ordering::all() {
            let (h, perm) = reorder(&g, o);
            assert_eq!(h.num_edges(), g.num_edges(), "{}", o.name());
            // Edge (u,v) in g <=> (perm[u], perm[v]) in h.
            let mut orig: Vec<_> = g.edges().map(|(u, v)| (perm[u as usize], perm[v as usize])).collect();
            let mut new: Vec<_> = h.edges().collect();
            orig.sort_unstable();
            new.sort_unstable();
            assert_eq!(orig, new, "{}", o.name());
        }
    }

    #[test]
    fn unpermute_maps_back() {
        let g = skewed();
        let (h, perm) = reorder(&g, Ordering::DegreeSort);
        // Value = new-space degree; unpermuted must equal old-space degree.
        let vals: Vec<u32> = h.out_degrees();
        let back = unpermute(&vals, &perm);
        assert_eq!(back, g.out_degrees());
    }

    #[test]
    fn prop_permutations_valid() {
        check("orderings produce valid permutations", 20, |gen| {
            let (n, edges) = gen.edges(1..120, 4);
            let g = Csr::from_edges(n, &edges);
            for &o in Ordering::all() {
                let p = permutation(&g, o);
                let mut sorted = p.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..n as VertexId).collect::<Vec<_>>(), "{}", o.name());
            }
        });
    }

    #[test]
    fn random_seed_is_configurable_and_default_preserved() {
        let g = skewed();
        // Default-seed path is unchanged from the historical constant.
        assert_eq!(
            permutation(&g, Ordering::Random),
            permutation_seeded(&g, Ordering::Random, DEFAULT_RANDOM_SEED)
        );
        // Same seed reproduces; different seeds diverge.
        assert_eq!(
            permutation_seeded(&g, Ordering::Random, 42),
            permutation_seeded(&g, Ordering::Random, 42)
        );
        assert_ne!(
            permutation_seeded(&g, Ordering::Random, 42),
            permutation_seeded(&g, Ordering::Random, 43)
        );
        // Deterministic orderings ignore the seed.
        assert_eq!(
            permutation_seeded(&g, Ordering::DegreeSort, 1),
            permutation_seeded(&g, Ordering::DegreeSort, 2)
        );
        // Seeded variants still produce valid permutations.
        let (h, p) = reorder_seeded(&g, Ordering::Random, 7);
        assert_eq!(h.num_edges(), g.num_edges());
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..g.num_vertices() as VertexId).collect::<Vec<_>>());
    }

    #[test]
    fn prop_invert_roundtrip() {
        check("invert(invert(p)) == p", 20, |gen| {
            let n = gen.usize(1..200);
            let p = gen.permutation(n);
            assert_eq!(invert(&invert(&p)), p);
        });
    }
}
