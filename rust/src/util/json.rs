//! Minimal JSON encoder/parser for the offline mirror (no `serde`).
//!
//! The bench-report subsystem ([`crate::bench::report`]) needs a
//! machine-readable interchange format that CI and `cagra bench diff` can
//! both speak. This module provides the smallest JSON implementation that
//! supports it: an ordered [`Value`] tree, a deterministic pretty-printer,
//! and a strict recursive-descent parser.
//!
//! Guarantees the bench code relies on:
//! - **Stable round trips**: `render(parse(render(v))) == render(v)`.
//!   Object key order is preserved (objects are association lists, not
//!   maps) and numbers print via Rust's shortest-round-trip `Display`.
//! - **Corrupt input always errors**: truncation, trailing garbage,
//!   malformed escapes, and over-deep nesting all return `Err`, never a
//!   silently-wrong tree.
//! - **Non-finite numbers render as `null`** (JSON has no NaN/Inf);
//!   callers that must not lose data validate finiteness before encoding.

use anyhow::{bail, Result};
use std::fmt::Write as _;

/// Maximum nesting depth accepted by the parser (stack-overflow guard).
const MAX_DEPTH: usize = 64;

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match; `None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number as a non-negative integer, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Deterministic pretty-printed JSON (2-space indent, no trailing
    /// newline).
    pub fn render(&self) -> String {
        let mut out = String::new();
        write_value(self, 0, &mut out);
        out
    }

    /// Single-line JSON with no interior newlines — the newline-delimited
    /// wire format (`cagra serve`). Parses back to the same tree as
    /// [`render`] output; only the whitespace differs.
    pub fn render_compact(&self) -> String {
        let mut out = String::new();
        write_value_compact(self, &mut out);
        out
    }
}

fn write_value_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value_compact(item, out);
            }
            out.push(']');
        }
        Value::Obj(fields) => {
            out.push('{');
            for (i, (k, item)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value_compact(item, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push(']');
        }
        Value::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encoders that cannot tolerate the loss
        // validate before rendering (see BenchFile::to_json).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Rust's Display for f64 is the shortest string that parses back
        // to the same value — exactly what a stable round trip needs.
        let _ = write!(out, "{n}");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document. Trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {} of JSON input", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} of JSON input",
                b as char,
                self.pos
            );
        }
    }

    fn eat_literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH} levels");
        }
        self.skip_ws();
        let Some(b) = self.peek() else {
            bail!("unexpected end of JSON input");
        };
        match b {
            b'n' | b't' | b'f' => self.literal(),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!(
                "unexpected byte {:?} at position {} of JSON input",
                other as char,
                self.pos
            ),
        }
    }

    fn literal(&mut self) -> Result<Value> {
        if self.eat_literal("null") {
            Ok(Value::Null)
        } else if self.eat_literal("true") {
            Ok(Value::Bool(true))
        } else if self.eat_literal("false") {
            Ok(Value::Bool(false))
        } else {
            bail!("invalid JSON literal at byte {}", self.pos);
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {} of JSON input", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            fields.push((key, self.value(depth + 1)?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {} of JSON input", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                bail!("unterminated JSON string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        bail!("unterminated escape in JSON string");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{08}'),
                        b'f' => out.push('\u{0C}'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => bail!("invalid escape '\\{}' in JSON string", other as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8: re-scan from the byte we consumed,
                    // decoding at most one 4-byte sequence (never the whole
                    // remaining input — that would make parsing O(n²)).
                    self.pos -= 1;
                    let end = (self.pos + 4).min(self.bytes.len());
                    let chunk = &self.bytes[self.pos..end];
                    let c = match std::str::from_utf8(chunk) {
                        Ok(s) => s.chars().next().unwrap(),
                        // A trailing sequence cut off by `end` still decodes
                        // its leading char; an error here with a valid-UTF-8
                        // input (&str) can only mean a truncated tail.
                        Err(e) if e.valid_up_to() > 0 => {
                            let s = std::str::from_utf8(&chunk[..e.valid_up_to()]).unwrap();
                            s.chars().next().unwrap()
                        }
                        Err(_) => bail!("invalid UTF-8 in JSON string"),
                    };
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        let code = if (0xD800..=0xDBFF).contains(&hi) {
            // High surrogate: a low surrogate escape must follow.
            if !self.eat_literal("\\u") {
                bail!("high surrogate not followed by \\u escape");
            }
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                bail!("invalid low surrogate {lo:#06x}");
            }
            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        } else {
            hi
        };
        char::from_u32(code).ok_or_else(|| anyhow::anyhow!("invalid unicode escape {code:#x}"))
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut code = 0u32;
        for _ in 0..4 {
            let Some(b) = self.peek() else {
                bail!("truncated \\u escape");
            };
            self.pos += 1;
            let digit = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a') as u32 + 10,
                b'A'..=b'F' => (b - b'A') as u32 + 10,
                _ => bail!("invalid hex digit {:?} in \\u escape", b as char),
            };
            code = code * 16 + digit;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid JSON number {text:?}"))?;
        if !n.is_finite() {
            bail!("non-finite JSON number {text:?}");
        }
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_stable() {
        let v = Value::Obj(vec![
            ("name".into(), Value::Str("table2/optimized".into())),
            ("median".into(), Value::Num(0.1415926535)),
            ("reps".into(), Value::Num(5.0)),
            ("work".into(), Value::Null),
            ("ok".into(), Value::Bool(true)),
            (
                "samples".into(),
                Value::Arr(vec![Value::Num(1.0), Value::Num(2.5)]),
            ),
        ]);
        let once = v.render();
        let reparsed = parse(&once).unwrap();
        assert_eq!(reparsed, v);
        assert_eq!(reparsed.render(), once, "encode→parse→encode must be stable");
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let v = Value::Obj(vec![
            ("op".into(), Value::Str("run".into())),
            ("iters".into(), Value::Num(3.0)),
            ("note".into(), Value::Str("line1\nline2".into())),
            (
                "args".into(),
                Value::Arr(vec![Value::Null, Value::Bool(false), Value::Obj(vec![])]),
            ),
        ]);
        let line = v.render_compact();
        assert!(!line.contains('\n'), "compact output must be one line: {line:?}");
        assert_eq!(parse(&line).unwrap(), v);
        assert_eq!(
            line,
            r#"{"op":"run","iters":3,"note":"line1\nline2","args":[null,false,{}]}"#
        );
    }

    #[test]
    fn numbers_roundtrip_exactly() {
        for n in [0.0, -1.5, 1e-9, 123456789.0, 0.1 + 0.2, f64::MIN_POSITIVE] {
            let s = Value::Num(n).render();
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), n.to_bits(), "{n} rendered as {s}");
        }
    }

    #[test]
    fn string_escapes() {
        let v = Value::Str("a\"b\\c\nd\tе\u{1}".into());
        let s = v.render();
        assert_eq!(parse(&s).unwrap(), v);
        assert_eq!(parse(r#""\u0041\u00e9\ud83d\ude00""#).unwrap(), Value::Str("Aé😀".into()));
    }

    #[test]
    fn preserves_key_order() {
        let s = r#"{"z": 1, "a": 2, "m": 3}"#;
        let Value::Obj(fields) = parse(s).unwrap() else {
            panic!("not an object")
        };
        let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn corrupt_inputs_error() {
        for bad in [
            "",
            "{",
            "[1, 2",
            "\"unterminated",
            "{\"a\": }",
            "nul",
            "[1] trailing",
            "{\"a\": 1,}",
            "\"\\u12\"",
            "\"\\ud800\"",
            "--5",
            "1e",
            "{\"a\" 1}",
        ] {
            assert!(parse(bad).is_err(), "accepted corrupt input {bad:?}");
        }
    }

    #[test]
    fn truncation_always_errors() {
        let full = Value::Obj(vec![
            ("cases".into(), Value::Arr(vec![Value::Num(1.0)])),
            ("name".into(), Value::Str("x".into())),
        ])
        .render();
        for cut in 1..full.len() {
            assert!(
                parse(&full[..cut]).is_err(),
                "accepted truncation at {cut}: {:?}",
                &full[..cut]
            );
        }
    }

    #[test]
    fn depth_limit_enforced() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(40) + &"]".repeat(40);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn non_finite_renders_null() {
        assert_eq!(Value::Num(f64::NAN).render(), "null");
        assert_eq!(Value::Num(f64::INFINITY).render(), "null");
    }
}
