//! Summary statistics over f64 samples — used by the bench harness and the
//! metrics reports.

/// Summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 0.5),
            min: sorted[0],
            max: sorted[n - 1],
            stddev: var.sqrt(),
            p05: percentile_sorted(&sorted, 0.05),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Combine two independent standard deviations in quadrature
/// (`√(a² + b²)`) — the noise margin `bench diff` adds on top of its
/// relative tolerance when comparing two measured medians. Delegates to
/// [`f64::hypot`] (no intermediate overflow/underflow).
pub fn quadrature(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Geometric mean (all samples must be positive).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive samples");
            x.ln()
        })
        .sum();
    (log_sum / samples.len() as f64).exp()
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Default, Clone, Copy)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.5), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn quadrature_basics() {
        assert_eq!(quadrature(3.0, 4.0), 5.0);
        assert_eq!(quadrature(0.0, 0.0), 0.0);
        assert_eq!(quadrature(0.0, 2.5), 2.5);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.stddev() - s.stddev).abs() < 1e-12);
    }
}
