//! Tiny leveled logger writing to stderr. Level from `CAGRA_LOG`
//! (error|warn|info|debug|trace, default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset sentinel
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = std::env::var("CAGRA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    // audit: relaxed-ok — idempotent one-way cache of the env parse;
    // racing initializers store the same value.
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically.
pub fn set_level(l: Level) {
    // audit: relaxed-ok — advisory verbosity knob; no data depends on it.
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Log a message at `l`. Prefer the `log_*!` macros.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {}] {args}", l.tag());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn  { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn,  format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info  { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info,  format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
