//! General-purpose substrates that the offline crate mirror could not
//! provide: deterministic RNG, CLI/config parsing, logging, timers,
//! statistics, and a miniature property-testing framework.

pub mod rng;
pub mod cli;
pub mod config;
pub mod json;
pub mod logger;
pub mod timer;
pub mod stats;
pub mod prop;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a byte count with binary units ("96 KiB", "2.0 MiB").
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a count with thousands separators ("1,469,000,000").
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i).is_multiple_of(3) {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(30 * 1024 * 1024), "30.0 MiB");
    }

    #[test]
    fn fmt_count_commas() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1469000000), "1,469,000,000");
    }
}
