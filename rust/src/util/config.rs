//! Flat key-value configuration files (a TOML subset; no `serde` in the
//! offline mirror).
//!
//! Syntax:
//! ```text
//! # comment
//! [section]           # keys below become "section.key"
//! key = value         # value parsed on demand (str / int / float / bool)
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed configuration: dotted keys → raw string values.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from source text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header: {raw:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`: {raw:?}", lineno + 1);
            };
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let mut value = line[eq + 1..].trim().to_string();
            // Strip matching quotes.
            if value.len() >= 2
                && ((value.starts_with('"') && value.ends_with('"'))
                    || (value.starts_with('\'') && value.ends_with('\'')))
            {
                value = value[1..value.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.values.insert(full, value);
        }
        Ok(cfg)
    }

    /// Load and parse a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key} = {v:?} is not an integer")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key} = {v:?} is not an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key} = {v:?} is not a number")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("{key} = {v:?} is not a boolean"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` starts a comment unless inside quotes.
    let mut in_quote: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_quote) {
            ('"', None) | ('\'', None) => in_quote = Some(c),
            (q, Some(open)) if q == open => in_quote = None,
            ('#', None) => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let cfg = Config::parse(
            "# top comment\n\
             threads = 4\n\
             [cache]\n\
             llc_bytes = 98304   # scaled LLC\n\
             line = 64\n\
             [pagerank]\n\
             damping = 0.85\n\
             verbose = true\n\
             name = \"hot path\"\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("threads", 0).unwrap(), 4);
        assert_eq!(cfg.get_usize("cache.llc_bytes", 0).unwrap(), 98304);
        assert_eq!(cfg.get_f64("pagerank.damping", 0.0).unwrap(), 0.85);
        assert!(cfg.get_bool("pagerank.verbose", false).unwrap());
        assert_eq!(cfg.get("pagerank.name"), Some("hot path"));
    }

    #[test]
    fn defaults_apply() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(cfg.get_str("missing", "x"), "x");
        assert_eq!(cfg.get_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn get_u64_parses_large_values() {
        let cfg = Config::parse("cap = 2147483648\n").unwrap();
        assert_eq!(cfg.get_u64("cap", 0).unwrap(), 1 << 31);
        let bad = Config::parse("cap = nope\n").unwrap();
        assert!(bad.get_u64("cap", 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("[unterminated").is_err());
        let cfg = Config::parse("k = notanum").unwrap();
        assert!(cfg.get_usize("k", 0).is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let cfg = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(cfg.get("k"), Some("a#b"));
    }
}
