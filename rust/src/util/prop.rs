//! A miniature property-testing framework (no `proptest` in the offline
//! mirror): seeded generators + a `check` runner with iteration-count
//! control and failure reporting, plus naive input shrinking for integer
//! and vector cases.
//!
//! Usage:
//! ```
//! use cagra::util::prop::{check, Gen};
//! check("reverse twice is id", 100, |g| {
//!     let xs = g.vec_u32(0..50, 0..1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    pub iteration: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        self.rng.range(r.start, r.end)
    }

    pub fn u32(&mut self, r: Range<u32>) -> u32 {
        self.usize(r.start as usize..r.end as usize) as u32
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    /// Vector of u32s with random length in `len` and values in `vals`.
    pub fn vec_u32(&mut self, len: Range<usize>, vals: Range<u32>) -> Vec<u32> {
        let n = self.usize(len);
        (0..n).map(|_| self.u32(vals.clone())).collect()
    }

    /// Vector of f64s.
    pub fn vec_f64(&mut self, len: Range<usize>, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Random edge list over `n` vertices with `m` edges.
    pub fn edges(&mut self, n: Range<usize>, avg_degree: usize) -> (usize, Vec<(u32, u32)>) {
        let nv = self.usize(n).max(1);
        let m = nv * avg_degree.max(1);
        let edges = (0..m)
            .map(|_| (self.u32(0..nv as u32), self.u32(0..nv as u32)))
            .collect();
        (nv, edges)
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        self.rng.permutation(n)
    }
}

/// Run `iters` iterations of the property `f` with fresh seeded generators.
/// Panics (with the failing seed) if any iteration panics. Seed taken from
/// `CAGRA_PROP_SEED` when set, so failures replay deterministically.
pub fn check(name: &str, iters: usize, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed: u64 = std::env::var("CAGRA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xCA62A);
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            iteration: i,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at iteration {i} (replay with \
                 CAGRA_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = std::sync::atomic::AtomicUsize::new(0);
        check("trivial", 25, |g| {
            let _ = g.u64();
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(*count.get_mut(), 25);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_reports() {
        check("fails", 10, |g| {
            let x = g.usize(0..100);
            assert!(x < 1000); // always true
            assert!(g.iteration < 5, "iteration too big"); // fails at 5
        });
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 50, |g| {
            let v = g.vec_u32(0..20, 10..30);
            for x in v {
                assert!((10..30).contains(&x));
            }
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let (n, es) = g.edges(1..50, 4);
            for (s, d) in es {
                assert!((s as usize) < n && (d as usize) < n);
            }
        });
    }
}
