//! Minimal command-line argument parser (no `clap` in the offline mirror).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands. Unknown flags are collected so callers can reject or
//! forward them.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, named options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I, subcommands: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // First non-flag token matching a known subcommand becomes the
        // subcommand.
        let mut saw_subcommand = false;
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    out.options.insert(k.to_string(), v[1..].to_string());
                } else {
                    // Peek: if next token exists and is not a flag, treat as
                    // value; otherwise boolean flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => out.flags.push(stripped.to_string()),
                    }
                }
            } else if !saw_subcommand && subcommands.contains(&tok.as_str()) {
                out.subcommand = Some(tok);
                saw_subcommand = true;
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(subcommands: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), subcommands)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(v(&["run", "--graph", "twitter-sim", "--iters=20"]), &["run", "bench"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("graph"), Some("twitter-sim"));
        assert_eq!(a.get_usize("iters", 0), 20);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(v(&["run", "--verbose", "--graph", "x"]), &["run"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("graph"), Some("x"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = Args::parse(v(&["--quiet"]), &[]);
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn positionals_collected() {
        let a = Args::parse(v(&["gen", "out.bin", "--seed", "1"]), &["gen"]);
        assert_eq!(a.positional, vec!["out.bin"]);
        assert_eq!(a.get_u64("seed", 0), 1);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(v(&[]), &["run"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("graph", "def"), "def");
        assert_eq!(a.get_f64("damping", 0.85), 0.85);
    }
}
