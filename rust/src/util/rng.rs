//! Deterministic pseudo-random number generation.
//!
//! The offline mirror has no `rand` crate, so we implement SplitMix64 (for
//! seeding) and xoshiro256++ (the workhorse generator). Every experiment in
//! the repo is seeded, so benchmark inputs are reproducible run-to-run.

/// SplitMix64 — used to expand a single u64 seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// graph generation; exact rejection not needed at our scales).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample from a Zipf(s) distribution over `{0, .., n-1}` using the
    /// precomputed CDF in `ZipfSampler` — see below. Standalone geometric
    /// approximation for one-off use.
    pub fn zipf_approx(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the continuous Pareto approximation.
        let u = self.next_f64().max(1e-12);
        let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
        (x as usize).min(n - 1)
    }
}

/// Exact Zipf sampler with a precomputed CDF (O(log n) per sample).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, exponent: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn zipf_skewed() {
        let mut r = Rng::new(5);
        let z = ZipfSampler::new(1000, 1.0);
        let mut count0 = 0;
        let mut count_tail = 0;
        for _ in 0..20_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k == 0 {
                count0 += 1;
            }
            if k >= 500 {
                count_tail += 1;
            }
        }
        // Rank 0 should individually beat the entire [500, 1000) tail
        // being ~1/H(1000) ≈ 13% vs ~9%.
        assert!(count0 > count_tail / 2, "count0={count0} tail={count_tail}");
    }
}
