//! Wall-clock timing helpers and a scoped phase timer used by the
//! coordinator's metrics and the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Named phase accumulator: `timer.phase("merge", || ...)` adds elapsed
/// time under "merge"; totals are queryable and printable.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn phase<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed();
        *self.totals.entry(name.to_string()).or_default() += dt;
        *self.counts.entry(name.to_string()).or_default() += 1;
        out
    }

    /// Add externally-measured time to a phase.
    pub fn add(&mut self, name: &str, secs: f64) {
        *self.totals.entry(name.to_string()).or_default() += Duration::from_secs_f64(secs);
        *self.counts.entry(name.to_string()).or_default() += 1;
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.totals.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or(0)
    }

    pub fn total_seconds(&self) -> f64 {
        self.totals.values().map(|d| d.as_secs_f64()).sum()
    }

    /// Phases sorted by descending time share.
    pub fn report(&self) -> Vec<(String, f64, f64)> {
        let total = self.total_seconds().max(1e-12);
        let mut rows: Vec<_> = self
            .totals
            .iter()
            .map(|(k, d)| (k.clone(), d.as_secs_f64(), d.as_secs_f64() / total))
            .collect();
        rows.sort_by(|a, b| b.1.total_cmp(&a.1));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures() {
        let (v, dt) = time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(dt >= 0.004, "dt={dt}");
    }

    #[test]
    fn phases_accumulate() {
        let mut t = PhaseTimer::new();
        t.phase("a", || std::thread::sleep(Duration::from_millis(2)));
        t.phase("a", || std::thread::sleep(Duration::from_millis(2)));
        t.add("b", 0.001);
        assert_eq!(t.count("a"), 2);
        assert!(t.seconds("a") >= 0.003);
        let rows = t.report();
        assert_eq!(rows[0].0, "a");
        let share_sum: f64 = rows.iter().map(|r| r.2).sum();
        assert!((share_sum - 1.0).abs() < 1e-9);
    }
}
