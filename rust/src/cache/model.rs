//! The paper's §5 analytical cache model.
//!
//! Assumes each access to the vertex-data vector is independent with
//! probability `P(i)` ∝ out-degree(i). For a k-way set-associative LRU
//! cache:
//!
//! - Eq (1): `p_l = P(l) / Σ_{l' ∈ S} P(l')` — probability an access to
//!   set S goes to line l.
//! - Eq (2): `P_hit(l) = Σ_{i<k} p_l (1-p_l)^i = 1 - (1-p_l)^k`.
//! - Eq (3): `E[M] = Σ_l P(l) · (1-p_l)^k`.
//!
//! Propositions 1 and 2 (degree-sort optimality) are checked empirically
//! by the tests and the `model_validation` bench.

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub sets: usize,
    pub assoc: usize,
    pub line_bytes: usize,
}

impl CacheGeometry {
    pub fn new(total_bytes: usize, assoc: usize, line_bytes: usize) -> CacheGeometry {
        assert!(assoc >= 1 && line_bytes >= 1);
        let lines = (total_bytes / line_bytes).max(assoc);
        let sets = (lines / assoc).max(1);
        CacheGeometry {
            sets,
            assoc,
            line_bytes,
        }
    }

    pub fn total_bytes(&self) -> usize {
        self.sets * self.assoc * self.line_bytes
    }

    pub fn lines(&self) -> usize {
        self.sets * self.assoc
    }
}

/// Predicted miss rate for accesses to a vertex-value vector laid out in
/// id order, where element `i` is accessed with weight `weights[i]`
/// (out-degree for pull-based updates) and each element occupies
/// `elem_bytes`.
///
/// Elements are grouped into cache lines by layout, lines mapped to sets
/// by `line_id % sets`, then Eq (1)–(3) give the expected miss rate.
pub fn predicted_miss_rate(weights: &[u64], elem_bytes: usize, geom: CacheGeometry) -> f64 {
    let total: u64 = weights.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let per_line = (geom.line_bytes / elem_bytes).max(1);
    let num_lines = weights.len().div_ceil(per_line);
    // P(l) per line.
    let mut p_line = vec![0.0f64; num_lines];
    for (i, &w) in weights.iter().enumerate() {
        p_line[i / per_line] += w as f64 / total as f64;
    }
    // Per-set denominators.
    let mut set_sum = vec![0.0f64; geom.sets];
    for (l, &p) in p_line.iter().enumerate() {
        set_sum[l % geom.sets] += p;
    }
    // E[M] = Σ_l P(l) (1 - p_l)^k.
    let k = geom.assoc as f64;
    let mut miss = 0.0;
    for (l, &p) in p_line.iter().enumerate() {
        if p == 0.0 {
            continue;
        }
        let denom = set_sum[l % geom.sets];
        if denom <= 0.0 {
            continue;
        }
        let p_l = (p / denom).min(1.0);
        miss += p * (1.0 - p_l).powf(k);
    }
    miss
}

/// Expected miss rate after applying a permutation (`perm[old] = new`) to
/// the vertex layout: weights are scattered to their new positions first.
pub fn predicted_miss_rate_permuted(
    weights: &[u64],
    perm: &[u32],
    elem_bytes: usize,
    geom: CacheGeometry,
) -> f64 {
    assert_eq!(weights.len(), perm.len());
    let mut permuted = vec![0u64; weights.len()];
    for (old, &w) in weights.iter().enumerate() {
        permuted[perm[old] as usize] = w;
    }
    predicted_miss_rate(&permuted, elem_bytes, geom)
}

/// Proposition 1, constructively: within one cache set, expected hit rate
/// of a line assignment (element probabilities grouped into lines).
/// Tests verify that swapping a hot element into a hotter line never
/// decreases this value under the proposition's precondition.
pub fn set_hit_rate(line_elem_probs: &[Vec<f64>], assoc: usize) -> f64 {
    let set_total: f64 = line_elem_probs.iter().map(|l| l.iter().sum::<f64>()).sum();
    if set_total <= 0.0 {
        return 1.0;
    }
    line_elem_probs
        .iter()
        .map(|l| {
            let p: f64 = l.iter().sum();
            let p_l = p / set_total;
            p * (1.0 - (1.0 - p_l).powf(assoc as f64))
        })
        .sum::<f64>()
        / set_total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn zipf_weights(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        let mut w: Vec<u64> = (1..=n)
            .map(|k| ((1e6 / (k as f64)) as u64).max(1))
            .collect();
        rng.shuffle(&mut w);
        w
    }

    #[test]
    fn geometry_roundtrip() {
        let g = CacheGeometry::new(32 * 1024, 8, 64);
        assert_eq!(g.lines(), 512);
        assert_eq!(g.sets, 64);
        assert_eq!(g.total_bytes(), 32 * 1024);
    }

    #[test]
    fn tiny_working_set_no_misses() {
        // Everything fits in one set's ways => p_l large => near-zero miss.
        let g = CacheGeometry {
            sets: 1,
            assoc: 16,
            line_bytes: 64,
        };
        let weights = vec![1u64; 8]; // one line (8 × 8B)
        let m = predicted_miss_rate(&weights, 8, g);
        assert!(m < 1e-9, "m={m}");
    }

    #[test]
    fn uniform_large_set_mostly_misses() {
        let g = CacheGeometry::new(8 * 1024, 8, 64); // 128 lines
        let weights = vec![1u64; 1 << 16]; // 8192 lines of 8 ids
        let m = predicted_miss_rate(&weights, 8, g);
        assert!(m > 0.9, "m={m}");
    }

    #[test]
    fn degree_sort_reduces_predicted_misses() {
        // The §5 claim: sorting by weight is optimal; at least it must
        // beat the shuffled layout.
        let weights = zipf_weights(1 << 14, 3);
        let g = CacheGeometry::new(64 * 1024, 16, 64);
        let shuffled = predicted_miss_rate(&weights, 8, g);
        let mut sorted = weights.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let sorted_m = predicted_miss_rate(&sorted, 8, g);
        assert!(
            sorted_m < shuffled * 0.9,
            "sorted={sorted_m} shuffled={shuffled}"
        );
    }

    #[test]
    fn sorted_beats_random_permutations() {
        // Proposition 2, empirically: no random permutation we try beats
        // the descending-sort layout.
        let weights = zipf_weights(1 << 10, 7);
        let g = CacheGeometry::new(4 * 1024, 8, 64);
        let mut sorted = weights.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let best = predicted_miss_rate(&sorted, 8, g);
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let perm = rng.permutation(weights.len());
            let m = predicted_miss_rate_permuted(&weights, &perm, 8, g);
            assert!(m >= best - 1e-9, "perm beat sorted: {m} < {best}");
        }
    }

    #[test]
    fn proposition1_swap_improves_set_hit_rate() {
        // Prop 1 precondition: P(l1) < P(l2) < 2/(k+1) · Σ_{l'∈S} P(l').
        // Build a set with many low-probability lines so the bound holds,
        // put hot element x1 in the colder line l1 and cold x2 in l2;
        // swapping them must improve the set hit rate.
        let assoc = 8;
        let mut lines: Vec<Vec<f64>> = (0..18).map(|_| vec![0.0025, 0.0025]).collect();
        lines.push(vec![0.004, 0.001]); // l1: P=0.005, x1=0.004 hot
        lines.push(vec![0.0005, 0.006]); // l2: P=0.0065 > P(l1)
        let total: f64 = lines.iter().flatten().sum();
        let bound = 2.0 / (assoc as f64 + 1.0) * total;
        assert!(0.0065 < bound, "precondition violated: bound={bound}");
        let before = set_hit_rate(&lines, assoc);
        // Swap x1 (l1, elem 0) with x2 (l2, elem 0).
        let x1 = lines[18][0];
        lines[18][0] = lines[19][0];
        lines[19][0] = x1;
        let after = set_hit_rate(&lines, assoc);
        assert!(after > before, "after={after} before={before}");
    }

    #[test]
    fn miss_rate_in_unit_interval() {
        crate::util::prop::check("E[M] ∈ [0,1]", 25, |gen| {
            let n = gen.usize(1..2000);
            let weights: Vec<u64> = (0..n).map(|_| gen.usize(0..100) as u64).collect();
            let g = CacheGeometry::new(
                [1024usize, 4096, 65536][gen.usize(0..3)],
                [2usize, 8, 16][gen.usize(0..3)],
                64,
            );
            let m = predicted_miss_rate(&weights, 8, g);
            assert!((0.0..=1.0 + 1e-12).contains(&m), "m={m}");
        });
    }
}
