//! Set-associative LRU cache simulator (the Dinero IV stand-in used to
//! validate the §5 analytical model, and the engine behind the simulated
//! stall-cycle metrics).

use crate::cache::model::CacheGeometry;

/// One cache level: `sets × assoc` lines of `line_bytes`.
#[derive(Debug, Clone)]
pub struct CacheSim {
    pub geom: CacheGeometry,
    /// `tags[set * assoc + way]` — line tag or u64::MAX when invalid.
    tags: Vec<u64>,
    /// LRU stamps parallel to `tags` (bigger = more recent).
    stamps: Vec<u64>,
    clock: u64,
    pub accesses: u64,
    pub misses: u64,
}

impl CacheSim {
    pub fn new(geom: CacheGeometry) -> CacheSim {
        let lines = geom.sets * geom.assoc;
        CacheSim {
            geom,
            tags: vec![u64::MAX; lines],
            stamps: vec![0; lines],
            clock: 0,
            accesses: 0,
            misses: 0,
        }
    }

    /// Convenience constructor from total capacity.
    pub fn with_capacity(total_bytes: usize, assoc: usize, line_bytes: usize) -> CacheSim {
        CacheSim::new(CacheGeometry::new(total_bytes, assoc, line_bytes))
    }

    /// Access a byte address; returns true on hit. LRU replacement.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        self.accesses += 1;
        let line = addr / self.geom.line_bytes as u64;
        let set = (line % self.geom.sets as u64) as usize;
        let base = set * self.geom.assoc;
        let ways = &mut self.tags[base..base + self.geom.assoc];
        // Hit?
        for (w, &tag) in ways.iter().enumerate() {
            if tag == line {
                self.stamps[base + w] = self.clock;
                return true;
            }
        }
        // Miss: evict LRU way.
        self.misses += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.geom.assoc {
            let s = self.stamps[base + w];
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if s < oldest {
                oldest = s;
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamps[base + victim] = self.clock;
        false
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }

    /// Invalidate all lines (counters kept).
    pub fn flush(&mut self) {
        self.tags.fill(u64::MAX);
        self.stamps.fill(0);
    }
}

/// Per-level hit counters from a [`Hierarchy`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierarchyCounters {
    pub accesses: u64,
    /// Hits at L1 / L2 / L3.
    pub hits: [u64; 3],
    /// Misses that went to DRAM.
    pub dram: u64,
}

impl HierarchyCounters {
    pub fn llc_miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.dram as f64 / self.accesses as f64
        }
    }
}

/// An inclusive multi-level hierarchy (up to 3 levels). Mirrors the
/// evaluation machine's shape at scaled capacities (DESIGN.md §3).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub levels: Vec<CacheSim>,
    pub counters: HierarchyCounters,
}

impl Hierarchy {
    pub fn new(levels: Vec<CacheSim>) -> Hierarchy {
        assert!(!levels.is_empty() && levels.len() <= 3);
        Hierarchy {
            levels,
            counters: HierarchyCounters::default(),
        }
    }

    /// The scaled default: 32 KiB 8-way L1d, 256 KiB 8-way L2, and an
    /// `llc_bytes` 16-way L3 (64 B lines throughout).
    pub fn scaled_default(llc_bytes: usize) -> Hierarchy {
        Hierarchy::new(vec![
            CacheSim::with_capacity(32 * 1024, 8, 64),
            CacheSim::with_capacity(256 * 1024, 8, 64),
            CacheSim::with_capacity(llc_bytes, 16, 64),
        ])
    }

    /// Access an address; returns the level index that hit (0-based), or
    /// `levels.len()` for DRAM. Fills all missed levels (inclusive).
    pub fn access(&mut self, addr: u64) -> usize {
        self.counters.accesses += 1;
        let mut hit_level = self.levels.len();
        for (i, level) in self.levels.iter_mut().enumerate() {
            if level.access(addr) {
                hit_level = i;
                break;
            }
        }
        if hit_level < self.levels.len() {
            self.counters.hits[hit_level] += 1;
        } else {
            self.counters.dram += 1;
        }
        hit_level
    }

    pub fn reset(&mut self) {
        for l in &mut self.levels {
            l.reset_counters();
            l.flush();
        }
        self.counters = HierarchyCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_within_capacity_hits_after_warmup() {
        let mut c = CacheSim::with_capacity(4096, 4, 64); // 64 lines
        for round in 0..3 {
            for i in 0..32u64 {
                let hit = c.access(i * 64);
                if round > 0 {
                    assert!(hit, "round {round} line {i} should hit");
                }
            }
        }
        assert_eq!(c.misses, 32); // compulsory only
    }

    #[test]
    fn capacity_misses_when_oversubscribed() {
        let mut c = CacheSim::with_capacity(4096, 4, 64); // 64 lines
        // Cycle through 128 lines: with LRU every access misses.
        for _ in 0..3 {
            for i in 0..128u64 {
                c.access(i * 64);
            }
        }
        assert!(c.miss_rate() > 0.99, "mr={}", c.miss_rate());
    }

    #[test]
    fn lru_keeps_hot_line() {
        let mut c = CacheSim::new(CacheGeometry {
            sets: 1,
            assoc: 2,
            line_bytes: 64,
        });
        // Two-way set; A kept hot while B/C alternate evicting each other.
        let a = 0u64;
        let b = 64;
        let cc = 128;
        c.access(a);
        c.access(b);
        assert!(c.access(a)); // hit, refreshes A
        c.access(cc); // evicts B (LRU), not A
        assert!(c.access(a));
        assert!(!c.access(b)); // B was evicted
    }

    #[test]
    fn full_associativity_no_conflicts() {
        // 64 lines fully associative: any 64-line working set has only
        // compulsory misses.
        let mut c = CacheSim::new(CacheGeometry {
            sets: 1,
            assoc: 64,
            line_bytes: 64,
        });
        // Strided addresses that would conflict in a set-indexed cache.
        for _ in 0..4 {
            for i in 0..64u64 {
                c.access(i * 64 * 128);
            }
        }
        assert_eq!(c.misses, 64);
    }

    #[test]
    fn miss_rate_monotone_in_capacity() {
        // Random accesses over a fixed footprint: bigger cache, fewer
        // misses.
        let mut rng = crate::util::rng::Rng::new(17);
        let addrs: Vec<u64> = (0..60_000).map(|_| rng.next_below(1 << 20)).collect();
        let mut rates = Vec::new();
        for kib in [16usize, 64, 256, 1024, 4096] {
            let mut c = CacheSim::with_capacity(kib * 1024, 8, 64);
            for &a in &addrs {
                c.access(a);
            }
            rates.push(c.miss_rate());
        }
        for w in rates.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{rates:?}");
        }
    }

    #[test]
    fn hierarchy_levels_filter() {
        let mut h = Hierarchy::scaled_default(1024 * 1024);
        // Working set of 64 KiB: misses L1, fits L2.
        let lines = 1024u64;
        for _ in 0..4 {
            for i in 0..lines {
                h.access(i * 64);
            }
        }
        let c = h.counters;
        assert_eq!(c.accesses, 4 * lines);
        assert!(c.hits[1] > 0, "L2 should absorb L1 capacity misses: {c:?}");
        assert_eq!(c.dram, lines, "only compulsory misses reach DRAM: {c:?}");
    }
}
