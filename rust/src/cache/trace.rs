//! Memory-trace generation for the graph applications.
//!
//! The §5 model covers the *vertex-value vector* accesses — the dominant
//! random stream in pull-based graph updates. [`vertex_trace`] emits that
//! stream (one access per edge, addressed by source id); [`full_trace`]
//! additionally interleaves the sequential edge-array and output streams,
//! which is what the stall estimator feeds through the simulated
//! hierarchy. Traces can be sampled (every `1/rate` edges) to keep
//! simulation affordable on big graphs; miss *rates* are preserved because
//! sampling is applied per-vertex-block, not per-set.

use crate::graph::{Csr, VertexId};

/// Classified access used by the stall model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    /// Random read into the vertex-data vector (addr).
    VertexRead(u64),
    /// Sequential read of the edge array.
    EdgeRead(u64),
    /// Sequential write of the output array.
    OutWrite(u64),
}

impl Access {
    pub fn addr(self) -> u64 {
        match self {
            Access::VertexRead(a) | Access::EdgeRead(a) | Access::OutWrite(a) => a,
        }
    }
}

/// Address-space layout for the synthetic traces: regions are spaced far
/// apart so they never alias.
pub const VERTEX_BASE: u64 = 0;
pub const EDGE_BASE: u64 = 1 << 40;
pub const OUT_BASE: u64 = 1 << 41;
/// Frontier membership flags (the frontier apps' extra random stream).
pub const FRONTIER_BASE: u64 = 1 << 43;

/// The random vertex-data access stream of a pull-mode sweep over `g`
/// (destinations in id order, reading each in-neighbor's data).
/// `elem_bytes` is the per-vertex payload (8 for PageRank's f64 rank,
/// 8*K for CF's K-float latent vector). `sample_every >= 1` keeps one
/// destination vertex in every `sample_every` (all its edges), preserving
/// the per-line reuse structure.
pub fn vertex_trace(g_pull: &Csr, elem_bytes: u64, sample_every: usize) -> Vec<u64> {
    let step = sample_every.max(1);
    let mut out = Vec::new();
    for v in (0..g_pull.num_vertices()).step_by(step) {
        for &u in g_pull.neighbors(v as VertexId) {
            out.push(VERTEX_BASE + u as u64 * elem_bytes);
        }
    }
    out
}

/// Full classified trace of one pull-mode iteration: for each destination
/// v: sequential edge reads, a random vertex read per in-neighbor, one
/// output write.
pub fn full_trace(g_pull: &Csr, elem_bytes: u64, sample_every: usize) -> Vec<Access> {
    let step = sample_every.max(1);
    let mut out = Vec::new();
    for v in (0..g_pull.num_vertices()).step_by(step) {
        let lo = g_pull.offsets[v];
        let hi = g_pull.offsets[v + 1];
        for (k, &u) in g_pull.neighbors(v as VertexId).iter().enumerate() {
            out.push(Access::EdgeRead(EDGE_BASE + (lo + k as u64) * 4));
            out.push(Access::VertexRead(VERTEX_BASE + u as u64 * elem_bytes));
        }
        let _ = hi;
        out.push(Access::OutWrite(OUT_BASE + v as u64 * elem_bytes));
    }
    out
}

/// One frontier-app pull sweep (BFS/BC/SSSP — Tables 7/8): per
/// destination, a sequential edge read plus a random *frontier
/// membership* probe per in-neighbor (dense byte, or packed bit when
/// `bitvector` — an 8x footprint shrink), plus `vertex_elem` bytes of
/// per-vertex payload when `vertex_elem > 0` (8B σ for BC, 8B distances
/// for SSSP, the 4B parent probe for BFS), then one output write.
pub fn frontier_trace(
    g_pull: &Csr,
    vertex_elem: u64,
    bitvector: bool,
    sample_every: usize,
) -> Vec<Access> {
    let step = sample_every.max(1);
    let mut out = Vec::new();
    for v in (0..g_pull.num_vertices()).step_by(step) {
        let lo = g_pull.offsets[v];
        for (k, &u) in g_pull.neighbors(v as VertexId).iter().enumerate() {
            out.push(Access::EdgeRead(EDGE_BASE + (lo + k as u64) * 4));
            let faddr = if bitvector { u as u64 / 8 } else { u as u64 };
            out.push(Access::VertexRead(FRONTIER_BASE + faddr));
            if vertex_elem > 0 {
                out.push(Access::VertexRead(VERTEX_BASE + u as u64 * vertex_elem));
            }
        }
        out.push(Access::OutWrite(OUT_BASE + v as u64 * 8));
    }
    out
}

/// The same iteration under CSR segmenting: per segment, destinations are
/// walked and only sources within the segment are read; then the merge
/// pass reads the per-segment intermediates and writes the dense output —
/// all sequential. Emits the equivalent access stream.
pub fn segmented_trace(
    sg: &crate::segment::SegmentedCsr,
    elem_bytes: u64,
    sample_every: usize,
) -> Vec<Access> {
    let step = sample_every.max(1);
    let mut out = Vec::new();
    // Intermediate vectors live in their own region per segment.
    let inter_base = |s: usize| (1u64 << 42) + (s as u64) * (1 << 34);
    for (si, seg) in sg.segments.iter().enumerate() {
        for i in (0..seg.num_dsts()).step_by(step) {
            let lo = seg.offsets[i];
            let hi = seg.offsets[i + 1];
            for (k, &u) in seg.sources[lo as usize..hi as usize].iter().enumerate() {
                out.push(Access::EdgeRead(EDGE_BASE + (lo + k as u64) * 4));
                out.push(Access::VertexRead(VERTEX_BASE + u as u64 * elem_bytes));
            }
            out.push(Access::OutWrite(inter_base(si) + i as u64 * elem_bytes));
        }
    }
    // Merge phase: sequential read of each segment's intermediates +
    // dense output writes.
    for (si, seg) in sg.segments.iter().enumerate() {
        for i in (0..seg.num_dsts()).step_by(step) {
            out.push(Access::EdgeRead(inter_base(si) + i as u64 * elem_bytes));
            out.push(Access::OutWrite(OUT_BASE + seg.dst_ids[i] as u64 * elem_bytes));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn small() -> Csr {
        let (n, e) = generators::rmat(8, 8, generators::RmatParams::graph500(), 5);
        Csr::from_edges(n, &e).transpose() // pull orientation
    }

    #[test]
    fn vertex_trace_one_per_edge() {
        let g = small();
        let t = vertex_trace(&g, 8, 1);
        assert_eq!(t.len(), g.num_edges());
        // Addresses bounded by n * elem.
        let maxaddr = (g.num_vertices() as u64) * 8;
        assert!(t.iter().all(|&a| a < maxaddr));
    }

    #[test]
    fn sampling_reduces_length() {
        let g = small();
        let full = vertex_trace(&g, 8, 1);
        let s4 = vertex_trace(&g, 8, 4);
        assert!(s4.len() < full.len());
        assert!(s4.len() > full.len() / 16); // degree skew tolerance
    }

    #[test]
    fn full_trace_classification() {
        let g = small();
        let t = full_trace(&g, 8, 1);
        let vr = t.iter().filter(|a| matches!(a, Access::VertexRead(_))).count();
        let er = t.iter().filter(|a| matches!(a, Access::EdgeRead(_))).count();
        let ow = t.iter().filter(|a| matches!(a, Access::OutWrite(_))).count();
        assert_eq!(vr, g.num_edges());
        assert_eq!(er, g.num_edges());
        assert_eq!(ow, g.num_vertices());
    }

    #[test]
    fn segmented_trace_confines_vertex_reads() {
        let (n, e) = generators::rmat(8, 8, generators::RmatParams::graph500(), 6);
        let g = Csr::from_edges(n, &e);
        let sg = crate::segment::SegmentedCsr::build(&g, 32);
        let t = segmented_trace(&sg, 8, 1);
        // Vertex reads appear in segment-contiguous runs: within each run
        // the address span is <= seg_size * elem.
        let mut spans: Vec<(u64, u64)> = Vec::new();
        let mut cur: Option<(u64, u64)> = None;
        for a in &t {
            match a {
                Access::VertexRead(addr) => {
                    cur = Some(match cur {
                        None => (*addr, *addr),
                        Some((lo, hi)) => (lo.min(*addr), hi.max(*addr)),
                    });
                }
                Access::OutWrite(_) => {}
                Access::EdgeRead(_) => {}
            }
        }
        if let Some(s) = cur {
            spans.push(s);
        }
        // Whole-trace span is bounded by graph size; detailed per-segment
        // confinement is exercised by the stall model tests.
        assert!(spans[0].1 <= n as u64 * 8);
    }
}
