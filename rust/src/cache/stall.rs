//! Stall-cycle estimation — the stand-in for the paper's `perf`-measured
//! "cycles stalled on memory" (Tables 7/8, Figures 2/3/9).
//!
//! A classified trace ([`super::trace`]) is run through the simulated
//! hierarchy; stalls are `Σ hits(level) × latency(level)` with sequential
//! streams charged the *prefetched* DRAM cost (§2.3: "Sequential access to
//! DRAM effectively uses all memory bandwidth ... and benefits from
//! hardware prefetchers"; "random access to DRAM is 6-8x more expensive
//! than random access to LLC or sequential accesses to DRAM").

use super::sim::Hierarchy;
use super::trace::Access;

/// Latency model (cycles). Defaults follow Ivy Bridge folklore numbers;
/// only the *ratios* matter for reproducing the paper's shapes.
#[derive(Debug, Clone, Copy)]
pub struct StallModel {
    pub l1: f64,
    pub l2: f64,
    pub l3: f64,
    /// Random DRAM access (cache-line granularity, untranslated pointer
    /// chase).
    pub dram_random: f64,
    /// Effective per-access cost of a prefetched sequential DRAM stream.
    pub dram_sequential: f64,
}

impl Default for StallModel {
    fn default() -> Self {
        StallModel {
            l1: 0.0,        // L1 hits don't stall the pipeline
            l2: 8.0,
            l3: 30.0,
            dram_random: 200.0,
            dram_sequential: 25.0, // ≈ 8x cheaper than random (paper §2.3)
        }
    }
}

/// Result of a stall estimation run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallEstimate {
    pub accesses: u64,
    pub stall_cycles: f64,
    pub llc_misses: u64,
    pub llc_miss_rate: f64,
}

impl StallEstimate {
    pub fn stalls_per_access(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.stall_cycles / self.accesses as f64
        }
    }
}

/// Run a classified trace through `hier`, charging latencies per the
/// model. Sequential streams (edge reads, output writes) that miss all
/// levels are charged `dram_sequential`; random vertex reads that miss
/// are charged `dram_random`.
pub fn estimate(trace: &[Access], hier: &mut Hierarchy, model: StallModel) -> StallEstimate {
    let nlev = hier.levels.len();
    let mut stall = 0.0f64;
    let mut llc_misses = 0u64;
    for &a in trace {
        let level = hier.access(a.addr());
        let lat = match level {
            0 => model.l1,
            1 => model.l2,
            2 => model.l3,
            _ => {
                llc_misses += 1;
                match a {
                    Access::VertexRead(_) => model.dram_random,
                    Access::EdgeRead(_) | Access::OutWrite(_) => model.dram_sequential,
                }
            }
        };
        // Treat level==nlev when fewer than 3 levels configured.
        let lat = if level >= nlev && level < 3 {
            match a {
                Access::VertexRead(_) => model.dram_random,
                _ => model.dram_sequential,
            }
        } else {
            lat
        };
        stall += lat;
    }
    StallEstimate {
        accesses: trace.len() as u64,
        stall_cycles: stall,
        llc_misses,
        llc_miss_rate: if trace.is_empty() {
            0.0
        } else {
            llc_misses as f64 / trace.len() as f64
        },
    }
}

/// Convenience: estimate one pull-iteration's stalls for a graph with the
/// default scaled hierarchy.
pub fn estimate_pull_iteration(
    g_pull: &crate::graph::Csr,
    elem_bytes: u64,
    llc_bytes: usize,
    sample_every: usize,
) -> StallEstimate {
    let trace = super::trace::full_trace(g_pull, elem_bytes, sample_every);
    let mut hier = Hierarchy::scaled_default(llc_bytes);
    estimate(&trace, &mut hier, StallModel::default())
}

/// Estimate one frontier-app pull sweep (BFS/BC/SSSP, Tables 7/8) with
/// the default scaled hierarchy. See [`super::trace::frontier_trace`]
/// for the access-stream shape.
pub fn estimate_frontier_iteration(
    g_pull: &crate::graph::Csr,
    vertex_elem: u64,
    bitvector: bool,
    llc_bytes: usize,
    sample_every: usize,
) -> StallEstimate {
    let trace = super::trace::frontier_trace(g_pull, vertex_elem, bitvector, sample_every);
    let mut hier = Hierarchy::scaled_default(llc_bytes);
    estimate(&trace, &mut hier, StallModel::default())
}

/// Whole-iteration frontier-app estimate, registry-ready: samples the
/// trace on big graphs (one destination in every `m/4M`) and scales the
/// totals back up by the sample factor, so `stall_cycles`, `accesses`
/// and `llc_misses` are comparable across graph sizes while the miss
/// *rate* stays the sampled measurement. `reordered` applies the §3.3
/// coarse degree sort first, mirroring the reordering variants.
pub fn simulate_frontier_app(
    g: &crate::graph::Csr,
    llc_bytes: usize,
    vertex_elem: u64,
    reordered: bool,
    bitvector: bool,
) -> StallEstimate {
    let sample = (g.num_edges() / 4_000_000).max(1);
    let pull = if reordered {
        let (h, _) = crate::reorder::reorder(g, crate::reorder::Ordering::CoarseDegreeSort);
        h.transpose()
    } else {
        g.transpose()
    };
    let est = estimate_frontier_iteration(&pull, vertex_elem, bitvector, llc_bytes, sample);
    StallEstimate {
        accesses: est.accesses * sample as u64,
        stall_cycles: est.stall_cycles * sample as f64,
        llc_misses: est.llc_misses * sample as u64,
        llc_miss_rate: est.llc_miss_rate,
    }
}

/// Estimate a segmented iteration's stalls (for the Fig 2/9 comparisons).
pub fn estimate_segmented_iteration(
    sg: &crate::segment::SegmentedCsr,
    elem_bytes: u64,
    llc_bytes: usize,
    sample_every: usize,
) -> StallEstimate {
    let trace = super::trace::segmented_trace(sg, elem_bytes, sample_every);
    let mut hier = Hierarchy::scaled_default(llc_bytes);
    estimate(&trace, &mut hier, StallModel::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};

    fn graph(scale: u32) -> Csr {
        let (n, e) = generators::rmat(scale, 16, generators::RmatParams::graph500(), 12);
        Csr::from_edges(n, &e)
    }

    /// A shrunken hierarchy (1 KiB L1 / 4 KiB L2 / `llc` L3) so that small
    /// test graphs still exhibit the paper's working-set-vs-LLC regime.
    fn tiny_hier(llc: usize) -> Hierarchy {
        Hierarchy::new(vec![
            crate::cache::sim::CacheSim::with_capacity(1024, 8, 64),
            crate::cache::sim::CacheSim::with_capacity(4 * 1024, 8, 64),
            crate::cache::sim::CacheSim::with_capacity(llc, 16, 64),
        ])
    }

    #[test]
    fn segmenting_reduces_stalls() {
        // The headline effect: with vertex data ≫ LLC, the segmented trace
        // must stall substantially less than the unsegmented one.
        let g = graph(13); // 8192 vertices => 64 KiB of f64 data
        let llc = 16 * 1024; // effective LLC holds 1/4 of vertex data
        let pull = g.transpose();
        let trace = crate::cache::trace::full_trace(&pull, 8, 1);
        let base = estimate(&trace, &mut tiny_hier(llc), StallModel::default());
        let seg_size = llc / 8 / 2; // half the LLC for source data
        let sg = crate::segment::SegmentedCsr::build(&g, seg_size);
        let strace = crate::cache::trace::segmented_trace(&sg, 8, 1);
        let seg = estimate(&strace, &mut tiny_hier(llc), StallModel::default());
        assert!(
            seg.stall_cycles < 0.7 * base.stall_cycles,
            "seg={} base={}",
            seg.stall_cycles,
            base.stall_cycles
        );
        // And the LLC miss-rate drop mirrors §6.3 (46% -> 10% on Twitter).
        assert!(seg.llc_miss_rate < base.llc_miss_rate);
    }

    #[test]
    fn reordering_reduces_stalls_on_random_order_graph() {
        let g = graph(13);
        let (sorted, _) = crate::reorder::reorder(&g, crate::reorder::Ordering::DegreeSort);
        let llc = 16 * 1024;
        let base = estimate_pull_iteration(&g.transpose(), 8, llc, 1);
        let reord = estimate_pull_iteration(&sorted.transpose(), 8, llc, 1);
        assert!(
            reord.stall_cycles < base.stall_cycles,
            "reord={} base={}",
            reord.stall_cycles,
            base.stall_cycles
        );
    }

    #[test]
    fn small_graph_fits_cache_no_dram() {
        let g = graph(8); // 256 vertices: 2 KiB vertex data
        let est = estimate_pull_iteration(&g.transpose(), 8, 1 << 20, 1);
        // Everything fits: only compulsory misses, tiny miss rate.
        assert!(est.llc_miss_rate < 0.05, "mr={}", est.llc_miss_rate);
    }

    #[test]
    fn stalls_scale_with_trace() {
        let g = graph(10);
        let pull = g.transpose();
        let full = estimate_pull_iteration(&pull, 8, 8 * 1024, 1);
        let sampled = estimate_pull_iteration(&pull, 8, 8 * 1024, 4);
        assert!(sampled.accesses < full.accesses);
        assert!(sampled.stall_cycles < full.stall_cycles);
    }
}
