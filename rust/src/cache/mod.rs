//! Cache substrate: a Dinero-IV-style set-associative LRU simulator
//! ([`sim`]), the paper's §5 analytical miss-rate model ([`model`]),
//! memory-trace generation for the graph apps ([`trace`]), and the
//! stall-cycle estimator ([`stall`]) that substitutes for the paper's
//! `perf`-measured "cycles stalled on memory". When the hardware PMU is
//! reachable, [`crate::obs::pmu`] reads the real counters alongside this
//! simulation so the model can be validated against measurement
//! (DESIGN.md §3).

pub mod sim;
pub mod model;
pub mod trace;
pub mod stall;

pub use model::CacheGeometry;
pub use sim::{CacheSim, Hierarchy, HierarchyCounters};
pub use stall::{StallEstimate, StallModel};
