//! Shared-memory parallel runtime.
//!
//! The paper parallelizes with Intel Cilk Plus and implements a
//! *work-estimating* load balancer on top (§3.2): after degree-sorting,
//! high-degree vertices cluster, so ranges must be split by **cost** (sum
//! of degrees) rather than by vertex count. The offline crate mirror has
//! neither `rayon` nor Cilk, so this module provides the substrate:
//!
//! - [`pool`]: a persistent worker pool (workers + the calling thread).
//! - [`parallel_for`] / [`parallel_for_dynamic`]: static and
//!   self-scheduling loops.
//! - [`parallel_for_cost`]: the paper's divide-and-conquer cost-based
//!   work-stealing scheme.
//! - [`atomics`]: CAS-based f64/f32 atomic adds (for the HAtomic baseline).
//! - [`UnsafeSlice`]: disjoint-index concurrent writes without locks.

pub mod pool;
pub mod atomics;

use pool::global;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads the global pool uses (`CAGRA_THREADS` env
/// override, else `available_parallelism`).
pub fn num_threads() -> usize {
    global().num_threads()
}

/// Run `f(thread_id)` on every pool thread and wait for all.
pub fn run_on_all(f: &(dyn Fn(usize) + Sync)) {
    global().run(f);
}

/// Statically-partitioned parallel loop: `0..n` is split into one
/// contiguous chunk per thread; `f(i)` is called for every index.
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    let nt = num_threads();
    if n == 0 {
        return;
    }
    if nt == 1 || n < 2 * nt {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(nt);
    run_on_all(&|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        for i in lo..hi {
            f(i);
        }
    });
}

/// Dynamically self-scheduled parallel loop: threads grab `grain`-sized
/// chunks from a shared cursor. Better than [`parallel_for`] when per-index
/// cost is irregular but cheap to batch.
pub fn parallel_for_dynamic(n: usize, grain: usize, f: impl Fn(usize) + Sync) {
    let grain = grain.max(1);
    if n == 0 {
        return;
    }
    if num_threads() == 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    run_on_all(&|_| loop {
        let lo = cursor.fetch_add(grain, Ordering::Relaxed);
        if lo >= n {
            break;
        }
        let hi = (lo + grain).min(n);
        for i in lo..hi {
            f(i);
        }
    });
}

/// Parallel loop over contiguous ranges: each call gets `(lo, hi)` with
/// static partitioning — useful when the body wants chunk-local state.
pub fn parallel_ranges(n: usize, f: impl Fn(usize, usize) + Sync) {
    let nt = num_threads();
    if n == 0 {
        return;
    }
    if nt == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(nt);
    run_on_all(&|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        if lo < hi {
            f(lo, hi);
        }
    });
}

/// The paper's §3.2 work-estimating divide-and-conquer scheduler.
///
/// `cost(lo, hi)` estimates the work in a vertex range (typically via a
/// degree prefix-sum: "the sum of their neighbors ... how many reads it
/// will make to the rank array"). Ranges costlier than `threshold` are
/// split in two; small ranges are processed by `process(lo, hi)`. Idle
/// workers steal pending ranges from a shared queue.
pub fn parallel_for_cost(
    n: usize,
    threshold: u64,
    cost: impl Fn(usize, usize) -> u64 + Sync,
    process: impl Fn(usize, usize) + Sync,
) {
    if n == 0 {
        return;
    }
    if num_threads() == 1 {
        // Serial fast path: still honor the threshold so behaviour (and
        // cache footprint per call) matches the parallel schedule. The
        // stack is a fixed array — splits halve the range, so depth is
        // bounded by ⌈log2 n⌉ + 1 ≤ 65 and the path stays allocation-free
        // (required by the engine's zero-allocation steady state, which
        // tests assert under CAGRA_THREADS=1).
        let mut stack = [(0usize, 0usize); 128];
        stack[0] = (0, n);
        let mut sp = 1usize;
        while sp > 0 {
            sp -= 1;
            let (lo, hi) = stack[sp];
            // `sp + 2 > len` cannot happen given the depth bound; process
            // directly rather than overflow if it ever did.
            if hi - lo <= 1 || cost(lo, hi) <= threshold || sp + 2 > stack.len() {
                process(lo, hi);
            } else {
                let mid = lo + (hi - lo) / 2;
                stack[sp] = (mid, hi);
                stack[sp + 1] = (lo, mid);
                sp += 2;
            }
        }
        return;
    }
    // Shared LIFO of pending ranges + count of in-flight tasks so workers
    // know when to quit (empty queue alone is not termination: a running
    // task may still push halves).
    let queue: Mutex<Vec<(usize, usize)>> = Mutex::new(vec![(0, n)]);
    let in_flight = AtomicUsize::new(1);
    run_on_all(&|_| loop {
        let item = queue.lock().unwrap_or_else(|p| p.into_inner()).pop();
        match item {
            Some((lo, hi)) => {
                if hi - lo <= 1 || cost(lo, hi) <= threshold {
                    process(lo, hi);
                } else {
                    let mid = lo + (hi - lo) / 2;
                    in_flight.fetch_add(1, Ordering::Relaxed);
                    queue.lock().unwrap_or_else(|p| p.into_inner()).push((mid, hi));
                    // Process the left half ourselves by re-queueing it;
                    // keeps the queue the single source of truth.
                    in_flight.fetch_add(1, Ordering::Relaxed);
                    queue.lock().unwrap_or_else(|p| p.into_inner()).push((lo, mid));
                }
                in_flight.fetch_sub(1, Ordering::Release);
            }
            None => {
                if in_flight.load(Ordering::Acquire) == 0 {
                    break;
                }
                std::hint::spin_loop();
            }
        }
    });
}

/// Parallel map-reduce: each thread folds its share of `0..n` with `fold`,
/// partials are combined with `combine` on the caller.
pub fn parallel_reduce<T: Send>(
    n: usize,
    identity: impl Fn() -> T + Sync,
    fold: impl Fn(T, usize) -> T + Sync,
    combine: impl Fn(T, T) -> T,
) -> T {
    let nt = num_threads();
    if n == 0 {
        return identity();
    }
    if nt == 1 || n < 2 * nt {
        let mut acc = identity();
        for i in 0..n {
            acc = fold(acc, i);
        }
        return acc;
    }
    let partials: Mutex<Vec<T>> = Mutex::new(Vec::with_capacity(nt));
    let chunk = n.div_ceil(nt);
    run_on_all(&|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        if lo >= hi {
            return;
        }
        let mut acc = identity();
        for i in lo..hi {
            acc = fold(acc, i);
        }
        partials.lock().unwrap_or_else(|p| p.into_inner()).push(acc);
    });
    partials
        .into_inner()
        .unwrap()
        .into_iter()
        .fold(identity(), combine)
}

/// Wrapper allowing concurrent writes to **disjoint** indices of a slice
/// from multiple threads without locks or atomics. The caller must
/// guarantee disjointness (each index written by at most one thread per
/// parallel region) — exactly the guarantee segment-local processing and
/// the block merge provide.
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: UnsafeSlice is a lifetime-tagged `*mut T` + len over an
// exclusively-borrowed slice. Sending it to another thread is morally
// sending disjoint `&mut T`s, which needs exactly `T: Send` (no `Sync`
// bound: the disjointness contract on `write`/`get_mut`/`slice_mut`
// means no element is ever *shared* between threads, only partitioned).
unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}
// SAFETY: `&UnsafeSlice` only exposes writes/reborrows of disjoint
// elements (the caller contract on every unsafe method); with that
// contract upheld, concurrent use from many threads is a partition of
// the slice into per-thread `&mut T`s — again requiring only `T: Send`.
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `v` to index `i`.
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently accesses index `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        // SAFETY: `i < len` (caller contract) keeps the offset in
        // bounds of the borrowed slice; exclusivity at index `i` is the
        // caller's disjointness guarantee.
        unsafe { *self.ptr.add(i) = v };
    }

    /// Get a mutable reference to index `i`.
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently accesses index `i`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        // SAFETY: in bounds by `i < len` (caller contract); producing
        // `&mut` is exclusive because no other thread touches index `i`
        // (caller contract). NOTE the provenance of the result covers
        // only element `i` — widening it to a longer slice is UB; use
        // `slice_mut` for ranges.
        unsafe { &mut *self.ptr.add(i) }
    }

    /// Reborrow the subrange `lo..lo + len` as a mutable slice.
    ///
    /// # Safety
    /// `lo + len <= self.len()`, and no other thread concurrently
    /// accesses any index in `lo..lo + len`. Unlike taking `get_mut(lo)`
    /// and widening it (which is UB — that reference's provenance spans
    /// one element), the returned slice derives straight from the base
    /// pointer, whose provenance covers the whole underlying slice.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, lo: usize, len: usize) -> &mut [T] {
        debug_assert!(lo.checked_add(len).is_some_and(|hi| hi <= self.len));
        // SAFETY: the range is in bounds (caller contract, debug-checked
        // above) and exclusively owned by this thread for the duration
        // of the borrow (caller's disjointness contract).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), len) }
    }
}

thread_local! {
    /// Set while executing inside a pool worker so nested parallel calls
    /// degrade to serial instead of deadlocking.
    pub(crate) static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_dynamic_covers_all() {
        let n = 5_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_ranges_partition() {
        let n = 1234;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(n, |lo, hi| {
            for h in &hits[lo..hi] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn cost_based_covers_all_with_skewed_costs() {
        // Power-law-ish costs: vertex 0 is enormously expensive.
        let n = 4096;
        let degree: Vec<u64> = (0..n).map(|i| if i < 8 { 100_000 } else { 2 }).collect();
        let prefix: Vec<u64> = std::iter::once(0)
            .chain(degree.iter().scan(0u64, |acc, &d| {
                *acc += d;
                Some(*acc)
            }))
            .collect();
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_cost(
            n,
            50_000,
            |lo, hi| prefix[hi] - prefix[lo],
            |lo, hi| {
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reduce_sums() {
        let n = 100_000usize;
        let total = parallel_reduce(n, || 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn unsafe_slice_disjoint_writes() {
        let mut data = vec![0u64; 1000];
        let s = UnsafeSlice::new(&mut data);
        // SAFETY: each loop index writes only its own slot; i < 1000.
        parallel_for(1000, |i| unsafe { s.write(i, i as u64 * 3) });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    #[test]
    fn nested_parallel_for_is_safe() {
        let outer = AtomicU64::new(0);
        parallel_for(16, |_| {
            // Nested call must not deadlock; it runs serially in-worker.
            parallel_for(16, |_| {
                outer.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 256);
    }
}
