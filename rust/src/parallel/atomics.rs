//! CAS-based atomic floating-point accumulation.
//!
//! The paper measures atomic f64 adds at ~3× the cost of plain stores
//! (§6.4, HAtomic); these wrappers are used by the GridGraph-style and
//! HAtomic baselines and by push-mode EdgeMap.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// An f64 updatable atomically via compare-and-swap on its bit pattern.
#[repr(transparent)]
#[derive(Debug, Default)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        Self {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> f64 {
        f64::from_bits(self.bits.load(order))
    }

    #[inline]
    pub fn store(&self, v: f64, order: Ordering) {
        self.bits.store(v.to_bits(), order);
    }

    /// Atomically `self += v`; returns the previous value.
    #[inline]
    pub fn fetch_add(&self, v: f64, order: Ordering) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(cur, next, order, Ordering::Relaxed) {
                Ok(_) => return f64::from_bits(cur),
                Err(actual) => cur = actual,
            }
        }
    }

    /// Atomically `self = min(self, v)`.
    #[inline]
    pub fn fetch_min(&self, v: f64, order: Ordering) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cur_f = f64::from_bits(cur);
            if cur_f <= v {
                return cur_f;
            }
            match self
                .bits
                .compare_exchange_weak(cur, v.to_bits(), order, Ordering::Relaxed)
            {
                Ok(_) => return cur_f,
                Err(actual) => cur = actual,
            }
        }
    }
}

/// An f32 updatable atomically via CAS.
#[repr(transparent)]
#[derive(Debug, Default)]
pub struct AtomicF32 {
    bits: AtomicU32,
}

impl AtomicF32 {
    pub fn new(v: f32) -> Self {
        Self {
            bits: AtomicU32::new(v.to_bits()),
        }
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> f32 {
        f32::from_bits(self.bits.load(order))
    }

    #[inline]
    pub fn store(&self, v: f32, order: Ordering) {
        self.bits.store(v.to_bits(), order);
    }

    #[inline]
    pub fn fetch_add(&self, v: f32, order: Ordering) -> f32 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f32::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(cur, next, order, Ordering::Relaxed) {
                Ok(_) => return f32::from_bits(cur),
                Err(actual) => cur = actual,
            }
        }
    }
}

/// View a `&mut [f64]` as `&[AtomicF64]` (same layout; `repr(transparent)`).
pub fn as_atomic_f64(xs: &mut [f64]) -> &[AtomicF64] {
    let len = xs.len();
    // SAFETY: AtomicF64 is repr(transparent) over AtomicU64, which has
    // the same size/alignment as f64, so the cast is layout-valid. The
    // pointer comes from `as_mut_ptr` on the exclusive borrow (NOT
    // `as_ptr`, whose shared reborrow would strip write provenance under
    // Stacked Borrows — the atomics write through this pointer). The
    // `&mut` is reborrowed for the returned lifetime, so no other access
    // aliases the atomics while the view lives.
    unsafe { std::slice::from_raw_parts(xs.as_mut_ptr() as *const AtomicF64, len) }
}

/// View a `&mut [f32]` as `&[AtomicF32]`.
pub fn as_atomic_f32(xs: &mut [f32]) -> &[AtomicF32] {
    let len = xs.len();
    // SAFETY: as for `as_atomic_f64` — transparent layout over
    // AtomicU32, write provenance retained via `as_mut_ptr`, exclusivity
    // for the view's lifetime from the `&mut` reborrow.
    unsafe { std::slice::from_raw_parts(xs.as_mut_ptr() as *const AtomicF32, len) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::parallel_for;

    #[test]
    fn concurrent_adds_sum_exactly() {
        // Integer-valued doubles add exactly; checks atomicity.
        let acc = AtomicF64::new(0.0);
        parallel_for(10_000, |_| {
            acc.fetch_add(1.0, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 10_000.0);
    }

    #[test]
    fn fetch_min_converges() {
        let m = AtomicF64::new(f64::INFINITY);
        parallel_for(1000, |i| {
            m.fetch_min(i as f64, Ordering::Relaxed);
        });
        assert_eq!(m.load(Ordering::Relaxed), 0.0);
    }

    #[test]
    fn slice_view_roundtrip() {
        let mut xs = vec![1.0f64, 2.0, 3.0];
        {
            let a = as_atomic_f64(&mut xs);
            a[1].fetch_add(10.0, Ordering::Relaxed);
        }
        assert_eq!(xs, vec![1.0, 12.0, 3.0]);
    }

    #[test]
    fn f32_adds() {
        let acc = AtomicF32::new(0.0);
        parallel_for(4096, |_| {
            acc.fetch_add(1.0, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 4096.0);
    }
}
