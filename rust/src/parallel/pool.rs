//! Persistent worker pool.
//!
//! One global pool is created lazily; `run(f)` broadcasts a job to all
//! workers *and* executes a share on the calling thread, returning when
//! every participant finished. Nested `run` calls from inside a worker run
//! the job serially on the caller (no deadlock).
//!
//! The job is passed as a raw wide pointer with an epoch/completion
//! handshake; this is sound because `run` does not return until all
//! workers have finished with the pointer.

use super::IN_WORKER;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

struct JobSlot {
    /// Lifetime-erased `&dyn Fn(usize)` valid for the duration of the
    /// epoch. A first-class raw wide pointer (NOT a `(data, vtable)`
    /// tuple: the layout of fat pointers is unspecified, so the old
    /// transmute-to-tuple trick was UB by layout assumption).
    ptr: Option<*const (dyn Fn(usize) + Sync)>,
    epoch: u64,
}

// SAFETY: JobSlot crosses threads only inside `Shared.slot`'s Mutex, and
// the pointer is only dereferenced between the epoch publish and the
// done-count handshake in `run`, during which `run` keeps the referent
// borrowed (it does not return until every worker reports done). The
// pointee is `Sync`, so shared calls from many workers are sound.
unsafe impl Send for JobSlot {}

struct Shared {
    slot: Mutex<JobSlot>,
    work_cv: Condvar,
    done: Mutex<usize>,
    done_cv: Condvar,
    shutdown: AtomicBool,
    n_spawned: usize,
}

/// The worker pool. Thread ids passed to jobs are `0..num_threads()`;
/// id 0 is the calling thread.
pub struct ThreadPool {
    shared: &'static Shared,
    n_threads: usize,
    running: AtomicBool,
    epoch: AtomicU64,
}

impl ThreadPool {
    fn new(n_threads: usize) -> ThreadPool {
        let n_spawned = n_threads.saturating_sub(1);
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            slot: Mutex::new(JobSlot { ptr: None, epoch: 0 }),
            work_cv: Condvar::new(),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            n_spawned,
        }));
        for worker_id in 1..n_threads {
            std::thread::Builder::new()
                .name(format!("cagra-worker-{worker_id}"))
                .spawn(move || worker_loop(shared, worker_id))
                .expect("spawning pool worker");
        }
        ThreadPool {
            shared,
            n_threads,
            running: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
        }
    }

    pub fn num_threads(&self) -> usize {
        self.n_threads
    }

    /// Broadcast `f` to all threads (ids `0..num_threads()`), running id 0
    /// on the caller. Returns after every thread finishes. Reentrant calls
    /// (from inside a worker, or while another `run` is active on another
    /// thread) execute `f(0)` serially.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.n_threads == 1 || IN_WORKER.with(|w| w.get()) {
            f(0);
            return;
        }
        // One outer `run` at a time; concurrent callers serialize here by
        // falling back to serial execution (correct, just not parallel).
        if self
            .running
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            f(0);
            return;
        }
        let epoch = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
        {
            let mut slot = self.shared.slot.lock().unwrap_or_else(|p| p.into_inner());
            // Plain unsizing coercion to a raw wide pointer — no unsafe
            // here; the lifetime erasure is accounted for where the
            // pointer is dereferenced (worker_loop).
            slot.ptr = Some(f as *const (dyn Fn(usize) + Sync));
            slot.epoch = epoch;
            self.shared.work_cv.notify_all();
        }
        // Caller participates as thread 0.
        f(0);
        // Wait for all spawned workers to finish this epoch.
        let mut done = self.shared.done.lock().unwrap_or_else(|p| p.into_inner());
        while *done < self.shared.n_spawned {
            done = self.shared.done_cv.wait(done).unwrap();
        }
        *done = 0;
        drop(done);
        // Invalidate the pointer before `f` can go out of scope.
        self.shared.slot.lock().unwrap_or_else(|p| p.into_inner()).ptr = None;
        self.running.store(false, Ordering::Release);
    }
}

fn worker_loop(shared: &'static Shared, worker_id: usize) {
    IN_WORKER.with(|w| w.set(true));
    let mut last_epoch = 0u64;
    loop {
        let parts = {
            let mut slot = shared.slot.lock().unwrap_or_else(|p| p.into_inner());
            while slot.epoch == last_epoch && !shared.shutdown.load(Ordering::Relaxed) {
                slot = shared.work_cv.wait(slot).unwrap();
            }
            if shared.shutdown.load(Ordering::Relaxed) {
                return;
            }
            last_epoch = slot.epoch;
            slot.ptr.expect("job pointer set with epoch")
        };
        // SAFETY: `parts` was published under the slot mutex together
        // with a fresh epoch, and `run` blocks until this worker bumps
        // the done count below — so the `&dyn Fn` behind the pointer is
        // live for the whole call. The closure is `Sync`, so calling it
        // concurrently from every worker is sound.
        let f = unsafe { &*parts };
        f(worker_id);
        let mut done = shared.done.lock().unwrap_or_else(|p| p.into_inner());
        *done += 1;
        shared.done_cv.notify_one();
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Number of threads requested via `CAGRA_THREADS`, else the machine's
/// available parallelism.
pub fn default_threads() -> usize {
    std::env::var("CAGRA_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// The lazily-created global pool.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_visits_every_thread_id() {
        let pool = global();
        let nt = pool.num_threads();
        let seen: Vec<AtomicUsize> = (0..nt).map(|_| AtomicUsize::new(0)).collect();
        pool.run(&|t| {
            seen[t].fetch_add(1, Ordering::Relaxed);
        });
        for (t, s) in seen.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), 1, "thread {t}");
        }
    }

    #[test]
    fn run_is_repeatable() {
        let pool = global();
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(&|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 50 * pool.num_threads());
    }

    #[test]
    fn borrows_local_state() {
        let pool = global();
        let data: Vec<AtomicUsize> = (0..pool.num_threads()).map(|_| AtomicUsize::new(7)).collect();
        pool.run(&|t| {
            data[t].fetch_add(t, Ordering::Relaxed);
        });
        for (t, d) in data.iter().enumerate() {
            assert_eq!(d.load(Ordering::Relaxed), 7 + t);
        }
    }
}
