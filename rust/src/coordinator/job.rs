//! Job pipeline: dataset → preprocess (reorder / segment) → execute →
//! metrics. This is the entry point the CLI and benches share, so every
//! experiment runs through identical plumbing.

use super::config::SystemConfig;
use super::metrics::Metrics;
use crate::apps::{bc, bfs, cf, pagerank};
use crate::cache;
use crate::graph::datasets::{self, Dataset};
use crate::store::{fingerprint, ArtifactStore, StoreCtx};
use crate::util::timer::time;
use anyhow::{bail, Result};

/// Which application to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    PageRank(pagerank::Variant),
    Cf(cf::Variant),
    Bc(bfs::Variant),
    Bfs(bfs::Variant),
}

impl AppKind {
    pub fn parse(app: &str, variant: &str) -> Result<AppKind> {
        let pr_variant = |v: &str| -> Result<pagerank::Variant> {
            Ok(match v {
                "baseline" => pagerank::Variant::Baseline,
                "reorder" | "reordering" => pagerank::Variant::Reordered,
                "segment" | "segmenting" => pagerank::Variant::Segmented,
                "both" | "optimized" => pagerank::Variant::ReorderedSegmented,
                "lower-bound" => pagerank::Variant::NoRandomLowerBound,
                _ => bail!("unknown pagerank variant {v:?}"),
            })
        };
        let fr_variant = |v: &str| -> Result<bfs::Variant> {
            Ok(match v {
                "baseline" => bfs::Variant::Baseline,
                "reorder" | "reordering" => bfs::Variant::Reordered,
                "bitvector" => bfs::Variant::Bitvector,
                "both" | "optimized" => bfs::Variant::ReorderedBitvector,
                _ => bail!("unknown frontier variant {v:?}"),
            })
        };
        Ok(match app {
            "pagerank" | "pr" => AppKind::PageRank(pr_variant(variant)?),
            "cf" => AppKind::Cf(match variant {
                "baseline" => cf::Variant::Baseline,
                "segment" | "segmenting" | "optimized" => cf::Variant::Segmented,
                _ => bail!("unknown cf variant {variant:?}"),
            }),
            "bc" => AppKind::Bc(fr_variant(variant)?),
            "bfs" => AppKind::Bfs(fr_variant(variant)?),
            _ => bail!("unknown app {app:?} (pagerank|cf|bc|bfs)"),
        })
    }
}

/// A full job description.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub dataset: String,
    pub app: AppKind,
    pub iters: usize,
    /// Sources for BC/BFS (count of high-degree starts).
    pub num_sources: usize,
    /// Attach simulated memory-system metrics (slower).
    pub analyze_memory: bool,
    pub scale: f64,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            dataset: "livejournal-sim".to_string(),
            app: AppKind::PageRank(pagerank::Variant::ReorderedSegmented),
            iters: 10,
            num_sources: 12,
            analyze_memory: false,
            scale: 1.0,
        }
    }
}

/// Result values + metrics.
#[derive(Debug)]
pub struct JobResult {
    pub metrics: Metrics,
    /// App-specific scalar summary (rank L1 mass / RMSE / reached count /
    /// max BC), used for smoke-checking runs.
    pub summary: f64,
}

/// Execute a job end-to-end.
pub fn run_job(spec: &JobSpec, cfg: &SystemConfig) -> Result<JobResult> {
    let mut metrics = Metrics::default();
    let (ds, load_s): (Dataset, f64) = {
        let (r, s) = time(|| datasets::load_scaled(&spec.dataset, spec.scale));
        (r?, s)
    };
    metrics.phases.add("load", load_s);
    metrics.edges = ds.graph.num_edges() as u64;
    let g = &ds.graph;
    // Persistent preprocessing-artifact store: cold runs build + persist,
    // warm runs read back. Open failures degrade to uncached operation —
    // the store must never take a job down. Only variants that actually
    // preprocess (reorder and/or segment) go through the store; skip the
    // open + fingerprint entirely otherwise so --store adds no overhead
    // (and no misleading 0-hit stats) to baselines and frontier apps.
    let app_uses_store = match spec.app {
        AppKind::PageRank(v) => !matches!(
            v,
            pagerank::Variant::Baseline | pagerank::Variant::NoRandomLowerBound
        ),
        AppKind::Cf(v) => v == cf::Variant::Segmented,
        AppKind::Bc(_) | AppKind::Bfs(_) => false,
    };
    let store = if cfg.store_enabled && app_uses_store {
        match ArtifactStore::open(&cfg.store_dir, cfg.store_cap_bytes) {
            Ok(s) => Some(s),
            Err(e) => {
                crate::log_warn!("artifact store disabled for this job: {e:#}");
                None
            }
        }
    } else {
        None
    };
    let ctx = match &store {
        Some(s) => {
            let (fp, fp_s) = time(|| fingerprint::fingerprint_dataset(&spec.dataset, spec.scale, g));
            metrics.phases.add("fingerprint", fp_s);
            Some(StoreCtx::new(s, fp))
        }
        None => None,
    };
    let summary = match spec.app {
        AppKind::PageRank(variant) => {
            let (mut prep, prep_s) = time(|| pagerank::Prepared::new_cached(g, cfg, variant, ctx));
            metrics.phases.add("preprocess", prep_s);
            prep.reset();
            for _ in 0..spec.iters {
                let (_, s) = time(|| prep.step());
                metrics.iter_seconds.push(s);
            }
            if spec.analyze_memory {
                metrics.stalls = Some(simulate_pagerank(g, cfg, variant));
            }
            // Rank L1 mass in original id space — a deterministic smoke
            // value (warm and cold runs must agree bitwise).
            prep.values().iter().sum::<f64>()
        }
        AppKind::Cf(variant) => {
            let (mut prep, prep_s) = time(|| cf::Prepared::new_cached(g, cfg, variant, ctx));
            metrics.phases.add("preprocess", prep_s);
            for _ in 0..spec.iters {
                let (_, s) = time(|| prep.step());
                metrics.iter_seconds.push(s);
            }
            prep.rmse()
        }
        AppKind::Bc(variant) => {
            let (prep, prep_s) = time(|| bc::Prepared::new(g, variant));
            metrics.phases.add("preprocess", prep_s);
            let sources = bc::default_sources(g, spec.num_sources);
            let (scores, s) = time(|| prep.run(&sources));
            metrics.iter_seconds.push(s);
            scores.iter().cloned().fold(0.0, f64::max)
        }
        AppKind::Bfs(variant) => {
            let (prep, prep_s) = time(|| bfs::Prepared::new(g, variant));
            metrics.phases.add("preprocess", prep_s);
            let sources = bc::default_sources(g, spec.num_sources);
            let mut reached = 0usize;
            for &s0 in &sources {
                let (parents, s) = time(|| prep.run(s0));
                metrics.iter_seconds.push(s);
                reached += parents.iter().filter(|&&p| p != u32::MAX).count();
            }
            reached as f64
        }
    };
    metrics.store = store.as_ref().map(|s| s.stats());
    Ok(JobResult { metrics, summary })
}

/// Simulated stall estimate for one PageRank iteration under `variant`.
pub fn simulate_pagerank(
    g: &crate::graph::Csr,
    cfg: &SystemConfig,
    variant: pagerank::Variant,
) -> cache::StallEstimate {
    use crate::reorder::{self, Ordering as VOrdering};
    let sample = (g.num_edges() / 2_000_000).max(1);
    match variant {
        pagerank::Variant::Baseline | pagerank::Variant::NoRandomLowerBound => {
            cache::stall::estimate_pull_iteration(&g.transpose(), 8, cfg.llc_bytes, sample)
        }
        pagerank::Variant::Reordered => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            cache::stall::estimate_pull_iteration(&h.transpose(), 8, cfg.llc_bytes, sample)
        }
        pagerank::Variant::Segmented => {
            let sg = crate::segment::SegmentedCsr::build(g, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
        pagerank::Variant::ReorderedSegmented => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            let sg = crate::segment::SegmentedCsr::build(&h, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_app_kinds() {
        assert!(matches!(
            AppKind::parse("pagerank", "both").unwrap(),
            AppKind::PageRank(pagerank::Variant::ReorderedSegmented)
        ));
        assert!(matches!(
            AppKind::parse("bfs", "bitvector").unwrap(),
            AppKind::Bfs(bfs::Variant::Bitvector)
        ));
        assert!(AppKind::parse("nope", "x").is_err());
        assert!(AppKind::parse("pagerank", "nope").is_err());
    }

    #[test]
    fn run_small_pagerank_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            iters: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert_eq!(r.metrics.iter_seconds.len(), 3);
        assert!(r.metrics.edges > 0);
    }

    #[test]
    fn run_small_bfs_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Bfs(bfs::Variant::ReorderedBitvector),
            num_sources: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert!(r.summary > 0.0); // reached something
    }
}
