//! Job pipeline: dataset → preprocess (reorder / segment) → execute →
//! metrics. This is the entry point the CLI and benches share, so every
//! experiment runs through identical plumbing.
//!
//! The pipeline is fully app-generic: the job's [`AppKind`] is resolved
//! through [`crate::apps::registry`] to a [`crate::apps::GraphApp`],
//! which performs all preprocessing (`prepare`, routed through the artifact store when
//! the app's variant has cacheable artifacts) and hands back a
//! [`crate::apps::PreparedApp`] that the one driver loop below executes
//! according to its [`ExecutionShape`]. Adding a workload means
//! registering it — `run_job` never names a concrete app.

use super::config::SystemConfig;
use super::metrics::Metrics;
use crate::apps::app::{default_sources, ExecutionShape};
use crate::apps::registry;
use crate::cache;
use crate::graph::datasets::{self, Dataset};
use crate::graph::VertexId;
use crate::store::{fingerprint, Artifact, ArtifactStore, MemStore, StoreCtx};
use crate::util::timer::time;
use anyhow::{bail, Result};
use std::sync::Arc;

pub use crate::apps::app::AppKind;

/// A full job description.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub dataset: String,
    pub app: AppKind,
    /// Iteration count for [`ExecutionShape::Iterative`] apps.
    pub iters: usize,
    /// Source count for [`ExecutionShape::PerSource`] apps (BC/BFS/SSSP;
    /// count of high-degree starts).
    pub num_sources: usize,
    /// Attach simulated memory-system metrics (slower).
    pub analyze_memory: bool,
    /// Read hardware PMU counters (perf_event_open) around each phase and
    /// execution unit. Runtime-probed: degrades to a warning where the
    /// syscall is blocked (containers, CI) or the `pmu` feature is off.
    pub collect_pmu: bool,
    pub scale: f64,
    /// Per-job override of [`SystemConfig::delta_epsilon`] (PageRank-Delta
    /// activeness threshold). `None` keeps the system-wide value — app
    /// knobs default to config but individual jobs in a batch can diverge.
    pub delta_epsilon: Option<f64>,
    /// Per-job override of [`SystemConfig::cf_k`] (CF latent dimension).
    /// Validated to 1..=64 before preprocessing (the segment-local CF
    /// kernel's stack buffer bound) so a bad request errors instead of
    /// panicking a worker.
    pub cf_k: Option<usize>,
    /// Per-job override of [`SystemConfig::damping`] (PageRank).
    pub damping: Option<f64>,
    /// Pin per-source apps (BC/BFS/SSSP) to this single **original-space**
    /// source vertex instead of the `num_sources` highest-degree defaults.
    pub bfs_source: Option<VertexId>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            dataset: "livejournal-sim".to_string(),
            app: AppKind::PageRank(crate::apps::pagerank::Variant::ReorderedSegmented),
            iters: 10,
            num_sources: 12,
            analyze_memory: false,
            collect_pmu: false,
            scale: 1.0,
            delta_epsilon: None,
            cf_k: None,
            damping: None,
            bfs_source: None,
        }
    }
}

/// Result values + metrics.
#[derive(Debug)]
pub struct JobResult {
    pub metrics: Metrics,
    /// App-specific scalar summary (rank L1 mass / RMSE / reached count /
    /// max BC / component count / triangle count), used for smoke-checking
    /// runs.
    pub summary: f64,
}

/// The shared long-lived resources a job runs against: a cross-job disk
/// store (`cagra batch`) and, in a resident process (`cagra serve`), the
/// in-memory artifact layer. Both optional — `Default` is a fully
/// private, cold job.
#[derive(Debug, Clone, Copy, Default)]
pub struct JobEnv<'a> {
    /// Shared artifact store; `None` opens a private one per job when the
    /// config enables stores at all.
    pub shared_store: Option<&'a ArtifactStore>,
    /// In-memory artifact layer: datasets and decoded artifacts are
    /// pinned behind `Arc` so warm jobs perform zero CSR decode.
    pub mem: Option<&'a MemStore>,
}

/// Execute a job end-to-end through the app registry, opening (and
/// closing) a private artifact store if the config enables one.
pub fn run_job(spec: &JobSpec, cfg: &SystemConfig) -> Result<JobResult> {
    run_job_env(spec, cfg, JobEnv::default())
}

/// [`run_job`] against an optional **shared** long-lived store (`cagra
/// batch`, embedders serving many jobs from one process).
pub fn run_job_with_store(
    spec: &JobSpec,
    cfg: &SystemConfig,
    shared: Option<&ArtifactStore>,
) -> Result<JobResult> {
    run_job_env(
        spec,
        cfg,
        JobEnv {
            shared_store: shared,
            ..JobEnv::default()
        },
    )
}

/// Memory-layer key for a pinned dataset (not a disk artifact, so it gets
/// its own namespace rather than a store filename).
pub fn dataset_mem_key(name: &str, scale: f64) -> String {
    format!("dataset:{name}-s{scale}")
}

/// [`run_job`] against shared long-lived resources ([`JobEnv`]). The
/// job's store writes are recorded under a per-job eviction-exemption
/// scope ([`ArtifactStore::begin_scope`]) that is released when the job
/// completes, so a store instance that outlives this job never
/// accumulates unbounded exemptions on its behalf.
pub fn run_job_env(spec: &JobSpec, cfg: &SystemConfig, env: JobEnv<'_>) -> Result<JobResult> {
    // JobSpec-level app-knob overrides shadow SystemConfig for this job
    // only (a batch or request stream can mix per-job values over one
    // system config). Bounds are checked here — a worker must reject a
    // bad request as an error, not die on an app-level assert.
    if let Some(k) = spec.cf_k {
        if k == 0 || k > 64 {
            bail!("cf_k must be in 1..=64 (segment-local kernel bound), got {k}");
        }
    }
    if let Some(d) = spec.damping {
        if !(0.0..=1.0).contains(&d) {
            bail!("damping must be in [0, 1], got {d}");
        }
    }
    let cfg_override;
    let cfg = if spec.delta_epsilon.is_some() || spec.cf_k.is_some() || spec.damping.is_some() {
        cfg_override = SystemConfig {
            delta_epsilon: spec.delta_epsilon.unwrap_or(cfg.delta_epsilon),
            cf_k: spec.cf_k.unwrap_or(cfg.cf_k),
            damping: spec.damping.unwrap_or(cfg.damping),
            ..cfg.clone()
        };
        &cfg_override
    } else {
        cfg
    };
    let mut metrics = Metrics::default();
    // Hardware counters are opt-in and probed once per job; every
    // measurement below degrades to recorder-only when the group is None.
    let mut pmu_group = if spec.collect_pmu {
        let group = crate::obs::pmu::PmuGroup::open();
        if group.is_none() {
            crate::log_warn!(
                "PMU counters unavailable (perf_event_open failed or unsupported \
                 platform/feature); continuing without hardware counters"
            );
        }
        group
    } else {
        None
    };
    let mut pmu = crate::obs::PmuMetrics::default();
    let t_load = crate::obs::recorder::timestamp();
    if let Some(pg) = &mut pmu_group {
        pg.start();
    }
    // Dataset resolution: with the in-memory layer, the decoded CSR is
    // pinned behind an Arc and shared across concurrent jobs — a warm
    // request performs zero disk reads and zero CSR decode here.
    let (ds, load_s): (Arc<Dataset>, f64) = {
        let (r, s) = time(|| match env.mem {
            Some(m) => m.try_get_or_insert_full(&dataset_mem_key(&spec.dataset, spec.scale), || {
                let d = datasets::load_scaled(&spec.dataset, spec.scale)?;
                let bytes = d.graph.mem_bytes() + d.name.len() as u64;
                let mapped = d.graph.mapped_bytes();
                Ok((d, bytes, mapped))
            }),
            None => datasets::load_scaled(&spec.dataset, spec.scale).map(Arc::new),
        });
        (r?, s)
    };
    if let Some(pg) = &mut pmu_group {
        pmu.phases.push(("load".to_string(), pg.stop_and_read()));
    }
    crate::obs::recorder::record_phase("load", t_load);
    metrics.phases.add("load", load_s);
    metrics.edges = ds.graph.num_edges() as u64;
    let g = &ds.graph;
    if let Some(src) = spec.bfs_source {
        if (src as usize) >= g.num_vertices() {
            bail!(
                "bfs_source {src} out of range (dataset has {} vertices)",
                g.num_vertices()
            );
        }
    }
    let app = registry::app_for(spec.app);
    metrics.app = Some(format!(
        "{}/{}",
        spec.app.app_name(),
        spec.app.variant_name()
    ));
    // Persistent preprocessing-artifact store: cold runs build + persist,
    // warm runs read back. Open failures degrade to uncached operation —
    // the store must never take a job down. Only variants whose app
    // declares cacheable preprocessing go through the store; skip the
    // open + fingerprint entirely otherwise so --store adds no overhead
    // (and no misleading 0-hit stats) to the rest.
    let mut opened: Option<ArtifactStore> = None;
    let store: Option<&ArtifactStore> = if cfg.store_enabled && app.uses_store(spec.app) {
        match env.shared_store {
            Some(s) => Some(s),
            None => match ArtifactStore::open(&cfg.store_dir, cfg.store_cap_bytes) {
                Ok(s) => Some(opened.insert(s)),
                Err(e) => {
                    crate::log_warn!("artifact store disabled for this job: {e:#}");
                    None
                }
            },
        }
    } else {
        None
    };
    let scope = store.map(|s| s.begin_scope());
    let ctx = match store {
        Some(s) => {
            s.set_mmap_enabled(cfg.store_mmap);
            let t_fp = crate::obs::recorder::timestamp();
            // The fingerprint is itself cached in the memory layer (it
            // samples the whole CSR, which is pure overhead on a warm
            // resident request).
            let fp_of = || fingerprint::fingerprint_dataset(&spec.dataset, spec.scale, g);
            let (fp, fp_s) = time(|| match env.mem {
                Some(m) => *m.get_or_insert(
                    &format!("fp:{}-s{}", spec.dataset, spec.scale),
                    || (fp_of(), 8),
                ),
                None => fp_of(),
            });
            crate::obs::recorder::record_phase("fingerprint", t_fp);
            metrics.phases.add("fingerprint", fp_s);
            let sid = scope.as_ref().expect("scope opened with store").id();
            let ctx = StoreCtx::scoped(s, fp, sid);
            match env.mem {
                Some(m) => ctx.with_mem(m),
                None => ctx,
            }
        }
        None => StoreCtx::disabled(),
    };
    let t_prep = crate::obs::recorder::timestamp();
    if let Some(pg) = &mut pmu_group {
        pg.start();
    }
    let (prep, prep_s) = time(|| app.prepare(g, cfg, spec.app, &ctx));
    let mut prep = prep?;
    if let Some(pg) = &mut pmu_group {
        pmu.phases.push(("preprocess".to_string(), pg.stop_and_read()));
    }
    crate::obs::recorder::record_phase("preprocess", t_prep);
    metrics.phases.add("preprocess", prep_s);
    match prep.shape() {
        ExecutionShape::Iterative => {
            for i in 0..spec.iters {
                let t0 = crate::obs::recorder::timestamp();
                if let Some(pg) = &mut pmu_group {
                    pg.start();
                }
                let (_, s) = time(|| prep.step());
                if let Some(pg) = &mut pmu_group {
                    pmu.iters.push(pg.stop_and_read());
                }
                crate::obs::recorder::record_iter(t0, i as u64, 0);
                metrics.iter_seconds.push(s);
            }
        }
        ExecutionShape::PerSource => {
            let sources = match spec.bfs_source {
                Some(src) => vec![src],
                None => default_sources(g, spec.num_sources),
            };
            for (i, &src) in sources.iter().enumerate() {
                let t0 = crate::obs::recorder::timestamp();
                if let Some(pg) = &mut pmu_group {
                    pg.start();
                }
                let (_, s) = time(|| prep.run_source(src));
                if let Some(pg) = &mut pmu_group {
                    pmu.iters.push(pg.stop_and_read());
                }
                crate::obs::recorder::record_iter(t0, i as u64, src as u64);
                metrics.iter_seconds.push(s);
            }
        }
        // One-shot apps did their work in prepare; summary() is already
        // final and there is nothing meaningful to time per iteration.
        ExecutionShape::OneShot => {}
    }
    if spec.analyze_memory {
        let t_sim = crate::obs::recorder::timestamp();
        let (est, sim_s) = time(|| app.simulate(g, cfg, spec.app));
        crate::obs::recorder::record_phase("simulate", t_sim);
        metrics.phases.add("simulate", sim_s);
        metrics.stalls = est;
    }
    if pmu_group.is_some() {
        metrics.pmu = Some(pmu);
    }
    // Reusable-scratch footprint (peak): the memory the app holds so its
    // steady state allocates nothing. Read after execution so engine
    // pools have reached their high-water mark.
    let scratch = prep.scratch_bytes();
    metrics.scratch_bytes = (scratch > 0).then_some(scratch as u64);
    let summary = prep.summary();
    metrics.store = store.map(|s| s.stats());
    metrics.mem = env.mem.map(|m| m.stats());
    metrics.faults = crate::fault::snapshot()
        .into_iter()
        .map(|(site, n)| (site.to_string(), n))
        .collect();
    // Job complete: release this job's eviction exemptions (for a shared
    // store, its artifacts become ordinary LRU candidates from here on).
    drop(scope);
    Ok(JobResult { metrics, summary })
}

/// Simulated stall estimate for one PageRank iteration under `variant`
/// (exposed for the figure benches and `cagra simulate`; the pipeline
/// reaches it through [`crate::apps::GraphApp::simulate`]).
pub fn simulate_pagerank(
    g: &crate::graph::Csr,
    cfg: &SystemConfig,
    variant: crate::apps::pagerank::Variant,
) -> cache::StallEstimate {
    use crate::apps::pagerank::Variant;
    use crate::reorder::{self, Ordering as VOrdering};
    let sample = (g.num_edges() / 2_000_000).max(1);
    match variant {
        Variant::Baseline | Variant::NoRandomLowerBound => {
            cache::stall::estimate_pull_iteration(&g.transpose(), 8, cfg.llc_bytes, sample)
        }
        Variant::Reordered => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            cache::stall::estimate_pull_iteration(&h.transpose(), 8, cfg.llc_bytes, sample)
        }
        Variant::Segmented => {
            let sg = crate::segment::SegmentedCsr::build(g, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
        Variant::ReorderedSegmented => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            let sg = crate::segment::SegmentedCsr::build(&h, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{bfs, cc, pagerank, sssp, triangle};

    #[test]
    fn parse_app_kinds() {
        assert!(matches!(
            AppKind::parse("pagerank", "both").unwrap(),
            AppKind::PageRank(pagerank::Variant::ReorderedSegmented)
        ));
        assert!(matches!(
            AppKind::parse("bfs", "bitvector").unwrap(),
            AppKind::Bfs(bfs::Variant::Bitvector)
        ));
        assert!(matches!(
            AppKind::parse("bc", "both").unwrap(),
            AppKind::Bc(crate::apps::bc::Variant::ReorderedBitvector)
        ));
        assert!(matches!(
            AppKind::parse("sssp", "reordering").unwrap(),
            AppKind::Sssp(sssp::Variant::Reordered)
        ));
        assert!(matches!(
            AppKind::parse("cc", "segmenting").unwrap(),
            AppKind::Cc(cc::Variant::Segmented)
        ));
        assert!(matches!(
            AppKind::parse("tc", "degree-ordered").unwrap(),
            AppKind::Triangle(triangle::Variant::DegreeOrdered)
        ));
        assert!(AppKind::parse("nope", "x").is_err());
        assert!(AppKind::parse("pagerank", "nope").is_err());
    }

    #[test]
    fn run_small_pagerank_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            iters: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert_eq!(r.metrics.iter_seconds.len(), 3);
        assert!(r.metrics.edges > 0);
        assert_eq!(r.metrics.app.as_deref(), Some("pagerank/reordering+segmenting"));
    }

    #[test]
    fn run_small_bfs_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Bfs(bfs::Variant::ReorderedBitvector),
            num_sources: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert!(r.summary > 0.0); // reached something
        // Per-source shape: one timing entry per source.
        assert_eq!(r.metrics.iter_seconds.len(), 3);
    }

    #[test]
    fn knob_overrides_validated_and_applied() {
        let cfg = SystemConfig::default();
        // Out-of-range knobs must error before any preprocessing runs.
        let bad_k = JobSpec {
            scale: 1.0 / 64.0,
            cf_k: Some(65),
            ..Default::default()
        };
        assert!(run_job(&bad_k, &cfg).is_err());
        let bad_d = JobSpec {
            scale: 1.0 / 64.0,
            damping: Some(1.5),
            ..Default::default()
        };
        assert!(run_job(&bad_d, &cfg).is_err());
        let bad_src = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Bfs(bfs::Variant::Baseline),
            bfs_source: Some(u32::MAX - 1),
            ..Default::default()
        };
        assert!(run_job(&bad_src, &cfg).is_err());
        // A damping override must change the PageRank fixpoint.
        let base = JobSpec {
            scale: 1.0 / 64.0,
            iters: 3,
            ..Default::default()
        };
        let tweaked = JobSpec {
            damping: Some(0.5),
            ..base.clone()
        };
        let a = run_job(&base, &cfg).unwrap().summary;
        let b = run_job(&tweaked, &cfg).unwrap().summary;
        assert!((a - b).abs() > 1e-9, "damping override had no effect");
    }

    #[test]
    fn pinned_source_runs_exactly_once() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Bfs(bfs::Variant::Baseline),
            num_sources: 5,
            bfs_source: Some(0),
            ..Default::default()
        };
        let r = run_job(&spec, &SystemConfig::default()).unwrap();
        assert_eq!(r.metrics.iter_seconds.len(), 1, "pinned source overrides num_sources");
    }

    #[test]
    fn run_small_cc_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Cc(cc::Variant::Segmented),
            iters: 4,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert!(r.summary >= 1.0, "component count {}", r.summary);
    }
}
