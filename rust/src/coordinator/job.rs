//! Job pipeline: dataset → preprocess (reorder / segment) → execute →
//! metrics. This is the entry point the CLI and benches share, so every
//! experiment runs through identical plumbing.
//!
//! The pipeline is fully app-generic: the job's [`AppKind`] is resolved
//! through [`crate::apps::registry`] to a [`crate::apps::GraphApp`],
//! which performs all preprocessing (`prepare`, routed through the artifact store when
//! the app's variant has cacheable artifacts) and hands back a
//! [`crate::apps::PreparedApp`] that the one driver loop below executes
//! according to its [`ExecutionShape`]. Adding a workload means
//! registering it — `run_job` never names a concrete app.

use super::config::SystemConfig;
use super::metrics::Metrics;
use crate::apps::app::{default_sources, ExecutionShape};
use crate::apps::registry;
use crate::cache;
use crate::graph::datasets::{self, Dataset};
use crate::store::{fingerprint, ArtifactStore, StoreCtx};
use crate::util::timer::time;
use anyhow::Result;

pub use crate::apps::app::AppKind;

/// A full job description.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub dataset: String,
    pub app: AppKind,
    /// Iteration count for [`ExecutionShape::Iterative`] apps.
    pub iters: usize,
    /// Source count for [`ExecutionShape::PerSource`] apps (BC/BFS/SSSP;
    /// count of high-degree starts).
    pub num_sources: usize,
    /// Attach simulated memory-system metrics (slower).
    pub analyze_memory: bool,
    /// Read hardware PMU counters (perf_event_open) around each phase and
    /// execution unit. Runtime-probed: degrades to a warning where the
    /// syscall is blocked (containers, CI) or the `pmu` feature is off.
    pub collect_pmu: bool,
    pub scale: f64,
    /// Per-job override of [`SystemConfig::delta_epsilon`] (PageRank-Delta
    /// activeness threshold). `None` keeps the system-wide value — app
    /// knobs default to config but individual jobs in a batch can diverge.
    pub delta_epsilon: Option<f64>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            dataset: "livejournal-sim".to_string(),
            app: AppKind::PageRank(crate::apps::pagerank::Variant::ReorderedSegmented),
            iters: 10,
            num_sources: 12,
            analyze_memory: false,
            collect_pmu: false,
            scale: 1.0,
            delta_epsilon: None,
        }
    }
}

/// Result values + metrics.
#[derive(Debug)]
pub struct JobResult {
    pub metrics: Metrics,
    /// App-specific scalar summary (rank L1 mass / RMSE / reached count /
    /// max BC / component count / triangle count), used for smoke-checking
    /// runs.
    pub summary: f64,
}

/// Execute a job end-to-end through the app registry, opening (and
/// closing) a private artifact store if the config enables one.
pub fn run_job(spec: &JobSpec, cfg: &SystemConfig) -> Result<JobResult> {
    run_job_with_store(spec, cfg, None)
}

/// [`run_job`] against an optional **shared** long-lived store (`cagra
/// batch`, embedders serving many jobs from one process). The job's
/// store writes are recorded under a per-job eviction-exemption scope
/// ([`ArtifactStore::begin_scope`]) that is released when the job
/// completes, so a store instance that outlives this job never
/// accumulates unbounded exemptions on its behalf.
pub fn run_job_with_store(
    spec: &JobSpec,
    cfg: &SystemConfig,
    shared: Option<&ArtifactStore>,
) -> Result<JobResult> {
    // JobSpec-level app-knob overrides shadow SystemConfig for this job
    // only (a batch can mix per-job values over one system config).
    let cfg_override;
    let cfg = match spec.delta_epsilon {
        Some(e) => {
            cfg_override = SystemConfig {
                delta_epsilon: e,
                ..cfg.clone()
            };
            &cfg_override
        }
        None => cfg,
    };
    let mut metrics = Metrics::default();
    // Hardware counters are opt-in and probed once per job; every
    // measurement below degrades to recorder-only when the group is None.
    let mut pmu_group = if spec.collect_pmu {
        let group = crate::obs::pmu::PmuGroup::open();
        if group.is_none() {
            crate::log_warn!(
                "PMU counters unavailable (perf_event_open failed or unsupported \
                 platform/feature); continuing without hardware counters"
            );
        }
        group
    } else {
        None
    };
    let mut pmu = crate::obs::PmuMetrics::default();
    let t_load = crate::obs::recorder::timestamp();
    if let Some(pg) = &mut pmu_group {
        pg.start();
    }
    let (ds, load_s): (Dataset, f64) = {
        let (r, s) = time(|| datasets::load_scaled(&spec.dataset, spec.scale));
        (r?, s)
    };
    if let Some(pg) = &mut pmu_group {
        pmu.phases.push(("load".to_string(), pg.stop_and_read()));
    }
    crate::obs::recorder::record_phase("load", t_load);
    metrics.phases.add("load", load_s);
    metrics.edges = ds.graph.num_edges() as u64;
    let g = &ds.graph;
    let app = registry::app_for(spec.app);
    metrics.app = Some(format!(
        "{}/{}",
        spec.app.app_name(),
        spec.app.variant_name()
    ));
    // Persistent preprocessing-artifact store: cold runs build + persist,
    // warm runs read back. Open failures degrade to uncached operation —
    // the store must never take a job down. Only variants whose app
    // declares cacheable preprocessing go through the store; skip the
    // open + fingerprint entirely otherwise so --store adds no overhead
    // (and no misleading 0-hit stats) to the rest.
    let mut opened: Option<ArtifactStore> = None;
    let store: Option<&ArtifactStore> = if cfg.store_enabled && app.uses_store(spec.app) {
        match shared {
            Some(s) => Some(s),
            None => match ArtifactStore::open(&cfg.store_dir, cfg.store_cap_bytes) {
                Ok(s) => Some(opened.insert(s)),
                Err(e) => {
                    crate::log_warn!("artifact store disabled for this job: {e:#}");
                    None
                }
            },
        }
    } else {
        None
    };
    let scope = store.map(|s| s.begin_scope());
    let ctx = match store {
        Some(s) => {
            let t_fp = crate::obs::recorder::timestamp();
            let (fp, fp_s) = time(|| fingerprint::fingerprint_dataset(&spec.dataset, spec.scale, g));
            crate::obs::recorder::record_phase("fingerprint", t_fp);
            metrics.phases.add("fingerprint", fp_s);
            let sid = scope.as_ref().expect("scope opened with store").id();
            Some(StoreCtx::scoped(s, fp, sid))
        }
        None => None,
    };
    let t_prep = crate::obs::recorder::timestamp();
    if let Some(pg) = &mut pmu_group {
        pg.start();
    }
    let (prep, prep_s) = time(|| app.prepare(g, cfg, spec.app, ctx));
    let mut prep = prep?;
    if let Some(pg) = &mut pmu_group {
        pmu.phases.push(("preprocess".to_string(), pg.stop_and_read()));
    }
    crate::obs::recorder::record_phase("preprocess", t_prep);
    metrics.phases.add("preprocess", prep_s);
    match prep.shape() {
        ExecutionShape::Iterative => {
            for i in 0..spec.iters {
                let t0 = crate::obs::recorder::timestamp();
                if let Some(pg) = &mut pmu_group {
                    pg.start();
                }
                let (_, s) = time(|| prep.step());
                if let Some(pg) = &mut pmu_group {
                    pmu.iters.push(pg.stop_and_read());
                }
                crate::obs::recorder::record_iter(t0, i as u64, 0);
                metrics.iter_seconds.push(s);
            }
        }
        ExecutionShape::PerSource => {
            for (i, &src) in default_sources(g, spec.num_sources).iter().enumerate() {
                let t0 = crate::obs::recorder::timestamp();
                if let Some(pg) = &mut pmu_group {
                    pg.start();
                }
                let (_, s) = time(|| prep.run_source(src));
                if let Some(pg) = &mut pmu_group {
                    pmu.iters.push(pg.stop_and_read());
                }
                crate::obs::recorder::record_iter(t0, i as u64, src as u64);
                metrics.iter_seconds.push(s);
            }
        }
        // One-shot apps did their work in prepare; summary() is already
        // final and there is nothing meaningful to time per iteration.
        ExecutionShape::OneShot => {}
    }
    if spec.analyze_memory {
        let t_sim = crate::obs::recorder::timestamp();
        let (est, sim_s) = time(|| app.simulate(g, cfg, spec.app));
        crate::obs::recorder::record_phase("simulate", t_sim);
        metrics.phases.add("simulate", sim_s);
        metrics.stalls = est;
    }
    if pmu_group.is_some() {
        metrics.pmu = Some(pmu);
    }
    // Reusable-scratch footprint (peak): the memory the app holds so its
    // steady state allocates nothing. Read after execution so engine
    // pools have reached their high-water mark.
    let scratch = prep.scratch_bytes();
    metrics.scratch_bytes = (scratch > 0).then_some(scratch as u64);
    let summary = prep.summary();
    metrics.store = store.map(|s| s.stats());
    // Job complete: release this job's eviction exemptions (for a shared
    // store, its artifacts become ordinary LRU candidates from here on).
    drop(scope);
    Ok(JobResult { metrics, summary })
}

/// Simulated stall estimate for one PageRank iteration under `variant`
/// (exposed for the figure benches and `cagra simulate`; the pipeline
/// reaches it through [`crate::apps::GraphApp::simulate`]).
pub fn simulate_pagerank(
    g: &crate::graph::Csr,
    cfg: &SystemConfig,
    variant: crate::apps::pagerank::Variant,
) -> cache::StallEstimate {
    use crate::apps::pagerank::Variant;
    use crate::reorder::{self, Ordering as VOrdering};
    let sample = (g.num_edges() / 2_000_000).max(1);
    match variant {
        Variant::Baseline | Variant::NoRandomLowerBound => {
            cache::stall::estimate_pull_iteration(&g.transpose(), 8, cfg.llc_bytes, sample)
        }
        Variant::Reordered => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            cache::stall::estimate_pull_iteration(&h.transpose(), 8, cfg.llc_bytes, sample)
        }
        Variant::Segmented => {
            let sg = crate::segment::SegmentedCsr::build(g, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
        Variant::ReorderedSegmented => {
            let (h, _) = reorder::reorder(g, VOrdering::CoarseDegreeSort);
            let sg = crate::segment::SegmentedCsr::build(&h, cfg.segment_size(8));
            cache::stall::estimate_segmented_iteration(&sg, 8, cfg.llc_bytes, sample)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{bfs, cc, pagerank, sssp, triangle};

    #[test]
    fn parse_app_kinds() {
        assert!(matches!(
            AppKind::parse("pagerank", "both").unwrap(),
            AppKind::PageRank(pagerank::Variant::ReorderedSegmented)
        ));
        assert!(matches!(
            AppKind::parse("bfs", "bitvector").unwrap(),
            AppKind::Bfs(bfs::Variant::Bitvector)
        ));
        assert!(matches!(
            AppKind::parse("bc", "both").unwrap(),
            AppKind::Bc(crate::apps::bc::Variant::ReorderedBitvector)
        ));
        assert!(matches!(
            AppKind::parse("sssp", "reordering").unwrap(),
            AppKind::Sssp(sssp::Variant::Reordered)
        ));
        assert!(matches!(
            AppKind::parse("cc", "segmenting").unwrap(),
            AppKind::Cc(cc::Variant::Segmented)
        ));
        assert!(matches!(
            AppKind::parse("tc", "degree-ordered").unwrap(),
            AppKind::Triangle(triangle::Variant::DegreeOrdered)
        ));
        assert!(AppKind::parse("nope", "x").is_err());
        assert!(AppKind::parse("pagerank", "nope").is_err());
    }

    #[test]
    fn run_small_pagerank_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            iters: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert_eq!(r.metrics.iter_seconds.len(), 3);
        assert!(r.metrics.edges > 0);
        assert_eq!(r.metrics.app.as_deref(), Some("pagerank/reordering+segmenting"));
    }

    #[test]
    fn run_small_bfs_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Bfs(bfs::Variant::ReorderedBitvector),
            num_sources: 3,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert!(r.summary > 0.0); // reached something
        // Per-source shape: one timing entry per source.
        assert_eq!(r.metrics.iter_seconds.len(), 3);
    }

    #[test]
    fn run_small_cc_job() {
        let spec = JobSpec {
            dataset: "livejournal-sim".into(),
            scale: 1.0 / 64.0,
            app: AppKind::Cc(cc::Variant::Segmented),
            iters: 4,
            ..Default::default()
        };
        let cfg = SystemConfig::default();
        let r = run_job(&spec, &cfg).unwrap();
        assert!(r.summary >= 1.0, "component count {}", r.summary);
    }
}
