//! System configuration: the machine model every technique keys off.
//!
//! The paper sizes segments to the last-level cache ("sizing the segments
//! to fit in last level (L3) cache provided the best tradeoff", §4.5) and
//! merge blocks to L1. Our datasets are ~1/100 of the paper's, so the
//! *effective* LLC defaults to 2 MiB — this host's L2, the level below
//! its 105 MB shared L3 — keeping the working-set : cache ratios in the
//! paper's regime (DESIGN.md §3/§4; measured random-gather cliff: ~1 ns
//! L2-resident vs 5–15 ns beyond).

use crate::util::config::Config;

/// Machine + technique parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// Effective last-level cache for segment sizing (bytes).
    pub llc_bytes: usize,
    /// Effective L1d for merge-block sizing (bytes).
    pub l1_bytes: usize,
    /// Fraction of LLC given to a segment's source data (the rest holds
    /// edge stream + output block).
    pub segment_fill: f64,
    /// PageRank damping factor.
    pub damping: f64,
    /// Coarsening threshold for the §3.3 stable degree sort.
    pub coarsen: u32,
    /// CF latent dimensionality (GraphMat uses small K; we use 8).
    pub cf_k: usize,
    /// CF gradient-descent step.
    pub cf_lr: f64,
    /// PageRank-Delta activeness threshold: a vertex stays in the
    /// frontier while its relative rank change exceeds this.
    pub delta_epsilon: f64,
    /// Seed for [`crate::reorder::Ordering::Random`] permutations.
    /// Defaults to the historical constant so sweeps stay reproducible.
    pub random_seed: u64,
    /// Persist preprocessing artifacts (permutations, relabeled CSRs,
    /// segmented partitions) across runs.
    pub store_enabled: bool,
    /// Artifact store directory.
    pub store_dir: String,
    /// Artifact store size cap in bytes (0 = unlimited); oldest artifacts
    /// are evicted first. Must comfortably exceed one job's artifact set
    /// (permutation + relabeled CSR + segmented partition ≈ 2–3x the CSR
    /// size) or the store evicts its own freshly-written files and warm
    /// runs keep rebuilding.
    pub store_cap_bytes: u64,
    /// Serve warm artifact loads by mmap-ing codec-v2 files in place
    /// (zero decode/copy) instead of reading + decoding them. Falls back
    /// to decoding automatically when mapping is unsupported or fails;
    /// `--no-mmap` / `store_mmap = false` forces the decode path (used by
    /// CI to compare the two).
    pub store_mmap: bool,
    /// Failpoint spec (see [`crate::fault`] for the grammar); empty
    /// disarms. The `CAGRA_FAILPOINTS` environment variable overrides
    /// this at arming time.
    pub failpoints: String,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            llc_bytes: 2 * 1024 * 1024,
            l1_bytes: 32 * 1024,
            segment_fill: 0.5,
            damping: 0.85,
            coarsen: 10,
            cf_k: 8,
            cf_lr: 1e-3,
            delta_epsilon: 1e-4,
            random_seed: crate::reorder::DEFAULT_RANDOM_SEED,
            store_enabled: false,
            store_dir: "target/artifact-store".to_string(),
            store_cap_bytes: 8 * 1024 * 1024 * 1024,
            store_mmap: true,
            failpoints: String::new(),
        }
    }
}

impl SystemConfig {
    /// Load overrides from a parsed config file (section `[system]`).
    pub fn from_config(cfg: &Config) -> anyhow::Result<SystemConfig> {
        let d = SystemConfig::default();
        Ok(SystemConfig {
            llc_bytes: cfg.get_usize("system.llc_bytes", d.llc_bytes)?,
            l1_bytes: cfg.get_usize("system.l1_bytes", d.l1_bytes)?,
            segment_fill: cfg.get_f64("system.segment_fill", d.segment_fill)?,
            damping: cfg.get_f64("system.damping", d.damping)?,
            coarsen: cfg.get_usize("system.coarsen", d.coarsen as usize)? as u32,
            cf_k: cfg.get_usize("system.cf_k", d.cf_k)?,
            cf_lr: cfg.get_f64("system.cf_lr", d.cf_lr)?,
            delta_epsilon: cfg.get_f64("system.delta_epsilon", d.delta_epsilon)?,
            random_seed: cfg.get_u64("system.random_seed", d.random_seed)?,
            store_enabled: cfg.get_bool("system.store_enabled", d.store_enabled)?,
            store_dir: cfg.get_str("system.store_dir", &d.store_dir).to_string(),
            store_cap_bytes: cfg.get_u64("system.store_cap_bytes", d.store_cap_bytes)?,
            store_mmap: cfg.get_bool("system.store_mmap", d.store_mmap)?,
            failpoints: cfg.get_str("system.failpoints", &d.failpoints).to_string(),
        })
    }

    /// Segment size in **vertices** for per-vertex payload `elem_bytes`
    /// (§4.5: segment source data fits the LLC share).
    pub fn segment_size(&self, elem_bytes: usize) -> usize {
        (((self.llc_bytes as f64 * self.segment_fill) as usize) / elem_bytes.max(1)).max(1)
    }

    /// Merge block size in vertices (block of f64 output fits L1).
    pub fn merge_block(&self, elem_bytes: usize) -> usize {
        (self.l1_bytes / elem_bytes.max(1)).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = SystemConfig::default();
        assert_eq!(c.segment_size(8), 128 * 1024);
        assert_eq!(c.merge_block(8), 4096);
        // CF payload is K doubles: segments shrink accordingly.
        assert_eq!(c.segment_size(8 * c.cf_k), 16 * 1024);
    }

    #[test]
    fn from_config_overrides() {
        let cfg = Config::parse("[system]\nllc_bytes = 1048576\ndamping = 0.9\n").unwrap();
        let c = SystemConfig::from_config(&cfg).unwrap();
        assert_eq!(c.llc_bytes, 1 << 20);
        assert_eq!(c.damping, 0.9);
        assert_eq!(c.l1_bytes, SystemConfig::default().l1_bytes);
    }

    #[test]
    fn store_and_seed_overrides() {
        let d = SystemConfig::default();
        assert!(!d.store_enabled);
        assert_eq!(d.random_seed, crate::reorder::DEFAULT_RANDOM_SEED);
        let cfg = Config::parse(
            "[system]\nstore_enabled = true\nstore_dir = /tmp/arts\n\
             store_cap_bytes = 1024\nrandom_seed = 99\n",
        )
        .unwrap();
        let c = SystemConfig::from_config(&cfg).unwrap();
        assert!(c.store_enabled);
        assert_eq!(c.store_dir, "/tmp/arts");
        assert_eq!(c.store_cap_bytes, 1024);
        assert_eq!(c.random_seed, 99);
        assert!(c.store_mmap, "mmap defaults on");
        let cfg = Config::parse("[system]\nstore_mmap = false\n").unwrap();
        assert!(!SystemConfig::from_config(&cfg).unwrap().store_mmap);
    }
}
