//! L3 coordinator: system configuration, the preprocessing→execute→metrics
//! pipeline, and report formatting. The CLI (`main.rs`) and the benches
//! drive everything through this module; the pipeline itself resolves
//! workloads through [`crate::apps::registry`], so it stays app-agnostic.

pub mod batch;
pub mod config;
pub mod job;
pub mod metrics;

pub use batch::{parse_batch, run_batch, run_batch_with};
pub use config::SystemConfig;
pub use job::{
    dataset_mem_key, run_job, run_job_env, run_job_with_store, AppKind, JobEnv, JobResult, JobSpec,
};
