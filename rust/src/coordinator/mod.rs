//! L3 coordinator: system configuration, the preprocessing→execute→metrics
//! pipeline, and report formatting. The CLI (`main.rs`) and the benches
//! drive everything through this module.

pub mod config;
pub mod job;
pub mod metrics;

pub use config::SystemConfig;
pub use job::{run_job, AppKind, JobResult, JobSpec};
