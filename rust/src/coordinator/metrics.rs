//! Run metrics: timing phases plus memory-system statistics — the
//! simulated stall estimate and, when requested and reachable, the real
//! PMU counters it is validated against (DESIGN.md §3).

use crate::cache::{StallEstimate};
use crate::obs::PmuMetrics;
use crate::store::{MemStats, StoreStats};
use crate::util::timer::PhaseTimer;

/// Everything a job run reports.
#[derive(Debug, Default)]
pub struct Metrics {
    /// "app/variant" the job ran (from the registry), e.g. "bfs/both".
    pub app: Option<String>,
    pub phases: PhaseTimer,
    /// Wall time per execution unit (seconds): one entry per iteration
    /// for iterative apps, one per source for per-source apps.
    pub iter_seconds: Vec<f64>,
    /// Simulated stall estimate for one representative iteration, if the
    /// job asked for memory-system analysis.
    pub stalls: Option<StallEstimate>,
    /// Hardware PMU counters (perf_event_open), when the job asked for
    /// them and the platform exposes them. Complements `stalls`: the
    /// measured side of the sim-vs-hardware validation (DESIGN.md §3).
    pub pmu: Option<PmuMetrics>,
    /// Edges processed per iteration.
    pub edges: u64,
    /// Artifact-store snapshot, when the job ran with the store enabled.
    /// Counters are per store *instance*: under `cagra batch` (one shared
    /// store) they accumulate across jobs, so a job's own traffic is the
    /// delta from the previous job's snapshot.
    pub store: Option<StoreStats>,
    /// In-memory artifact-layer snapshot (`cagra serve`). Like `store`,
    /// counters are per layer instance and accumulate across the jobs
    /// that share it.
    pub mem: Option<MemStats>,
    /// Peak bytes of reusable execution scratch the prepared app held
    /// (engine scratch pools, per-source atomic arrays, per-segment
    /// buffers) — the memory cost of the zero-allocation steady state.
    /// `None` when the app has no reusable scratch.
    pub scratch_bytes: Option<u64>,
    /// Failpoint trigger counts (`site name`, fires) for sites that fired
    /// at least once during the process so far ([`crate::fault`]). Empty
    /// in normal operation; nonzero entries mean the run executed under
    /// injected faults and its numbers should be read accordingly.
    pub faults: Vec<(String, u64)>,
}

impl Metrics {
    pub fn median_iter_seconds(&self) -> f64 {
        if self.iter_seconds.is_empty() {
            return 0.0;
        }
        let mut s = self.iter_seconds.clone();
        s.sort_by(f64::total_cmp);
        s[s.len() / 2]
    }

    pub fn edges_per_second(&self) -> f64 {
        let t = self.median_iter_seconds();
        if t <= 0.0 {
            0.0
        } else {
            self.edges as f64 / t
        }
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(app) = &self.app {
            out.push_str(&format!("app: {app}\n"));
        }
        out.push_str(&format!(
            "iterations: {}  median: {:.6}s  throughput: {:.2} MEdge/s\n",
            self.iter_seconds.len(),
            self.median_iter_seconds(),
            self.edges_per_second() / 1e6
        ));
        if let Some(s) = &self.stalls {
            out.push_str(&format!(
                "simulated: {:.1} stall-cycles/access, LLC miss rate {:.1}%\n",
                s.stalls_per_access(),
                s.llc_miss_rate * 100.0
            ));
        }
        if let Some(p) = &self.pmu {
            let t = p.total();
            match t.llc_miss_rate() {
                Some(rate) => out.push_str(&format!(
                    "pmu: {} cycles, {} instructions, LLC miss rate {:.1}% ({} refs)\n",
                    t.cycles,
                    t.instructions,
                    rate * 100.0,
                    t.cache_references
                )),
                None => out.push_str(&format!(
                    "pmu: {} cycles, {} instructions (LLC counters unavailable)\n",
                    t.cycles, t.instructions
                )),
            }
        }
        if let Some(s) = &self.store {
            out.push_str(&format!(
                "artifact store: {} hits, {} misses, {} evictions; {} entries ({}); \
                 {} decoded, {} mapped\n",
                s.hits,
                s.misses,
                s.evictions,
                s.entries,
                crate::util::fmt_bytes(s.resident_bytes as usize),
                crate::util::fmt_bytes(s.bytes_read as usize),
                crate::util::fmt_bytes(s.bytes_mapped as usize)
            ));
        }
        if let Some(m) = &self.mem {
            out.push_str(&format!(
                "resident mem: {} hits, {} misses, {} evictions; {} entries \
                 ({} of {} budget, {} mapped)\n",
                m.hits,
                m.misses,
                m.evictions,
                m.entries,
                crate::util::fmt_bytes(m.resident_bytes as usize),
                crate::util::fmt_bytes(m.budget_bytes as usize),
                crate::util::fmt_bytes(m.mapped_bytes as usize)
            ));
        }
        if let Some(b) = self.scratch_bytes {
            out.push_str(&format!(
                "engine scratch: {} reusable (peak; buys the zero-allocation steady state)\n",
                crate::util::fmt_bytes(b as usize)
            ));
        }
        if !self.faults.is_empty() {
            let list: Vec<String> = self
                .faults
                .iter()
                .map(|(site, n)| format!("{site}:{n}"))
                .collect();
            out.push_str(&format!("injected faults: {}\n", list.join(" ")));
        }
        for (name, secs, share) in self.phases.report() {
            out.push_str(&format!("  {name:<24} {secs:>9.4}s  {:>5.1}%\n", share * 100.0));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_rate() {
        let m = Metrics {
            iter_seconds: vec![0.2, 0.1, 0.3],
            edges: 1_000_000,
            ..Default::default()
        };
        assert_eq!(m.median_iter_seconds(), 0.2);
        assert!((m.edges_per_second() - 5e6).abs() < 1e-6);
    }

    #[test]
    fn render_includes_phases() {
        let mut m = Metrics::default();
        m.phases.add("preprocess", 0.5);
        m.iter_seconds.push(0.1);
        m.edges = 10;
        let r = m.render();
        assert!(r.contains("preprocess"));
        assert!(!r.contains("artifact store"));
        assert!(!r.contains("resident mem"));
        assert!(!r.contains("app:"));
        assert!(!r.contains("engine scratch"));
        m.app = Some("bfs/both".to_string());
        assert!(m.render().contains("app: bfs/both"));
        m.store = Some(crate::store::StoreStats {
            hits: 3,
            misses: 1,
            bytes_mapped: 4096,
            ..Default::default()
        });
        assert!(m.render().contains("3 hits, 1 misses"));
        assert!(m.render().contains("4.0 KiB mapped"));
        m.mem = Some(crate::store::MemStats {
            hits: 2,
            misses: 1,
            entries: 1,
            resident_bytes: 1024,
            budget_bytes: 2048,
            ..Default::default()
        });
        assert!(m.render().contains("resident mem: 2 hits, 1 misses"));
        m.scratch_bytes = Some(2 * 1024 * 1024);
        assert!(m.render().contains("engine scratch: 2.0 MiB"));
        assert!(!m.render().contains("injected faults"));
        m.faults = vec![("worker.job".to_string(), 3)];
        assert!(m.render().contains("injected faults: worker.job:3"));
        m.pmu = Some(crate::obs::PmuMetrics {
            phases: vec![(
                "load".to_string(),
                crate::obs::PmuCounters {
                    cycles: 100,
                    instructions: 200,
                    cache_references: 50,
                    cache_misses: 10,
                },
            )],
            iters: Vec::new(),
        });
        assert!(m.render().contains("pmu: 100 cycles, 200 instructions"));
        assert!(m.render().contains("LLC miss rate 20.0% (50 refs)"));
    }
}
