//! Batch driver: many jobs, **one** long-lived artifact store.
//!
//! The ROADMAP's serving north star (many jobs, heavy traffic, one
//! machine) needs the store to be a shared substrate rather than a
//! per-job cache. [`run_batch`] opens the store exactly once, threads it
//! through every job via [`run_job_with_store`], and relies on the
//! store's per-job eviction-exemption scopes: each job's writes are
//! protected while it runs and released the moment it completes, so the
//! exemption set stays bounded no matter how many jobs one instance
//! serves (the old instance-scoped `own_writes` set grew forever).
//!
//! Batch files (`cagra batch <file>`) are one job per line: `key=value`
//! tokens separated by whitespace, `#` starts a comment. Keys:
//!
//! ```text
//! app=<name>            required; any registered app (see `cagra apps`)
//! variant=<variant>     default: the app's default variant
//! graph=<dataset>       default: livejournal-sim
//! iters=N  sources=N  scale=F  analyze=true|false
//! delta-epsilon=F       per-job SystemConfig::delta_epsilon override
//! cf-k=N                per-job SystemConfig::cf_k override (1..=64)
//! damping=F             per-job SystemConfig::damping override
//! bfs-source=N          pin per-source apps to one original-space source
//! ```

use super::config::SystemConfig;
use super::job::{run_job_with_store, JobResult, JobSpec};
use crate::apps::registry;
use crate::store::ArtifactStore;
use anyhow::{bail, Context, Result};

/// Parse a batch file into job specs. Lines are independent; the first
/// malformed one fails the whole parse (a batch with a typo'd job should
/// not half-run).
pub fn parse_batch(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let job = parse_job(line).with_context(|| format!("batch line {}: {raw:?}", lineno + 1))?;
        jobs.push(job);
    }
    if jobs.is_empty() {
        bail!("batch contains no jobs (expected one `app=<name> ...` line per job)");
    }
    Ok(jobs)
}

fn parse_job(line: &str) -> Result<JobSpec> {
    let mut spec = JobSpec::default();
    let mut app: Option<&str> = None;
    let mut variant: Option<&str> = None;
    for tok in line.split_whitespace() {
        let Some((k, v)) = tok.split_once('=') else {
            bail!("expected key=value, got {tok:?}");
        };
        match k {
            "app" => app = Some(v),
            "variant" => variant = Some(v),
            "graph" => spec.dataset = v.to_string(),
            "iters" => spec.iters = v.parse().with_context(|| format!("iters={v:?}"))?,
            "sources" => {
                spec.num_sources = v.parse().with_context(|| format!("sources={v:?}"))?
            }
            "scale" => spec.scale = v.parse().with_context(|| format!("scale={v:?}"))?,
            "analyze" => {
                spec.analyze_memory = v.parse().with_context(|| format!("analyze={v:?}"))?
            }
            "delta-epsilon" | "delta_epsilon" => {
                spec.delta_epsilon =
                    Some(v.parse().with_context(|| format!("delta-epsilon={v:?}"))?)
            }
            "cf-k" | "cf_k" => {
                spec.cf_k = Some(v.parse().with_context(|| format!("cf-k={v:?}"))?)
            }
            "damping" => {
                spec.damping = Some(v.parse().with_context(|| format!("damping={v:?}"))?)
            }
            "bfs-source" | "bfs_source" => {
                spec.bfs_source = Some(v.parse().with_context(|| format!("bfs-source={v:?}"))?)
            }
            _ => bail!(
                "unknown batch key {k:?} (expected \
                 app|variant|graph|iters|sources|scale|analyze|delta-epsilon|\
                 cf-k|damping|bfs-source)"
            ),
        }
    }
    let Some(app) = app else {
        bail!("missing app=<name>");
    };
    let a = registry::find(app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app:?} (see `cagra apps`)"))?;
    spec.app = match variant {
        Some(v) => a.parse_variant(v)?,
        None => a.default_variant(),
    };
    Ok(spec)
}

/// Run every job over one shared [`ArtifactStore`] instance (opened at
/// most once, and only if the config enables the store and some job can
/// use it). Jobs run in order; the first failure aborts the batch.
pub fn run_batch(specs: &[JobSpec], cfg: &SystemConfig) -> Result<Vec<JobResult>> {
    run_batch_with(specs, cfg, |_, _, _| Ok(()))
}

/// [`run_batch`] with a per-job observer called after each job completes
/// (and before the next one starts), while the job's recorder events are
/// still drainable. `cagra batch --report-dir` uses it to emit one run
/// report per job; the first callback error aborts the batch like a job
/// failure would.
pub fn run_batch_with(
    specs: &[JobSpec],
    cfg: &SystemConfig,
    mut after_job: impl FnMut(usize, &JobSpec, &JobResult) -> Result<()>,
) -> Result<Vec<JobResult>> {
    let store = if cfg.store_enabled
        && specs
            .iter()
            .any(|s| registry::app_for(s.app).uses_store(s.app))
    {
        match ArtifactStore::open(&cfg.store_dir, cfg.store_cap_bytes) {
            Ok(s) => Some(s),
            Err(e) => {
                crate::log_warn!("artifact store disabled for this batch: {e:#}");
                None
            }
        }
    } else {
        None
    };
    specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let result = run_job_with_store(spec, cfg, store.as_ref()).with_context(|| {
                format!(
                    "batch job {} ({}/{} on {})",
                    i + 1,
                    spec.app.app_name(),
                    spec.app.variant_name(),
                    spec.dataset
                )
            })?;
            after_job(i, spec, &result)?;
            Ok(result)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{cc, pagerank};
    use crate::coordinator::AppKind;

    #[test]
    fn parses_jobs_comments_and_defaults() {
        let text = "\
# two jobs sharing one store
app=pagerank variant=both graph=rmat25-sim iters=3 scale=0.015625
app=cc graph=rmat25-sim iters=2 scale=0.015625  # default variant
";
        let jobs = parse_batch(text).unwrap();
        assert_eq!(jobs.len(), 2);
        assert!(matches!(
            jobs[0].app,
            AppKind::PageRank(pagerank::Variant::ReorderedSegmented)
        ));
        assert_eq!(jobs[0].iters, 3);
        assert_eq!(jobs[0].scale, 0.015625);
        // Unset keys keep JobSpec defaults; variant falls back to the
        // app's default.
        assert!(matches!(jobs[1].app, AppKind::Cc(cc::Variant::Segmented)));
        assert_eq!(jobs[1].num_sources, JobSpec::default().num_sources);
        assert!(jobs[1].delta_epsilon.is_none());
    }

    #[test]
    fn parses_delta_epsilon_override() {
        let jobs = parse_batch("app=pagerank-delta delta-epsilon=1e-6\n").unwrap();
        assert_eq!(jobs[0].delta_epsilon, Some(1e-6));
        let jobs = parse_batch("app=pagerank-delta delta_epsilon=1e-5\n").unwrap();
        assert_eq!(jobs[0].delta_epsilon, Some(1e-5));
    }

    #[test]
    fn parses_knob_overrides() {
        let jobs =
            parse_batch("app=cf cf-k=16\napp=pagerank damping=0.9\napp=bfs bfs-source=42\n")
                .unwrap();
        assert_eq!(jobs[0].cf_k, Some(16));
        assert_eq!(jobs[1].damping, Some(0.9));
        assert_eq!(jobs[2].bfs_source, Some(42));
        // Underscore aliases, like delta_epsilon's.
        let jobs = parse_batch("app=cf cf_k=4 bfs_source=1\n").unwrap();
        assert_eq!(jobs[0].cf_k, Some(4));
        assert_eq!(jobs[0].bfs_source, Some(1));
        assert!(parse_batch("app=cf cf-k=abc\n").is_err());
    }

    #[test]
    fn rejects_malformed_batches() {
        assert!(parse_batch("").is_err(), "no jobs");
        assert!(parse_batch("# only comments\n").is_err(), "no jobs");
        assert!(parse_batch("variant=both\n").is_err(), "missing app");
        assert!(parse_batch("app=nope\n").is_err(), "unknown app");
        assert!(parse_batch("app=pagerank variant=nope\n").is_err(), "unknown variant");
        assert!(parse_batch("app=pagerank iters\n").is_err(), "not key=value");
        assert!(parse_batch("app=pagerank iters=abc\n").is_err(), "bad number");
        assert!(parse_batch("app=pagerank color=red\n").is_err(), "unknown key");
    }
}
